//! Toy-model convergence demo (a fast Fig. 2 slice): KL to the target vs
//! step count for τ-leaping, θ-RK-2 and θ-trapezoidal, showing the order
//! gap directly.
//!
//!     cargo run --release --example toy_convergence

use fastdds::ctmc::ToyModel;
use fastdds::solvers::{grid, toy, Solver};
use fastdds::util::rng::Xoshiro256;
use fastdds::util::stats::loglog_slope;

fn main() {
    let model = ToyModel::from_artifact("artifacts/toy_model.json").unwrap_or_else(|_| {
        let mut rng = Xoshiro256::seed_from_u64(7);
        ToyModel::paper_default(&mut rng)
    });
    let steps = [4usize, 8, 16, 32, 64];
    let n = 150_000;
    println!("toy model: {} states, T = {}", model.n_states(), model.horizon);
    println!("{:>8} {:>14} {:>14} {:>14}", "steps", "tau", "rk2(1/2)", "trap(1/2)");
    let mut series = vec![Vec::new(), Vec::new(), Vec::new()];
    for &s in &steps {
        let g = grid::toy_uniform(s, model.horizon, 1e-3);
        let mut row = format!("{s:>8}");
        for (i, solver) in [
            Solver::TauLeaping,
            Solver::Rk2 { theta: 0.5 },
            Solver::Trapezoidal { theta: 0.5 },
        ]
        .into_iter()
        .enumerate()
        {
            let q = toy::empirical_distribution(&model, solver, &g, n, 9 + s as u64, 8);
            let kl = model.kl_from_p0(&q);
            series[i].push(kl.max(1e-9));
            row += &format!(" {kl:>14.3e}");
        }
        println!("{row}");
    }
    let xs: Vec<f64> = steps.iter().map(|&s| s as f64).collect();
    for (name, ys) in ["tau", "rk2", "trap"].iter().zip(&series) {
        let (slope, r2) = loglog_slope(&xs, ys);
        println!("{name:>6}: fitted order {:.2} (r2 {:.3})", -slope, r2);
    }
}
