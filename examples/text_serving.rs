//! End-to-end serving driver (the DESIGN.md validation run): start the full
//! stack — PJRT runtime over AOT artifacts, dynamic batcher, TCP server —
//! then act as a client workload: submit batched generation requests across
//! solvers and report latency/throughput plus sample quality.
//!
//!     make artifacts && cargo run --release --example text_serving
//!
//! Everything on the request path is rust; the artifacts were compiled from
//! JAX/Pallas once at build time.

use std::time::Instant;

use fastdds::coordinator::{BatchPolicy, Coordinator, GenerateRequest};
use fastdds::eval::perplexity::batch_perplexity;
use fastdds::runtime::{Registry, RuntimeHandle};
use fastdds::score::markov::MarkovChain;
use fastdds::server::{client::Client, Server};
use fastdds::solvers::Solver;

fn main() -> anyhow::Result<()> {
    if !fastdds::runtime::artifacts_available("artifacts") {
        eprintln!("artifacts missing: run `make artifacts` first");
        std::process::exit(2);
    }
    // ---- bring the stack up -------------------------------------------
    let runtime = RuntimeHandle::spawn("artifacts")?;
    let registry = Registry::load("artifacts")?;
    let names: Vec<String> = registry
        .by_family("markov")
        .iter()
        .map(|a| a.name.clone())
        .collect();
    println!("compiling {} markov artifacts ...", names.len());
    runtime.preload(&names.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;
    let coordinator = Coordinator::start(
        runtime,
        registry,
        BatchPolicy::Timeout(std::time::Duration::from_millis(5)),
    );
    let server = Server::start("127.0.0.1:0", coordinator.clone())?;
    println!("serving on {}", server.addr);

    // ---- client workload over TCP --------------------------------------
    let chain = MarkovChain::from_artifact("artifacts/markov_model.json")?;
    let mut client = Client::connect(&server.addr.to_string())?;
    let started = Instant::now();
    let mut total_samples = 0usize;
    for (solver, nfe) in [
        ("tau", 32),
        ("trapezoidal:0.5", 32),
        ("trapezoidal:0.5", 64),
        ("rk2:0.3333", 32),
        ("euler", 32),
        ("parallel", 8),
    ] {
        let resp = client.generate(solver, nfe, 8, 1234, "markov")?;
        let ppl = batch_perplexity(&chain, &resp.sequences);
        total_samples += resp.sequences.len();
        println!(
            "{:18} nfe={:4} -> {} samples, nfe_used={:4}, latency {:7.1} ms, ppl {:.3}",
            solver,
            nfe,
            resp.sequences.len(),
            resp.nfe_used,
            resp.latency_ms,
            ppl
        );
    }
    let wall = started.elapsed().as_secs_f64();
    println!(
        "\n{total_samples} samples in {wall:.2}s ({:.1} samples/s over TCP)",
        total_samples as f64 / wall
    );
    println!("server metrics: {}", client.metrics()?);

    // ---- direct-coordinator batch (no TCP) for peak throughput ---------
    let started = Instant::now();
    let handles: Vec<_> = (0..16)
        .map(|i| {
            coordinator.submit(GenerateRequest::new(
                1000 + i,
                fastdds::api::SamplingSpec::builder()
                    .family("markov")
                    .solver(Solver::Trapezoidal { theta: 0.5 })
                    .nfe(32)
                    .n_samples(4)
                    .seed(i)
                    .build()
                    .expect("valid spec"),
            ))
        })
        .collect();
    let mut n = 0;
    for h in handles {
        n += h.wait()?.sequences.len();
    }
    let wall = started.elapsed().as_secs_f64();
    println!(
        "direct coordinator: {n} samples in {wall:.2}s ({:.1} samples/s)",
        n as f64 / wall
    );
    server.stop();
    Ok(())
}
