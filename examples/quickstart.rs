//! Quickstart: sample from a masked discrete diffusion model with the
//! θ-trapezoidal solver (Alg. 2) against every baseline, entirely in-process.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the exact Markov oracle score (no artifacts needed); see
//! `text_serving.rs` for the full PJRT-served path.

use fastdds::data::corpus::decode_pretty;
use fastdds::eval::perplexity::{batch_perplexity, reference_perplexity};
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::{grid, masked, Solver};
use fastdds::util::rng::Xoshiro256;

fn main() {
    let vocab = 26;
    let seq_len = 64;
    let mut rng = Xoshiro256::seed_from_u64(42);
    let chain = MarkovChain::generate(&mut rng, vocab, 0.3);
    let oracle = MarkovOracle::new(chain.clone(), seq_len);

    let nfe = 32;
    println!("Sampling {seq_len}-token sequences at NFE = {nfe}:\n");
    for solver in [
        Solver::Euler,
        Solver::TauLeaping,
        Solver::Tweedie,
        Solver::Rk2 { theta: 1.0 / 3.0 },
        Solver::Trapezoidal { theta: 0.5 },
    ] {
        let g = grid::masked_uniform(solver.steps_for_nfe(nfe), 1e-3);
        let mut seqs = Vec::new();
        let mut rng = Xoshiro256::seed_from_u64(7);
        for _ in 0..64 {
            let (toks, _) = masked::generate(&oracle, solver, &g, &mut rng);
            seqs.push(toks);
        }
        let ppl = batch_perplexity(&chain, &seqs);
        println!(
            "{:22} perplexity {:7.3}   e.g. \"{}\"",
            solver.name(),
            ppl,
            decode_pretty(&seqs[0], vocab)
        );
    }
    let mut rng = Xoshiro256::seed_from_u64(1);
    println!(
        "{:22} perplexity {:7.3}   (true-data reference)",
        "-",
        reference_perplexity(&chain, seq_len, 500, &mut rng)
    );
}
