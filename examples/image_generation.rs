//! Image-generation example: token-grid "images" sampled with parallel
//! decoding vs the θ-trapezoidal method at a small NFE budget, with FID
//! against the true MRF law and ASCII previews (the Fig. 3/7 workloads as
//! a runnable demo).
//!
//!     cargo run --release --example image_generation

use fastdds::data::images::{
    features, project_features, reference_features, render_ascii, GridSpec,
};
use fastdds::eval::fid::fid;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::{grid, masked, Solver};
use fastdds::util::rng::Xoshiro256;
use fastdds::util::threadpool::par_map_indexed;

fn main() {
    let spec = GridSpec { h: 12, w: 12, vocab: 16 };
    let mut rng = Xoshiro256::seed_from_u64(3);
    let chain = MarkovChain::generate(&mut rng, spec.vocab, 0.5);
    let oracle = MarkovOracle::new(chain.clone(), spec.seq_len());
    let n = 400;
    let refs: Vec<Vec<f64>> = reference_features(&chain, &spec, 2 * n, 1)
        .iter()
        .map(|f| project_features(f, 64, 9))
        .collect();

    for (name, solver, nfe) in [
        ("parallel-decoding", Solver::ParallelDecoding, 8),
        ("theta-trapezoidal", Solver::Trapezoidal { theta: 1.0 / 3.0 }, 8),
        ("parallel-decoding", Solver::ParallelDecoding, 32),
        ("theta-trapezoidal", Solver::Trapezoidal { theta: 1.0 / 3.0 }, 32),
    ] {
        let g = grid::masked_uniform(solver.steps_for_nfe(nfe), 1e-3);
        let samples = par_map_indexed(n, 8, |i| {
            let mut rng = Xoshiro256::seed_from_u64(100 + i as u64);
            masked::generate(&oracle, solver, &g, &mut rng).0
        });
        let feats: Vec<Vec<f64>> = samples
            .iter()
            .map(|s| project_features(&features(&spec, s), 64, 9))
            .collect();
        println!(
            "{name:20} NFE={nfe:3}  FID = {:.4}",
            fid(&feats, &refs)
        );
        if nfe == 32 {
            println!("{}", render_ascii(&spec, &samples[0]));
        }
    }
    let mut rng = Xoshiro256::seed_from_u64(77);
    println!("true data sample:");
    println!(
        "{}",
        render_ascii(&spec, &chain.sample(&mut rng, spec.seq_len()))
    );
}
