//! Adaptive step-size control quickstart: error-controlled θ-trapezoidal
//! sampling vs the fixed uniform grid, hard NFE budgets, and offline-tuned
//! schedules — the `schedule/` subsystem end to end, entirely in-process.
//!
//!     cargo run --release --example adaptive_sampling
//!
//! The same controls are served over the JSON-lines protocol:
//!     {"cmd": "generate", "solver": "trapezoidal:0.5", "nfe": 64,
//!      "schedule": "adaptive:tol=1e-3", "nfe_budget": 48}

use fastdds::eval::perplexity::batch_perplexity;
use fastdds::schedule::adaptive::{AdaptiveController, NfeBudget, StepController};
use fastdds::schedule::ScheduleTuner;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::{grid, masked, Solver};
use fastdds::util::rng::Xoshiro256;

fn main() {
    let (vocab, seq_len, delta) = (26usize, 64usize, 1e-3);
    let mut rng = Xoshiro256::seed_from_u64(42);
    let chain = MarkovChain::generate(&mut rng, vocab, 0.3);
    let oracle = MarkovOracle::new(chain.clone(), seq_len);
    let solver = Solver::Trapezoidal { theta: 0.5 };
    let n_seqs = 48usize;

    // --- fixed uniform baseline at NFE = 64 ------------------------------
    let g = grid::masked_uniform(solver.steps_for_nfe(64), delta);
    let mut rng = Xoshiro256::seed_from_u64(7);
    let mut seqs = Vec::new();
    let mut nfe = 0usize;
    for _ in 0..n_seqs {
        let (toks, stats) = masked::generate(&oracle, solver, &g, &mut rng);
        nfe += stats.nfe;
        seqs.push(toks);
    }
    println!(
        "uniform grid       mean NFE {:5.1}  perplexity {:7.3}",
        nfe as f64 / n_seqs as f64,
        batch_perplexity(&chain, &seqs)
    );

    // --- online error control: the controller picks the steps ------------
    for tol in [1e-2, 1e-3, 1e-4] {
        let cfg = AdaptiveController::for_span(tol, 1.0, delta);
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut seqs = Vec::new();
        let mut nfe = 0usize;
        let mut steps = 0usize;
        for _ in 0..n_seqs {
            let ctl = StepController::new(cfg, 0.1);
            let (toks, stats, _trace) =
                masked::generate_adaptive(&oracle, solver, ctl, delta, &mut rng);
            nfe += stats.nfe;
            steps += stats.steps;
            seqs.push(toks);
        }
        println!(
            "adaptive tol={tol:<6.0e} mean NFE {:5.1}  perplexity {:7.3}  (mean steps {:.1})",
            nfe as f64 / n_seqs as f64,
            batch_perplexity(&chain, &seqs),
            steps as f64 / n_seqs as f64
        );
    }

    // --- hard NFE budget: spend at most 32 evaluations, no matter what ---
    let cfg = AdaptiveController::for_span(1e-4, 1.0, delta);
    let mut rng = Xoshiro256::seed_from_u64(7);
    let mut seqs = Vec::new();
    let mut max_nfe = 0usize;
    for _ in 0..n_seqs {
        let ctl = StepController::new(cfg, 0.1).with_budget(NfeBudget {
            total: 32,
            nfe_per_step: solver.nfe_per_step(),
            reserve: 1,
        });
        let (toks, stats, _) = masked::generate_adaptive(&oracle, solver, ctl, delta, &mut rng);
        max_nfe = max_nfe.max(stats.nfe);
        seqs.push(toks);
    }
    println!(
        "budget nfe<=32     max  NFE {max_nfe:5}  perplexity {:7.3}",
        batch_perplexity(&chain, &seqs)
    );

    // --- offline-tuned reusable grid (fit once, serve many) --------------
    let tuned = ScheduleTuner::default().fit_masked(&oracle, solver, 16, delta, "markov");
    let mut rng = Xoshiro256::seed_from_u64(7);
    let mut seqs = Vec::new();
    for _ in 0..n_seqs {
        seqs.push(masked::generate(&oracle, solver, &tuned.grid, &mut rng).0);
    }
    println!(
        "tuned 16 steps     nominal  {:5}  perplexity {:7.3}  (pilot mean NFE {:.1})",
        16 * solver.nfe_per_step(),
        batch_perplexity(&chain, &seqs),
        tuned.pilot_nfe
    );
    println!(
        "tuned grid front-loads the small-t region: first step {:.4}, last step {:.4}",
        tuned.grid[0] - tuned.grid[1],
        tuned.grid[tuned.grid.len() - 2] - tuned.grid[tuned.grid.len() - 1]
    );
}
