//! Parallel-in-time frontier bench (`cargo bench --bench pit`): the
//! latency-vs-NFE trade the PIT driver buys, written to `BENCH_pit.json`
//! for cross-PR tracking (`--quick` = smoke sizes, used by tier1.sh).
//!
//! "Rounds" is the sequential-round count — the latency unit when score
//! evaluations within one call batch for free: a sequential pass with a
//! two-stage scheme pays one round per score call (NFE rounds total),
//! while a PIT pass pays one round per *sweep* regardless of how many
//! slices that sweep evaluates.  At `tol = 0` PIT is bit-identical to the
//! sequential driver (asserted per lane below), so quality (toy-CTMC KL,
//! text perplexity) matches exactly and the frontier win is just
//! `mean sweeps < sequential NFE`.
//!
//! Headline row: the matched-KL comparison the ISSUE acceptance pins —
//! PIT must reach the sequential driver's toy-CTMC KL with strictly fewer
//! sequential rounds than the sequential NFE at >= 1 configuration.

use fastdds::ctmc::ToyModel;
use fastdds::schedule::grid;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::pit::PitCfg;
use fastdds::solvers::{masked, toy, Solver};
use fastdds::util::json::Json;
use fastdds::util::rng::Xoshiro256;
use fastdds::util::threadpool::ThreadPool;

struct Row {
    driver: String,
    steps: usize,
    /// Sequential rounds paid: NFE for the sequential driver, mean sweeps
    /// for PIT.
    rounds: f64,
    /// Score-evaluation work actually performed (mean per lane).
    nfe: f64,
    metric: &'static str,
    quality: f64,
}

fn write_report(rows: &[Row], headline: Json, quick: bool) {
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("driver", Json::from(r.driver.as_str())),
                ("steps", Json::from(r.steps as u64)),
                ("rounds", Json::Num(r.rounds)),
                ("nfe", Json::Num(r.nfe)),
                ("metric", Json::from(r.metric)),
                ("quality", Json::Num(r.quality)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::from("pit")),
        ("quick", Json::from(quick)),
        ("rows", Json::Arr(json_rows)),
        ("headline", headline),
    ]);
    let path = if std::path::Path::new("ROADMAP.md").exists() {
        "BENCH_pit.json"
    } else if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_pit.json"
    } else {
        "BENCH_pit.json"
    };
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path} ({} rows)", rows.len()),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

/// Exact-convergence PIT probe on the toy model: every lane must land bit
/// on the sequential sample, and the sweep count is what the bench plots.
/// Returns (mean_sweeps, max_sweeps, mean_nfe).
fn toy_pit_probe(
    model: &ToyModel,
    solver: Solver,
    g: &[f64],
    lanes: usize,
    seed0: u64,
) -> (f64, usize, f64) {
    let steps = g.len() - 1;
    let cfg = PitCfg::new(steps.max(1), 0.0);
    let (mut sweeps_sum, mut sweeps_max, mut nfe_sum) = (0usize, 0usize, 0usize);
    for b in 0..lanes {
        let seed = seed0 ^ (b as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut seq_rng = Xoshiro256::seed_from_u64(seed);
        let want = toy::generate(model, solver, g, &mut seq_rng);
        let mut pit_rng = Xoshiro256::seed_from_u64(seed);
        let lane = toy::pit_generate(model, solver, g, &cfg, &mut pit_rng);
        assert!(lane.outcome.converged(), "tol=0 probe must converge");
        assert_eq!(lane.out, want, "PIT broke bit-parity (seed {seed})");
        sweeps_sum += lane.sweeps;
        sweeps_max = sweeps_max.max(lane.sweeps);
        nfe_sum += lane.stats.nfe;
    }
    (
        sweeps_sum as f64 / lanes as f64,
        sweeps_max,
        nfe_sum as f64 / lanes as f64,
    )
}

/// Within-tolerance PIT law on the toy model (no sequential twin to
/// compare bits against — quality is measured by its own KL).
/// Returns (empirical law, mean_sweeps, mean_nfe).
fn toy_pit_distribution(
    model: &ToyModel,
    solver: Solver,
    g: &[f64],
    cfg: &PitCfg,
    n: usize,
    seed0: u64,
) -> (Vec<f64>, f64, f64) {
    let mut counts = vec![0u64; model.n_states()];
    let (mut sweeps_sum, mut nfe_sum) = (0usize, 0usize);
    for b in 0..n {
        let seed = seed0 ^ (b as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let lane = toy::pit_generate(model, solver, g, cfg, &mut rng);
        counts[lane.out] += 1;
        sweeps_sum += lane.sweeps;
        nfe_sum += lane.stats.nfe;
    }
    let q: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
    (q, sweeps_sum as f64 / n as f64, nfe_sum as f64 / n as f64)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 20_000 } else { 200_000 };
    let probe_lanes = if quick { 64 } else { 256 };
    println!(
        "== fastdds benches: pit (latency-vs-NFE frontier, n={n}{}) ==",
        if quick { ", --quick" } else { "" }
    );
    let mut rng = Xoshiro256::seed_from_u64(7);
    let model = ToyModel::paper_default(&mut rng);
    let delta = 1e-3;
    let solver = Solver::Trapezoidal { theta: 0.5 };
    let threads = ThreadPool::default_size();
    let mut rows: Vec<Row> = Vec::new();

    // --- toy CTMC: sequential baseline vs exact PIT ----------------------
    // (seq_nfe, seq_kl, pit_rounds) per steps config for the headline.
    let mut frontier: Vec<(usize, f64, f64, f64)> = Vec::new();
    let step_grid: &[usize] = if quick { &[4, 8] } else { &[4, 8, 16, 32] };
    for &steps in step_grid {
        let g = grid::toy_uniform(steps, model.horizon, delta);
        let q = toy::empirical_distribution(&model, solver, &g, n, 100 + steps as u64, threads);
        let kl = model.kl_from_p0(&q);
        let nfe = (2 * steps) as f64;
        println!("toy sequential steps={steps:3}  rounds={nfe:6.1}  nfe={nfe:6.1}  kl={kl:.3e}");
        rows.push(Row {
            driver: "sequential".into(),
            steps,
            rounds: nfe,
            nfe,
            metric: "kl",
            quality: kl,
        });

        let (mean_sweeps, max_sweeps, mean_nfe) =
            toy_pit_probe(&model, solver, &g, probe_lanes, 100 + steps as u64);
        // Bit-identical at tol=0 (asserted above), so the KL is the
        // sequential KL by construction.
        println!(
            "toy pit:tol=0  steps={steps:3}  rounds={mean_sweeps:6.1}  nfe={mean_nfe:6.1}  \
             kl={kl:.3e}  (max sweeps {max_sweeps})"
        );
        rows.push(Row {
            driver: "pit:tol=0".into(),
            steps,
            rounds: mean_sweeps,
            nfe: mean_nfe,
            metric: "kl",
            quality: kl,
        });
        frontier.push((steps, nfe, kl, mean_sweeps));
    }

    // --- toy CTMC: within-tolerance PIT (fewer sweeps, approximate) ------
    let tol_n = if quick { 4_000 } else { 40_000 };
    for &steps in step_grid {
        let g = grid::toy_uniform(steps, model.horizon, delta);
        for &tol in &[1e-2, 1e-1] {
            let cfg = PitCfg::new(steps.max(1), tol);
            let (q, mean_sweeps, mean_nfe) =
                toy_pit_distribution(&model, solver, &g, &cfg, tol_n, 300 + steps as u64);
            let kl = model.kl_from_p0(&q);
            println!(
                "toy pit:tol={tol:<5.0e} steps={steps:3}  rounds={mean_sweeps:6.1}  \
                 nfe={mean_nfe:6.1}  kl={kl:.3e}"
            );
            rows.push(Row {
                driver: format!("pit:tol={tol}"),
                steps,
                rounds: mean_sweeps,
                nfe: mean_nfe,
                metric: "kl",
                quality: kl,
            });
        }
    }

    // --- text (Markov oracle): perplexity at matched bits ----------------
    let mut crng = Xoshiro256::seed_from_u64(11);
    let chain = MarkovChain::generate(&mut crng, 8, 0.5);
    let seq_len = if quick { 16 } else { 32 };
    let oracle = MarkovOracle::new(chain.clone(), seq_len);
    let text_lanes = if quick { 32 } else { 128 };
    let text_steps: &[usize] = if quick { &[8] } else { &[8, 16] };
    for &steps in text_steps {
        let g = grid::masked_uniform(steps, delta);
        let cfg = PitCfg::new(steps.max(1), 0.0);
        let mut seqs: Vec<Vec<fastdds::score::Tok>> = Vec::with_capacity(text_lanes);
        let (mut nfe_sum, mut sweeps_sum) = (0usize, 0usize);
        for b in 0..text_lanes {
            let seed = 700 + b as u64;
            let mut seq_rng = Xoshiro256::seed_from_u64(seed);
            let (want, stats) = masked::generate(&oracle, solver, &g, &mut seq_rng);
            nfe_sum += stats.nfe;
            let mut pit_rng = Xoshiro256::seed_from_u64(seed);
            let lane = masked::pit_generate(&oracle, solver, &g, &cfg, &mut pit_rng);
            assert!(lane.outcome.converged(), "text tol=0 probe must converge");
            assert_eq!(lane.out, want, "text PIT broke bit-parity (seed {seed})");
            sweeps_sum += lane.sweeps;
            seqs.push(want);
        }
        let ppl = fastdds::eval::perplexity::batch_perplexity(&chain, &seqs);
        let seq_nfe = nfe_sum as f64 / text_lanes as f64;
        let pit_rounds = sweeps_sum as f64 / text_lanes as f64;
        println!(
            "text sequential steps={steps:3}  rounds={seq_nfe:6.1}  nfe={seq_nfe:6.1}  ppl={ppl:.3}"
        );
        println!(
            "text pit:tol=0  steps={steps:3}  rounds={pit_rounds:6.1}  nfe={seq_nfe:6.1}  \
             ppl={ppl:.3}  (bit-identical)"
        );
        rows.push(Row {
            driver: "sequential".into(),
            steps,
            rounds: seq_nfe,
            nfe: seq_nfe,
            metric: "perplexity",
            quality: ppl,
        });
        rows.push(Row {
            driver: "pit:tol=0".into(),
            steps,
            rounds: pit_rounds,
            nfe: seq_nfe,
            metric: "perplexity",
            quality: ppl,
        });
    }

    // --- headline: PIT rounds vs sequential NFE at matched KL ------------
    // tol=0 PIT is bit-identical to the sequential pass, so the KL is
    // matched exactly; the win condition is just rounds < NFE, and the
    // two-stage replay guarantees sweeps <= steps = NFE/2.
    let mut best: Option<(usize, f64, f64, f64)> = None; // (steps, ratio, rounds, nfe)
    for &(steps, nfe, _kl, pit_rounds) in &frontier {
        let ratio = pit_rounds / nfe;
        if best.map(|(_, r, ..)| ratio < r).unwrap_or(true) {
            best = Some((steps, ratio, pit_rounds, nfe));
        }
    }
    let headline = match best {
        Some((steps, ratio, pit_rounds, nfe)) => {
            let kl = frontier
                .iter()
                .find(|f| f.0 == steps)
                .map(|f| f.2)
                .unwrap_or(f64::NAN);
            let pass = pit_rounds < nfe;
            println!(
                "headline: pit rounds {pit_rounds:.1} vs sequential nfe {nfe:.1} at KL={kl:.3e} \
                 (steps={steps}) -> ratio {ratio:.3} ({})",
                if pass { "PASS rounds < nfe" } else { "FAIL" }
            );
            Json::obj(vec![
                ("metric", Json::from("pit_rounds_vs_sequential_nfe_at_matched_kl")),
                ("steps", Json::from(steps as u64)),
                ("pit_rounds", Json::Num(pit_rounds)),
                ("sequential_nfe", Json::Num(nfe)),
                ("kl", Json::Num(kl)),
                ("ratio", Json::Num(ratio)),
                ("pass", Json::from(pass)),
            ])
        }
        None => {
            println!("headline: no frontier rows recorded");
            Json::obj(vec![("metric", Json::from("unmatched"))])
        }
    };
    write_report(&rows, headline, quick);
}
