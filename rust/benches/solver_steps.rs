//! Solver micro/meso benchmarks (`cargo bench`): per-step cost of every
//! scheme on the oracle path, the end-to-end per-sample cost at the paper's
//! NFE budgets, and the PJRT artifact dispatch cost when artifacts exist.
//! One bench block per paper table/figure workload (DESIGN.md §Perf).
//!
//! Results are also written to `BENCH_solvers.json` (name, ns/iter,
//! samples/s) so the perf trajectory is tracked across PRs; pass `--quick`
//! for a smoke run (same rows, few iterations — tier1.sh uses it).
//!
//! Rows of interest for the sparse/batched pipeline:
//! - `markov_oracle_probs*`: dense vs masked-sparse score evaluation;
//! - `generate NFE=64 ...`: single-lane end-to-end (row names stable since
//!   the seed bench — compare across PRs);
//! - `generate_batch B=8 ...`: batched lane-parallel path vs single lanes;
//! - `hmm_eval {scalar,blocked,soa-batch} V=...` + `pit_slice_eval` +
//!   `hmm_soa_headline`: the kernel roofline (ns/eval, GF/s) — scalar
//!   reference vs blocked vs SoA-batched message passes; tier1.sh gates
//!   the headline speedup.

use fastdds::bench::{bench, black_box, BenchResult};
use fastdds::ctmc::ToyModel;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::score::ScoreSource;
use fastdds::solvers::{grid, masked, toy, Solver};
use fastdds::util::json::Json;
use fastdds::util::rng::Xoshiro256;

struct Report {
    rows: Vec<Json>,
}

impl Report {
    fn push(&mut self, r: &BenchResult, items_per_iter: f64) {
        self.push_with(r, items_per_iter, Vec::new());
    }

    /// As [`Report::push`] with extra JSON fields appended to the row (the
    /// roofline rows carry ns-per-eval and GF/s alongside the raw timings).
    fn push_with(&mut self, r: &BenchResult, items_per_iter: f64, extra: Vec<(&str, Json)>) {
        println!(
            "{}  ({:.1} samples/s)",
            r.report(),
            r.items_per_sec(items_per_iter)
        );
        let mut fields = vec![
            ("name", Json::from(r.name.trim())),
            ("ns_per_iter", Json::Num(r.mean_ns)),
            ("p50_ns", Json::Num(r.p50_ns)),
            ("samples_per_s", Json::Num(r.items_per_sec(items_per_iter))),
        ];
        fields.extend(extra);
        self.rows.push(Json::obj(fields));
    }

    fn write(&self, quick: bool) {
        let doc = Json::obj(vec![
            ("bench", Json::from("solver_steps")),
            ("quick", Json::from(quick)),
            ("rows", Json::Arr(self.rows.clone())),
        ]);
        // cargo bench runs with the package dir (rust/) as cwd; put the
        // record at the repo root (next to ROADMAP.md) when we can find it.
        let path = if std::path::Path::new("ROADMAP.md").exists() {
            "BENCH_solvers.json"
        } else if std::path::Path::new("../ROADMAP.md").exists() {
            "../BENCH_solvers.json"
        } else {
            "BENCH_solvers.json"
        };
        match std::fs::write(path, doc.to_string()) {
            Ok(()) => println!("wrote {path} ({} rows)", self.rows.len()),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // (warmup, iters) pairs for the heavy and light blocks.
    let (warm_g, it_g) = if quick { (1, 3) } else { (2, 20) };
    let (warm_p, it_p) = if quick { (1, 5) } else { (3, 50) };
    println!(
        "== fastdds benches: solver steps{} ==",
        if quick { " (--quick)" } else { "" }
    );
    let mut report = Report { rows: Vec::new() };
    let mut rng = Xoshiro256::seed_from_u64(1);

    // --- oracle score evaluation (the per-NFE cost unit, Tab. 1/2 work) --
    let (l, v) = (256usize, 32usize);
    let chain = MarkovChain::generate(&mut rng, v, 0.3);
    let oracle = MarkovOracle::new(chain.clone(), l);
    let tokens = fastdds::score::all_masked(l, oracle.mask_id());
    let mut out = vec![0.0; l * v];
    let r = bench("markov_oracle_probs L=256 V=32", warm_p, it_p, || {
        oracle.probs_into(black_box(&tokens), 0.5, &mut out);
    });
    report.push(&r, 1.0);

    // Sparse evaluation: full occupancy (parity check) and a late-step
    // occupancy (1/8 of dims still masked) where the sparse path wins.
    let idx_all: Vec<usize> = (0..l).collect();
    let r = bench("markov_oracle_probs_masked m=256", warm_p, it_p, || {
        oracle.probs_masked_into(black_box(&tokens), &idx_all, 0.5, &mut out);
    });
    report.push(&r, 1.0);
    let mut late = chain.sample(&mut rng, l);
    let idx_late: Vec<usize> = (0..l).step_by(8).collect();
    for &i in &idx_late {
        late[i] = oracle.mask_id();
    }
    let mut out_late = vec![0.0; idx_late.len() * v];
    let r = bench("markov_oracle_probs_masked m=32", warm_p, it_p, || {
        oracle.probs_masked_into(black_box(&late), &idx_late, 0.5, &mut out_late);
    });
    report.push(&r, 1.0);

    // --- categorical sampling: alias table vs linear CDF scan ------------
    // One-shot rows (the solver finalize/Tweedie case) rebuild the table
    // per draw, so the build must beat a single scan to earn its place on
    // that path; the prebuilt rows show where the table DOES win (fixed
    // laws drawn many times — `MarkovChain::sampler`, used by corpus
    // generation).  These rows are the recorded evidence for keeping the
    // linear scan in `finalize` and wiring `AliasTable` into bulk sampling.
    {
        use fastdds::util::dist::{categorical, AliasTable};
        let mut rng = Xoshiro256::seed_from_u64(9);
        let row: Vec<f64> =
            (0..v).map(|i| 1.0 + (i as f64 * 0.37).sin().abs()).collect();
        let r = bench("categorical linear one-shot V=32", warm_p, it_p, || {
            black_box(categorical(&mut rng, black_box(&row)));
        });
        report.push(&r, 1.0);
        let r = bench("alias build+draw one-shot V=32", warm_p, it_p, || {
            let t = AliasTable::new(black_box(&row));
            black_box(t.sample(&mut rng));
        });
        report.push(&r, 1.0);
        let table = AliasTable::new(&row);
        let r = bench("alias prebuilt draw V=32", warm_p, it_p, || {
            black_box(table.sample(&mut rng));
        });
        report.push(&r, 1.0);
        let r = bench("chain.sample linear L=256", warm_g, it_g, || {
            black_box(chain.sample(&mut rng, l));
        });
        report.push(&r, l as f64);
        let sampler = chain.sampler();
        let r = bench("chain.sampler alias L=256", warm_g, it_g, || {
            black_box(sampler.sample(&mut rng, l));
        });
        report.push(&r, l as f64);
    }

    // --- one full generation per solver at NFE=64 (Tab. 2 row cost) -----
    let solvers = [
        Solver::Euler,
        Solver::TauLeaping,
        Solver::Tweedie,
        Solver::Rk2 { theta: 0.3333 },
        Solver::Trapezoidal { theta: 0.5 },
        Solver::ParallelDecoding,
    ];
    for solver in solvers {
        let g = grid::masked_uniform(solver.steps_for_nfe(64), 1e-3);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let r = bench(
            &format!("generate NFE=64 {:22}", solver.name()),
            warm_g,
            it_g,
            || {
                black_box(masked::generate(&oracle, solver, &g, &mut rng));
            },
        );
        report.push(&r, 1.0);
    }

    // --- kernel dispatch overhead: enum shim vs direct Driver::run ------
    // The `generate NFE=64 ...` rows above go through the Solver-enum shim
    // (one match + validation per call); these rows call the monomorphised
    // driver with a concrete kernel directly.  Equal numbers (±2%) prove
    // the kernel/driver trait factoring costs nothing on the hot path.
    {
        use fastdds::solvers::driver::{run_single, Schedule};
        use fastdds::solvers::kernel::{
            EulerKernel, MaskedFamily, PdKernel, Rk2Kernel, TauLeapingKernel,
            TrapezoidalKernel, TweedieKernel,
        };
        // Deliberate (small) duplicate of the crate-private
        // `kernel::dispatch_masked_kernel!`: benches are an external crate
        // and the point here is selecting the kernel OUTSIDE the timed
        // closure.  A scheme added to the crate macro should be added here
        // too so its dispatch-overhead row keeps appearing.
        macro_rules! with_kernel {
            ($solver:expr, $k:ident => $body:expr) => {
                match $solver {
                    Solver::Euler => {
                        let $k = EulerKernel;
                        $body
                    }
                    Solver::TauLeaping => {
                        let $k = TauLeapingKernel;
                        $body
                    }
                    Solver::Tweedie => {
                        let $k = TweedieKernel;
                        $body
                    }
                    Solver::Trapezoidal { theta } => {
                        let $k = TrapezoidalKernel::new(theta);
                        $body
                    }
                    Solver::Rk2 { theta } => {
                        let $k = Rk2Kernel::new(theta);
                        $body
                    }
                    Solver::ParallelDecoding => {
                        let $k = PdKernel;
                        $body
                    }
                    Solver::Exact => unreachable!("exact is not a per-window kernel"),
                }
            };
        }
        for solver in solvers {
            let g = grid::masked_uniform(solver.steps_for_nfe(64), 1e-3);
            let mut rng = Xoshiro256::seed_from_u64(2);
            let r = with_kernel!(solver, k => bench(
                &format!("driver_direct NFE=64 {:15}", solver.name()),
                warm_g,
                it_g,
                || {
                    black_box(run_single::<MaskedFamily<MarkovOracle>, _, _>(
                        &oracle,
                        &k,
                        Schedule::Fixed(&g),
                        &mut rng,
                    ));
                },
            ));
            report.push(&r, 1.0);
        }
    }

    // --- exact simulation through the shim (realized-NFE cost unit) -----
    {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let g = grid::masked_uniform(64, 1e-3);
        let r = bench("generate exact (fhs) L=256", warm_g, it_g, || {
            black_box(masked::generate(&oracle, Solver::Exact, &g, &mut rng));
        });
        report.push(&r, 1.0);
    }

    // --- batched lane-parallel generation (B lanes per iteration) -------
    let b = 8usize;
    let seeds: Vec<u64> = (0..b as u64).map(|i| 1000 + i * 7919).collect();
    for solver in solvers {
        let g = grid::masked_uniform(solver.steps_for_nfe(64), 1e-3);
        let r = bench(
            &format!("generate_batch B=8 NFE=64 {:15}", solver.name()),
            warm_g,
            it_g,
            || {
                black_box(masked::generate_batch(&oracle, solver, &g, &seeds));
            },
        );
        report.push(&r, b as f64);
    }

    // --- first-hitting sampler (single-row evals, the sparse extreme) ---
    {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let r = bench("fhs_generate L=256", warm_g, it_g, || {
            black_box(masked::fhs_generate(&oracle, 1e-3, &mut rng));
        });
        report.push(&r, 1.0);
    }

    // --- toy model step (Fig. 2 inner loop) ------------------------------
    let mut rng = Xoshiro256::seed_from_u64(3);
    let model = ToyModel::paper_default(&mut rng);
    let g = grid::toy_uniform(32, model.horizon, 1e-3);
    for solver in [Solver::TauLeaping, Solver::Trapezoidal { theta: 0.5 }] {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let r = bench(
            &format!("toy generate 32 steps {:18}", solver.name()),
            if quick { 2 } else { 10 },
            if quick { 10 } else { 200 },
            || {
                black_box(toy::generate(&model, solver, &g, &mut rng));
            },
        );
        report.push(&r, 1.0);
    }

    // --- roofline: blocked/SoA HMM kernels vs frozen scalar reference ----
    // The per-NFE cost unit at three vocab scales, three ways: the frozen
    // scalar reference (`hmm::reference`), the blocked single-lane kernels,
    // and the SoA batched path amortising one matrix walk over 8 lanes.
    // ns_per_eval and GF/s ride on every row; the `hmm_soa_headline` row
    // carries the tier-1-gated speedup (SoA per-lane vs scalar at V=64).
    {
        use fastdds::score::hmm::{reference, HmmUniformOracle};
        use fastdds::score::{masked_indices, Tok};
        use fastdds::util::rng::Rng;

        let l = 64usize;
        let b = 8usize;
        let mut headline = (f64::NAN, f64::NAN); // (scalar, soa) ns/eval at V=64
        for &v in &[8usize, 64, 256] {
            let mut rng = Xoshiro256::seed_from_u64(100 + v as u64);
            let o = HmmUniformOracle::new(MarkovChain::generate(&mut rng, v, 0.5), l);
            let mask = o.mask_id();
            let lanes: Vec<(Vec<Tok>, Vec<usize>)> = (0..b)
                .map(|_| {
                    let tokens: Vec<Tok> = (0..l)
                        .map(|_| {
                            if rng.gen_bool(0.5) {
                                mask
                            } else {
                                rng.gen_usize(v) as Tok
                            }
                        })
                        .collect();
                    let idx = masked_indices(&tokens, mask);
                    (tokens, idx)
                })
                .collect();
            // Flops model: forward + backward transfers are each ~2·L·V²
            // mul/adds, so 4·L·V² flops per evaluation; flop/ns == GF/s.
            let flops = 4.0 * l as f64 * (v * v) as f64;

            let (tk0, ix0) = (&lanes[0].0, &lanes[0].1);
            let mut buf0 = vec![0.0; ix0.len() * v];
            let mut ws = reference::RefScratch::new();
            let r = bench(&format!("hmm_eval scalar V={v}"), warm_p, it_p, || {
                reference::probs_masked_scalar(
                    &o.chain,
                    black_box(tk0),
                    ix0,
                    0.35,
                    &mut ws,
                    &mut buf0,
                );
            });
            let scalar_ns = r.mean_ns;
            report.push_with(&r, 1.0, vec![
                ("ns_per_eval", Json::Num(scalar_ns)),
                ("gf_per_s", Json::Num(flops / scalar_ns)),
            ]);

            let r = bench(&format!("hmm_eval blocked V={v}"), warm_p, it_p, || {
                o.probs_masked_into(black_box(tk0), ix0, 0.35, &mut buf0);
            });
            report.push_with(&r, 1.0, vec![
                ("ns_per_eval", Json::Num(r.mean_ns)),
                ("gf_per_s", Json::Num(flops / r.mean_ns)),
            ]);

            let mut bufs: Vec<Vec<f64>> =
                lanes.iter().map(|(_, ix)| vec![0.0; ix.len() * v]).collect();
            let reqs: Vec<(&[Tok], &[usize])> =
                lanes.iter().map(|(tk, ix)| (tk.as_slice(), ix.as_slice())).collect();
            let r = bench(&format!("hmm_eval soa-batch B=8 V={v}"), warm_p, it_p, || {
                let mut outs: Vec<&mut [f64]> =
                    bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                o.probs_masked_batch(black_box(&reqs), 0.35, &mut outs);
            });
            let soa_ns = r.mean_ns / b as f64;
            report.push_with(&r, b as f64, vec![
                ("ns_per_eval", Json::Num(soa_ns)),
                ("gf_per_s", Json::Num(flops / soa_ns)),
            ]);

            if v == 64 {
                headline = (scalar_ns, soa_ns);
                // PIT slice-eval wall-clock: mixed per-slice t through the
                // same SoA path (the parallel-in-time sweep seam).
                let sreqs: Vec<(&[Tok], &[usize], f64)> = lanes
                    .iter()
                    .enumerate()
                    .map(|(k, (tk, ix))| (tk.as_slice(), ix.as_slice(), 0.1 + 0.1 * k as f64))
                    .collect();
                let r = bench("pit_slice_eval B=8 V=64", warm_p, it_p, || {
                    let mut outs: Vec<&mut [f64]> =
                        bufs.iter_mut().map(|x| x.as_mut_slice()).collect();
                    o.probs_masked_slices(black_box(&sreqs), &mut outs);
                });
                report.push_with(&r, b as f64, vec![
                    ("ns_per_eval", Json::Num(r.mean_ns / b as f64)),
                    ("gf_per_s", Json::Num(flops / (r.mean_ns / b as f64))),
                ]);
            }
        }
        let (scalar_ns, soa_ns) = headline;
        let speedup = scalar_ns / soa_ns;
        let pass = speedup >= 1.5;
        println!("hmm_soa_headline V=64 B=8: {speedup:.2}x scalar-per-lane (pass={pass})");
        report.rows.push(Json::obj(vec![
            ("name", Json::from("hmm_soa_headline V=64 B=8")),
            ("scalar_ns_per_eval", Json::Num(scalar_ns)),
            ("soa_ns_per_eval", Json::Num(soa_ns)),
            ("speedup", Json::Num(speedup)),
            ("pass", Json::from(pass)),
        ]));
    }

    // --- PJRT artifact dispatch (runtime hot path) -----------------------
    if fastdds::runtime::artifacts_available("artifacts") {
        use fastdds::runtime::{RuntimeHandle, Value};
        use fastdds::util::rng::Rng;
        let h = RuntimeHandle::spawn("artifacts").unwrap();
        h.preload(&["markov_step_trapezoidal", "markov_step_tau"]).unwrap();
        let (b, l) = (8usize, 32usize);
        let mut rng = Xoshiro256::seed_from_u64(5);
        for (name, stages) in [("markov_step_tau", 1usize), ("markov_step_trapezoidal", 2)] {
            let mut u = vec![0.0f32; stages * 2 * b * l];
            let r = bench(&format!("pjrt dispatch {name:28}"), warm_g, it_g, || {
                rng.fill_f32(&mut u);
                let mut inputs = vec![
                    Value::i32(vec![16; b * l], vec![b, l]),
                    Value::scalar_f32(0.9),
                    Value::scalar_f32(0.8),
                ];
                if stages == 2 {
                    inputs.push(Value::scalar_f32(0.5));
                }
                inputs.push(Value::f32(u.clone(), vec![stages, 2, b, l]));
                black_box(h.execute(name, inputs).unwrap());
            });
            report.push(&r, b as f64);
        }
    } else {
        println!("(artifact benches skipped: run `make artifacts`)");
    }

    report.write(quick);
}
