//! Solver micro/meso benchmarks (`cargo bench`): per-step cost of every
//! scheme on the oracle path, the end-to-end per-sample cost at the paper's
//! NFE budgets, and the PJRT artifact dispatch cost when artifacts exist.
//! One bench block per paper table/figure workload (DESIGN.md §Perf).

use fastdds::bench::{bench, black_box};
use fastdds::ctmc::ToyModel;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::score::ScoreSource;
use fastdds::solvers::{grid, masked, toy, Solver};
use fastdds::util::rng::Xoshiro256;

fn main() {
    println!("== fastdds benches: solver steps ==");
    let mut rng = Xoshiro256::seed_from_u64(1);

    // --- oracle score evaluation (the per-NFE cost unit, Tab. 1/2 work) --
    let chain = MarkovChain::generate(&mut rng, 32, 0.3);
    let oracle = MarkovOracle::new(chain.clone(), 256);
    let tokens = fastdds::score::all_masked(256, oracle.mask_id());
    let mut out = vec![0.0; 256 * 32];
    let r = bench("markov_oracle_probs L=256 V=32", 3, 50, || {
        oracle.probs_into(black_box(&tokens), 0.5, &mut out);
    });
    println!("{}", r.report());

    // --- one full generation per solver at NFE=64 (Tab. 2 row cost) -----
    for solver in [
        Solver::Euler,
        Solver::TauLeaping,
        Solver::Tweedie,
        Solver::Rk2 { theta: 0.3333 },
        Solver::Trapezoidal { theta: 0.5 },
        Solver::ParallelDecoding,
    ] {
        let g = grid::masked_uniform(solver.steps_for_nfe(64), 1e-3);
        let mut rng = Xoshiro256::seed_from_u64(2);
        let r = bench(
            &format!("generate NFE=64 {:22}", solver.name()),
            2,
            20,
            || {
                black_box(masked::generate(&oracle, solver, &g, &mut rng));
            },
        );
        println!("{}  ({:.1} samples/s)", r.report(), r.items_per_sec(1.0));
    }

    // --- toy model step (Fig. 2 inner loop) ------------------------------
    let mut rng = Xoshiro256::seed_from_u64(3);
    let model = ToyModel::paper_default(&mut rng);
    let g = grid::toy_uniform(32, model.horizon, 1e-3);
    for solver in [Solver::TauLeaping, Solver::Trapezoidal { theta: 0.5 }] {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let r = bench(
            &format!("toy generate 32 steps {:18}", solver.name()),
            10,
            200,
            || {
                black_box(toy::generate(&model, solver, &g, &mut rng));
            },
        );
        println!("{}", r.report());
    }

    // --- PJRT artifact dispatch (runtime hot path) -----------------------
    if fastdds::runtime::artifacts_available("artifacts") {
        use fastdds::runtime::{RuntimeHandle, Value};
        use fastdds::util::rng::Rng;
        let h = RuntimeHandle::spawn("artifacts").unwrap();
        h.preload(&["markov_step_trapezoidal", "markov_step_tau"]).unwrap();
        let (b, l) = (8usize, 32usize);
        let mut rng = Xoshiro256::seed_from_u64(5);
        for (name, stages) in [("markov_step_tau", 1usize), ("markov_step_trapezoidal", 2)] {
            let mut u = vec![0.0f32; stages * 2 * b * l];
            let r = bench(&format!("pjrt dispatch {name:28}"), 3, 30, || {
                rng.fill_f32(&mut u);
                let mut inputs = vec![
                    Value::i32(vec![16; b * l], vec![b, l]),
                    Value::scalar_f32(0.9),
                    Value::scalar_f32(0.8),
                ];
                if stages == 2 {
                    inputs.push(Value::scalar_f32(0.5));
                }
                inputs.push(Value::f32(u.clone(), vec![stages, 2, b, l]));
                black_box(h.execute(name, inputs).unwrap());
            });
            println!(
                "{}  ({:.1} lanes/s)",
                r.report(),
                r.items_per_sec(b as f64)
            );
        }
    } else {
        println!("(artifact benches skipped: run `make artifacts`)");
    }
}
