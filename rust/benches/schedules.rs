//! Schedule-quality bench (`cargo bench --bench schedules`): KL / NFE rows
//! on the toy CTMC for fixed uniform grids vs the online adaptive
//! controller vs offline-tuned grids, written to `BENCH_schedules.json`
//! for cross-PR tracking (`--quick` = smoke sizes, used by tier1.sh).
//!
//! Headline row: the matched-KL comparison the ISSUE acceptance pins —
//! for each adaptive run, the smallest uniform-grid NFE reaching the same
//! KL is found and the NFE ratio recorded; `ratio <= 0.6` means the
//! adaptive controller delivers the claimed quality-per-NFE win.

use fastdds::ctmc::ToyModel;
use fastdds::schedule::adaptive::{AdaptiveController, StepController};
use fastdds::schedule::ScheduleTuner;
use fastdds::solvers::{grid, toy, Solver};
use fastdds::util::json::Json;
use fastdds::util::rng::Xoshiro256;
use fastdds::util::threadpool::ThreadPool;

struct Row {
    schedule: String,
    nfe: f64,
    kl: f64,
}

fn write_report(rows: &[Row], headline: Json, quick: bool) {
    let json_rows: Vec<Json> = rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("schedule", Json::from(r.schedule.as_str())),
                ("nfe", Json::Num(r.nfe)),
                ("kl", Json::Num(r.kl)),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("bench", Json::from("schedules")),
        ("quick", Json::from(quick)),
        ("rows", Json::Arr(json_rows)),
        ("headline", headline),
    ]);
    let path = if std::path::Path::new("ROADMAP.md").exists() {
        "BENCH_schedules.json"
    } else if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_schedules.json"
    } else {
        "BENCH_schedules.json"
    };
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path} ({} rows)", rows.len()),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n = if quick { 20_000 } else { 200_000 };
    println!(
        "== fastdds benches: schedules (toy CTMC, n={n}{}) ==",
        if quick { ", --quick" } else { "" }
    );
    let mut rng = Xoshiro256::seed_from_u64(7);
    let model = ToyModel::paper_default(&mut rng);
    let delta = 1e-3;
    let solver = Solver::Trapezoidal { theta: 0.5 };
    let threads = ThreadPool::default_size();
    let mut rows: Vec<Row> = Vec::new();

    // --- fixed uniform grids (the seed baseline) -------------------------
    let fixed_steps: &[usize] = &[2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96];
    let mut uniform: Vec<(f64, f64)> = Vec::new(); // (nfe, kl)
    for &steps in fixed_steps {
        let g = grid::toy_uniform(steps, model.horizon, delta);
        let q = toy::empirical_distribution(&model, solver, &g, n, 100 + steps as u64, threads);
        let kl = model.kl_from_p0(&q);
        let nfe = (2 * steps) as f64;
        println!("uniform     steps={steps:3}  nfe={nfe:6.1}  kl={kl:.3e}");
        uniform.push((nfe, kl));
        rows.push(Row { schedule: format!("uniform:steps={steps}"), nfe, kl });
    }

    // --- online adaptive at a tolerance sweep ----------------------------
    let mut adaptive: Vec<(f64, f64)> = Vec::new();
    for &tol in &[1e-1, 3e-2, 1e-2, 3e-3, 1e-3] {
        let cfg = AdaptiveController::for_span(tol, model.horizon, delta);
        let ctl = StepController::new(cfg, model.horizon / 8.0);
        let (q, mean_nfe) =
            toy::empirical_distribution_adaptive(&model, solver, &ctl, delta, n, 500, threads);
        let kl = model.kl_from_p0(&q);
        println!("adaptive    tol={tol:<7.0e}  nfe={mean_nfe:6.1}  kl={kl:.3e}");
        adaptive.push((mean_nfe, kl));
        rows.push(Row { schedule: format!("adaptive:tol={tol}"), nfe: mean_nfe, kl });
    }

    // --- offline-tuned grids ---------------------------------------------
    for &steps in &[4usize, 6, 8, 12, 16, 24] {
        let tuned = ScheduleTuner::default().fit_toy(&model, solver, steps, delta);
        let q =
            toy::empirical_distribution(&model, solver, &tuned.grid, n, 900 + steps as u64, threads);
        let kl = model.kl_from_p0(&q);
        let nfe = (2 * steps) as f64;
        println!("tuned       steps={steps:3}  nfe={nfe:6.1}  kl={kl:.3e}");
        rows.push(Row { schedule: format!("tuned:steps={steps}"), nfe, kl });
    }

    // --- headline: adaptive vs uniform at matched KL ---------------------
    // For each adaptive run, the cheapest uniform grid at least as good
    // (KL <= adaptive KL) gives the NFE it would take the seed baseline to
    // match; the best ratio across the sweep is the recorded headline.
    let mut best: Option<(f64, f64, f64, f64)> = None; // (ratio, a_nfe, u_nfe, kl)
    for &(a_nfe, a_kl) in &adaptive {
        let matched = uniform
            .iter()
            .filter(|&&(_, u_kl)| u_kl <= a_kl)
            .map(|&(u_nfe, _)| u_nfe)
            .fold(f64::INFINITY, f64::min);
        if matched.is_finite() {
            let ratio = a_nfe / matched;
            if best.map(|(r, ..)| ratio < r).unwrap_or(true) {
                best = Some((ratio, a_nfe, matched, a_kl));
            }
        }
    }
    let headline = match best {
        Some((ratio, a_nfe, u_nfe, kl)) => {
            println!(
                "headline: adaptive nfe {a_nfe:.1} vs uniform nfe {u_nfe:.1} at KL<={kl:.3e} \
                 -> ratio {ratio:.3} ({})",
                if ratio <= 0.6 { "PASS <= 0.6" } else { "above 0.6" }
            );
            Json::obj(vec![
                ("metric", Json::from("adaptive_vs_uniform_nfe_at_matched_kl")),
                ("adaptive_nfe", Json::Num(a_nfe)),
                ("uniform_nfe", Json::Num(u_nfe)),
                ("kl", Json::Num(kl)),
                ("ratio", Json::Num(ratio)),
                ("pass_0p6", Json::from(ratio <= 0.6)),
            ])
        }
        None => {
            println!("headline: no uniform grid matched any adaptive KL (sweep too coarse)");
            Json::obj(vec![("metric", Json::from("unmatched"))])
        }
    };
    write_report(&rows, headline, quick);
}
