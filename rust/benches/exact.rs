//! Exact-simulation benchmarks (`cargo bench --bench exact`): the
//! bracketed-thinning hot path of `ctmc::uniformization`, measured as
//! evaluations-per-sample, wall-clock-per-sample, and bracket hit rates
//! for both exact families — the HMM uniform-state text process (brackets
//! armed) and the toy CTMC (closed-form totals, bracket-free) — plus the
//! naive always-evaluate baseline (`NoBracket`) on the same seeds, so the
//! eval-reduction headline is an apples-to-apples ratio over bit-identical
//! jump streams.
//!
//! Results land in `BENCH_exact.json` (tier1.sh runs `--quick` and asserts
//! the evals-per-sample and bracket-hit-rate rows exist for both
//! families).  A warm-scratch FID row rides along as the `eval/linalg`
//! in-place evidence.

use fastdds::bench::{bench, black_box, BenchResult};
use fastdds::ctmc::uniformization::{
    simulate_backward_into, ExactStats, JumpProcess, NoBracket, ToyJump,
};
use fastdds::ctmc::ToyModel;
use fastdds::score::hmm::{HmmUniformOracle, UniformTextJump};
use fastdds::score::markov::MarkovChain;
use fastdds::score::Tok;
use fastdds::util::json::Json;
use fastdds::util::rng::{Rng, Xoshiro256};

struct Report {
    rows: Vec<Json>,
}

impl Report {
    fn value(&mut self, name: &str, value: f64) {
        println!("{name:44} {value:>12.2}");
        self.rows.push(Json::obj(vec![
            ("name", Json::from(name)),
            ("value", Json::Num(value)),
        ]));
    }

    fn timing(&mut self, r: &BenchResult) {
        println!("{}", r.report());
        self.rows.push(Json::obj(vec![
            ("name", Json::from(r.name.trim())),
            ("ns_per_iter", Json::Num(r.mean_ns)),
            ("p50_ns", Json::Num(r.p50_ns)),
        ]));
    }

    fn write(&self, quick: bool) {
        let doc = Json::obj(vec![
            ("bench", Json::from("exact")),
            ("quick", Json::from(quick)),
            ("rows", Json::Arr(self.rows.clone())),
        ]);
        let path = if std::path::Path::new("ROADMAP.md").exists() {
            "BENCH_exact.json"
        } else if std::path::Path::new("../ROADMAP.md").exists() {
            "../BENCH_exact.json"
        } else {
            "BENCH_exact.json"
        };
        match std::fs::write(path, doc.to_string()) {
            Ok(()) => println!("wrote {path} ({} rows)", self.rows.len()),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }
}

/// One full HMM uniform-state exact sample (bracketed or naive).
fn hmm_sample<P: JumpProcess<State = Vec<Tok>>>(
    proc: &P,
    seq_len: usize,
    vocab: usize,
    horizon: f64,
    t_end: f64,
    window_ratio: f64,
    seed: u64,
) -> ExactStats {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let x0: Vec<Tok> = (0..seq_len).map(|_| rng.gen_usize(vocab) as Tok).collect();
    let mut stats = ExactStats::counts_only();
    let x = simulate_backward_into(proc, x0, horizon, t_end, window_ratio, &mut rng, &mut stats);
    black_box(x);
    stats
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    println!(
        "== fastdds benches: exact simulation{} ==",
        if quick { " (--quick)" } else { "" }
    );
    let mut report = Report { rows: Vec::new() };

    // --- HMM uniform-state family (brackets armed) -----------------------
    // Near-deterministic rows push the score toward the Fig. 1 singularity
    // so the candidate count dominates the window count — the regime the
    // brackets are for.
    let (vocab, seq_len) = (6usize, 12usize);
    let (horizon, t_end, window_ratio) = (6.0, 0.01, 0.9);
    let slack = fastdds::ctmc::uniformization::DEFAULT_SLACK;
    let n_samples = if quick { 4u64 } else { 16 };
    let mut rng = Xoshiro256::seed_from_u64(1);
    let chain = MarkovChain::generate(&mut rng, vocab, 0.15);
    let oracle = HmmUniformOracle::new(chain, seq_len);
    let bracketed = UniformTextJump { oracle: &oracle, slack };
    let naive = NoBracket(UniformTextJump { oracle: &oracle, slack });

    let (mut ev_b, mut ev_n, mut cands, mut hits) = (0usize, 0usize, 0usize, 0usize);
    for seed in 0..n_samples {
        let sb = hmm_sample(&bracketed, seq_len, vocab, horizon, t_end, window_ratio, seed);
        let sn = hmm_sample(&naive, seq_len, vocab, horizon, t_end, window_ratio, seed);
        assert_eq!(
            sb.n_accepted, sn.n_accepted,
            "bracketed and naive loops must realize identical jump streams"
        );
        assert_eq!(sb.n_candidates, sn.n_candidates);
        ev_b += sb.nfe;
        ev_n += sn.nfe;
        cands += sb.n_candidates;
        hits += sb.free_rejects;
    }
    let per = |x: usize| x as f64 / n_samples as f64;
    report.value("exact hmm evals-per-sample", per(ev_b));
    report.value("exact hmm evals-per-sample naive", per(ev_n));
    report.value("exact hmm candidates-per-sample", per(cands));
    report.value(
        "exact hmm eval-reduction (naive/bracketed)",
        ev_n as f64 / ev_b.max(1) as f64,
    );
    report.value(
        "exact hmm bracket-hit-rate",
        if cands == 0 { 0.0 } else { hits as f64 / cands as f64 },
    );

    let (warm, iters) = if quick { (1, 3) } else { (2, 10) };
    let mut seed = 1000u64;
    let r = bench("exact hmm wall-clock/sample (bracketed)", warm, iters, || {
        seed += 1;
        black_box(hmm_sample(
            &bracketed,
            seq_len,
            vocab,
            horizon,
            t_end,
            window_ratio,
            seed,
        ));
    });
    report.timing(&r);
    let mut seed = 1000u64;
    let r = bench("exact hmm wall-clock/sample (naive)", warm, iters, || {
        seed += 1;
        black_box(hmm_sample(
            &naive,
            seq_len,
            vocab,
            horizon,
            t_end,
            window_ratio,
            seed,
        ));
    });
    report.timing(&r);

    // --- toy family (closed-form totals, bracket-free) -------------------
    let mut rng = Xoshiro256::seed_from_u64(3);
    let model = ToyModel::paper_default(&mut rng);
    let proc = ToyJump(&model);
    let toy_samples = if quick { 200u64 } else { 2000 };
    let (mut ev_t, mut cands_t, mut hits_t) = (0usize, 0usize, 0usize);
    for seed in 0..toy_samples {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let x0 = model.sample_stationary(&mut rng);
        let mut stats = ExactStats::counts_only();
        let x =
            simulate_backward_into(&proc, x0, model.horizon, 1e-3, 0.5, &mut rng, &mut stats);
        black_box(x);
        ev_t += stats.nfe;
        cands_t += stats.n_candidates;
        hits_t += stats.free_rejects;
    }
    report.value("exact toy evals-per-sample", ev_t as f64 / toy_samples as f64);
    report.value(
        "exact toy bracket-hit-rate",
        if cands_t == 0 { 0.0 } else { hits_t as f64 / cands_t as f64 },
    );
    let mut seed = 0u64;
    let r = bench("exact toy wall-clock/sample", warm, iters.max(20), || {
        seed += 1;
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let x0 = model.sample_stationary(&mut rng);
        let mut stats = ExactStats::counts_only();
        black_box(simulate_backward_into(
            &proc,
            x0,
            model.horizon,
            1e-3,
            0.5,
            &mut rng,
            &mut stats,
        ));
    });
    report.timing(&r);

    // --- FID with warm scratch (eval/linalg in-place evidence) -----------
    {
        use fastdds::eval::fid::{frechet_distance_with, moments_with, FidScratch, MomentsScratch};
        let d = 32usize;
        let n = if quick { 200 } else { 1000 };
        let mut rng = Xoshiro256::seed_from_u64(7);
        let cloud = |rng: &mut Xoshiro256, shift: f64| -> Vec<Vec<f64>> {
            (0..n)
                .map(|_| (0..d).map(|_| shift + rng.gen_f64()).collect())
                .collect()
        };
        let a = cloud(&mut rng, 0.0);
        let b = cloud(&mut rng, 0.1);
        let mut ms = MomentsScratch::default();
        let mut fs = FidScratch::new();
        let ma = moments_with(&a, &mut ms);
        let mb = moments_with(&b, &mut ms);
        let r = bench("fid d=32 warm-scratch", warm, iters.max(10), || {
            black_box(frechet_distance_with(&ma, &mb, &mut fs));
        });
        report.timing(&r);
    }

    report.write(quick);
}
