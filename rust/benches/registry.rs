//! Artifact-registry bench (`cargo bench --bench registry`): blob-store
//! throughput plus the headline the ISSUE acceptance pins — pulling a
//! published tuned schedule by digest vs re-fitting it locally.  Rows go
//! to `BENCH_registry.json` for cross-PR tracking (`--quick` = smoke
//! sizes, used by tier1.sh).
//!
//! Rows:
//!   - `registry put MB-per-s` — hash + write-temp-rename + manifest
//!     publish, per distinct artifact;
//!   - `registry get MB-per-s` — manifest parse + verified (re-hashed)
//!     blob reads;
//!   - headline `cold_pull_vs_refit_ms` — a cold coordinator pulling the
//!     fleet's tuned grid by digest must beat running the pilot fits.

use std::sync::Arc;
use std::time::Instant;

use fastdds::registry::{ArtifactKind, ArtifactRegistry, ManifestV1};
use fastdds::schedule::{ScheduleCache, ScheduleTuner, TuneKey};
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::Solver;
use fastdds::util::json::Json;
use fastdds::util::rng::Xoshiro256;

fn write_report(rows: Vec<Json>, headline: Json, quick: bool) {
    let n = rows.len();
    let doc = Json::obj(vec![
        ("bench", Json::from("registry")),
        ("quick", Json::from(quick)),
        ("rows", Json::Arr(rows)),
        ("headline", headline),
    ]);
    let path = if std::path::Path::new("ROADMAP.md").exists() {
        "BENCH_registry.json"
    } else if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_registry.json"
    } else {
        "BENCH_registry.json"
    };
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => println!("wrote {path} ({n} rows)"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let (n_blobs, blob_len) =
        if quick { (24usize, 128 * 1024usize) } else { (96, 1024 * 1024) };
    let total_mb = (n_blobs * blob_len) as f64 / 1e6;
    println!(
        "== fastdds benches: registry ({n_blobs} x {} KiB blobs{}) ==",
        blob_len / 1024,
        if quick { ", --quick" } else { "" }
    );

    let root = std::env::temp_dir()
        .join(format!("fastdds_bench_registry_{}", std::process::id()));
    let root = root.to_str().unwrap().to_string();
    let _ = std::fs::remove_dir_all(&root);
    let reg = ArtifactRegistry::open(&root).unwrap();

    // Deterministic pseudo-random content: incompressible-ish, distinct
    // per artifact so content addressing cannot dedup the workload away.
    let mut rng = Xoshiro256::seed_from_u64(41);
    let blobs: Vec<Vec<u8>> = (0..n_blobs)
        .map(|_| {
            let mut b = Vec::with_capacity(blob_len + 8);
            while b.len() < blob_len {
                b.extend_from_slice(&rng.next_u64().to_le_bytes());
            }
            b.truncate(blob_len);
            b
        })
        .collect();

    // --- put throughput ---------------------------------------------------
    let t0 = Instant::now();
    let digests: Vec<String> = blobs
        .iter()
        .enumerate()
        .map(|(i, b)| {
            let mut m =
                ManifestV1::new(ArtifactKind::CompatCorpus, &format!("bench-{i}"));
            m.family = "bench".into();
            m.created_by = "bench".into();
            reg.put(m, &[b.as_slice()]).unwrap()
        })
        .collect();
    let put_s = t0.elapsed().as_secs_f64();
    let put_mbps = total_mb / put_s;
    println!("registry put   {total_mb:8.1} MB in {put_s:6.3}s -> {put_mbps:8.1} MB/s");

    // --- get throughput (every read re-hashed and verified) ---------------
    let t0 = Instant::now();
    let mut read_bytes = 0usize;
    for d in &digests {
        let (_, got) = reg.get(d).unwrap();
        read_bytes += got.iter().map(Vec::len).sum::<usize>();
    }
    let get_s = t0.elapsed().as_secs_f64();
    assert_eq!(read_bytes, n_blobs * blob_len);
    let get_mbps = total_mb / get_s;
    println!("registry get   {total_mb:8.1} MB in {get_s:6.3}s -> {get_mbps:8.1} MB/s");

    let rows = vec![
        Json::obj(vec![
            ("row", Json::from("registry put MB-per-s")),
            ("mb_per_s", Json::Num(put_mbps)),
            ("bytes", Json::from(n_blobs * blob_len)),
            ("artifacts", Json::from(n_blobs)),
        ]),
        Json::obj(vec![
            ("row", Json::from("registry get MB-per-s")),
            ("mb_per_s", Json::Num(get_mbps)),
            ("bytes", Json::from(read_bytes)),
            ("artifacts", Json::from(n_blobs)),
        ]),
    ];

    // --- headline: cold digest pull vs local re-fit ------------------------
    // The serving-path fit (ScheduleTuner, 2 pilots — exactly what the
    // scheduler runs inline on a cache miss) vs a cold cache pulling the
    // published grid from the shared registry.
    let mut orng = Xoshiro256::seed_from_u64(23);
    let oracle = MarkovOracle::new(MarkovChain::generate(&mut orng, 6, 0.5), 14);
    let solver = Solver::Trapezoidal { theta: 0.5 };
    let steps = 8;
    let t0 = Instant::now();
    let fitted = ScheduleTuner { pilots: 2, tol: 1e-3, ..Default::default() }
        .fit_masked(&oracle, solver, steps, 1e-3, "markov");
    let refit_ms = t0.elapsed().as_secs_f64() * 1e3;
    reg.publish_tuned(&fitted, "bench").unwrap();

    let key = TuneKey::new("markov", 6, 14, solver, steps);
    let t0 = Instant::now();
    let mut cold = ScheduleCache::with_store(None, Some(Arc::clone(&reg)));
    let pulled = cold.get_or_fit(key, || panic!("cold pull must not run the tuner"));
    let pull_ms = t0.elapsed().as_secs_f64() * 1e3;
    assert_eq!(pulled.grid, fitted.grid, "pulled grid must be the published one");

    let speedup = refit_ms / pull_ms.max(1e-6);
    let pass = pull_ms < refit_ms;
    println!(
        "headline: cold pull {pull_ms:.3} ms vs re-fit {refit_ms:.3} ms \
         -> {speedup:.1}x ({})",
        if pass { "PASS pull < refit" } else { "refit was faster" }
    );
    let headline = Json::obj(vec![
        ("metric", Json::from("cold_pull_vs_refit_ms")),
        ("pull_ms", Json::Num(pull_ms)),
        ("refit_ms", Json::Num(refit_ms)),
        ("speedup", Json::Num(speedup)),
        ("pass", Json::from(pass)),
    ]);

    write_report(rows, headline, quick);
    let _ = std::fs::remove_dir_all(&root);
}
