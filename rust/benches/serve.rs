//! `bench serve` — end-to-end serving-path smoke over real TCP: N
//! concurrent clients hammer a local-oracle server with v2 requests,
//! blocking vs streaming, and the cancellation latency of a long exact
//! run is measured.  Results land in `BENCH_serve.json` (tier1.sh runs
//! `--quick` and asserts the rows), tracking requests/sec and p50/p99
//! request latency across PRs.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastdds::api::SamplingSpec;
use fastdds::coordinator::{BatchPolicy, Coordinator, CoordinatorCfg};
use fastdds::score::hmm::HmmUniformOracle;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::server::client::Client;
use fastdds::server::Server;
use fastdds::solvers::Solver;
use fastdds::testkit::fault::{silence_injected_panics, FaultPlan, FaultyScore};
use fastdds::util::json::Json;
use fastdds::util::rng::Xoshiro256;

struct Report {
    rows: Vec<Json>,
}

impl Report {
    fn value(&mut self, name: &str, value: f64) {
        println!("{name:44} {value:>12.2}");
        self.rows.push(Json::obj(vec![
            ("name", Json::from(name)),
            ("value", Json::Num(value)),
        ]));
    }

    fn write(&self, quick: bool) {
        let doc = Json::obj(vec![
            ("bench", Json::from("serve")),
            ("quick", Json::from(quick)),
            ("rows", Json::Arr(self.rows.clone())),
        ]);
        let path = if std::path::Path::new("ROADMAP.md").exists() {
            "BENCH_serve.json"
        } else if std::path::Path::new("../ROADMAP.md").exists() {
            "../BENCH_serve.json"
        } else {
            "BENCH_serve.json"
        };
        match std::fs::write(path, doc.to_string()) {
            Ok(()) => println!("wrote {path} ({} rows)", self.rows.len()),
            Err(e) => println!("could not write {path}: {e}"),
        }
    }
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    println!(
        "== fastdds benches: serving path{} ==",
        if quick { " (--quick)" } else { "" }
    );
    let mut report = Report { rows: Vec::new() };
    let (n_clients, reqs_per_client) = if quick { (4usize, 6usize) } else { (8, 25) };

    // --- blocking vs streaming throughput/latency over TCP ---------------
    let mut rng = Xoshiro256::seed_from_u64(23);
    let oracle = Arc::new(MarkovOracle::new(MarkovChain::generate(&mut rng, 6, 0.5), 16));
    let coord = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
    let srv = Server::start("127.0.0.1:0", coord).unwrap();
    let addr = srv.addr.to_string();

    for mode in ["blocking", "streaming"] {
        let started = Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|ci| {
                let addr = addr.clone();
                let streaming = mode == "streaming";
                std::thread::spawn(move || -> Vec<f64> {
                    let mut c = Client::connect(&addr).unwrap();
                    let mut lat = Vec::with_capacity(reqs_per_client);
                    for k in 0..reqs_per_client {
                        let spec = SamplingSpec::builder()
                            .solver(Solver::Trapezoidal { theta: 0.5 })
                            .nfe(32)
                            .n_samples(2)
                            .seed((ci * 1_000 + k) as u64)
                            .build()
                            .unwrap();
                        let t0 = Instant::now();
                        if streaming {
                            let out = c.generate_stream(&spec).unwrap();
                            assert_eq!(out.response.sequences.len(), 2);
                        } else {
                            let resp = c.generate_spec(&spec).unwrap();
                            assert_eq!(resp.sequences.len(), 2);
                        }
                        lat.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    lat
                })
            })
            .collect();
        let mut lats: Vec<f64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        let wall = started.elapsed().as_secs_f64().max(1e-9);
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        report.value(
            &format!("serve {mode} req-per-sec ({n_clients} clients)"),
            lats.len() as f64 / wall,
        );
        report.value(&format!("serve {mode} p50-ms"), percentile(&lats, 0.50));
        report.value(&format!("serve {mode} p99-ms"), percentile(&lats, 0.99));
    }
    srv.stop();

    // --- serving under injected lane panics ------------------------------
    // The robustness headline: the same workload with hash-deterministic
    // panics injected into ~1% of requests.  A 2-lane trapezoidal nfe=32
    // dispatch makes ~33 score calls, so a per-tick panic probability of
    // 3e-4 gives 1 - (1 - 3e-4)^33 ~ 1% per request.  Failed requests come
    // back typed (`lane_failed`); survivors and innocent co-batched
    // siblings complete, and throughput/p99 should stay within ~20% of the
    // clean rows above (the driver's regression gate).
    silence_injected_panics();
    let mut rng = Xoshiro256::seed_from_u64(23);
    let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 6, 0.5), 16);
    let faulty = Arc::new(FaultyScore::new(
        oracle,
        FaultPlan::new().random_panics(424_242, 3e-4),
    ));
    let coord = Coordinator::start_local(faulty, BatchPolicy::Greedy, 8);
    let srv = Server::start("127.0.0.1:0", coord).unwrap();
    let addr = srv.addr.to_string();
    let started = Instant::now();
    let handles: Vec<_> = (0..n_clients)
        .map(|ci| {
            let addr = addr.clone();
            std::thread::spawn(move || -> (Vec<f64>, usize) {
                let mut c = Client::connect(&addr).unwrap();
                let mut lat = Vec::with_capacity(reqs_per_client);
                let mut failed = 0usize;
                for k in 0..reqs_per_client {
                    let spec = SamplingSpec::builder()
                        .solver(Solver::Trapezoidal { theta: 0.5 })
                        .nfe(32)
                        .n_samples(2)
                        .seed((ci * 1_000 + k) as u64)
                        .build()
                        .unwrap();
                    let t0 = Instant::now();
                    match c.generate_spec(&spec) {
                        Ok(resp) => {
                            assert_eq!(resp.sequences.len(), 2);
                            lat.push(t0.elapsed().as_secs_f64() * 1e3);
                        }
                        Err(e) if e.to_string().contains("lane_failed") => {
                            failed += 1;
                        }
                        Err(e) => panic!("unexpected serve error: {e:#}"),
                    }
                }
                (lat, failed)
            })
        })
        .collect();
    let mut lats: Vec<f64> = Vec::new();
    let mut failed = 0usize;
    for h in handles {
        let (l, f) = h.join().unwrap();
        lats.extend(l);
        failed += f;
    }
    let wall = started.elapsed().as_secs_f64().max(1e-9);
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    report.value(
        &format!("serve faulty req-per-sec ({n_clients} clients)"),
        lats.len() as f64 / wall,
    );
    report.value("serve faulty p50-ms", percentile(&lats, 0.50));
    report.value("serve faulty p99-ms", percentile(&lats, 0.99));
    report.value("serve faulty failed-requests", failed as f64);
    srv.stop();

    // --- cancellation latency on a long exact run ------------------------
    // How long after the cancel verb does the partial response land?  The
    // contract is "within one uniformization window".  The cancel is
    // issued IMMEDIATELY after the accepted frame (64-dim exact jobs take
    // far longer than the accept round trip), so the measurement cannot
    // race job completion; if it ever does, the latency row records the
    // -1 sentinel instead of a silently meaningless value.
    let mut rng = Xoshiro256::seed_from_u64(29);
    let oracle = Arc::new(HmmUniformOracle::new(
        MarkovChain::generate(&mut rng, 6, 0.6),
        64,
    ));
    let coord = Coordinator::start_local(oracle, BatchPolicy::Greedy, 4);
    let srv = Server::start("127.0.0.1:0", coord).unwrap();
    let addr = srv.addr.to_string();
    let mut streaming = Client::connect(&addr).unwrap();
    let mut control = Client::connect(&addr).unwrap();
    let spec = SamplingSpec::builder()
        .solver(Solver::Exact)
        .n_samples(2)
        .seed(7)
        .build()
        .unwrap();
    let id = streaming.start_stream(&spec).unwrap();
    let t0 = Instant::now();
    let found = control.cancel(id).unwrap();
    let out = streaming.finish_stream(2).unwrap();
    let cancel_ms = t0.elapsed().as_secs_f64() * 1e3;
    let valid = found && out.response.partial;
    report.value(
        "serve cancel-to-partial-ms",
        if valid { cancel_ms } else { -1.0 },
    );
    report.value(
        "serve cancel found+partial (1=yes)",
        if valid { 1.0 } else { 0.0 },
    );
    srv.stop();

    // --- brownout ladder under sustained overload ------------------------
    // A 2-lane coordinator with a 4-lane queue cap is hammered by enough
    // concurrent clients to run well past 2x capacity.  With the ladder ON
    // the intake degrades expensive specs (uniform euler nfe=256 clamps to
    // the nfe floor at rung 3) instead of shedding them, so goodput-rps
    // (completed requests per second) should beat the ladder-OFF arm,
    // which can only shed typed `overloaded` once the queue fills.
    for ladder_on in [true, false] {
        let arm = if ladder_on { "ladder-on" } else { "ladder-off" };
        let mut rng = Xoshiro256::seed_from_u64(31);
        let oracle = Arc::new(MarkovOracle::new(MarkovChain::generate(&mut rng, 6, 0.5), 16));
        let mut cfg = CoordinatorCfg::default();
        cfg.queue_cap = Some(4);
        cfg.health.brownout = ladder_on;
        let coord = Coordinator::start_local_with_cfg(oracle, BatchPolicy::Greedy, 2, None, cfg);
        let srv = Server::start("127.0.0.1:0", coord).unwrap();
        let addr = srv.addr.to_string();
        let started = Instant::now();
        let handles: Vec<_> = (0..n_clients)
            .map(|ci| {
                let addr = addr.clone();
                std::thread::spawn(move || -> (Vec<f64>, usize) {
                    let mut c = Client::connect(&addr).unwrap();
                    let mut lat = Vec::with_capacity(reqs_per_client);
                    let mut shed = 0usize;
                    for k in 0..reqs_per_client {
                        let spec = SamplingSpec::builder()
                            .solver(Solver::Euler)
                            .nfe(256)
                            .n_samples(1)
                            .seed((ci * 1_000 + k) as u64)
                            .build()
                            .unwrap();
                        let t0 = Instant::now();
                        match c.generate_spec(&spec) {
                            Ok(resp) => {
                                assert_eq!(resp.sequences.len(), 1);
                                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                            }
                            Err(e) if e.to_string().contains("overloaded") => shed += 1,
                            Err(e) => panic!("unexpected serve error: {e:#}"),
                        }
                    }
                    (lat, shed)
                })
            })
            .collect();
        let mut lats: Vec<f64> = Vec::new();
        let mut shed = 0usize;
        for h in handles {
            let (l, s) = h.join().unwrap();
            lats.extend(l);
            shed += s;
        }
        let wall = started.elapsed().as_secs_f64().max(1e-9);
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        report.value(
            &format!("serve brownout {arm} goodput-rps"),
            lats.len() as f64 / wall,
        );
        report.value(
            &format!("serve brownout {arm} p99-ms"),
            percentile(&lats, 0.99),
        );
        report.value(&format!("serve brownout {arm} shed-requests"), shed as f64);
        srv.stop();
    }

    // --- stalled backend: watchdog on vs off -----------------------------
    // Hash-deterministic latency jitter freezes ~1% of score calls for
    // 300ms — long enough that one stalled eval parks the whole dispatch
    // loop.  With the watchdog ON the stalled eval is abandoned at the
    // cost-model-derived deadline and retried, so tail latency stays near
    // the watchdog floor; OFF, every stall is eaten in full and queued
    // requests inherit it, so p99 lands at 300ms+.
    for watchdog_on in [true, false] {
        let arm = if watchdog_on { "watchdog-on" } else { "watchdog-off" };
        let mut rng = Xoshiro256::seed_from_u64(37);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 6, 0.5), 16);
        let faulty = Arc::new(FaultyScore::new(oracle, FaultPlan::new()));
        let mut cfg = CoordinatorCfg::default();
        cfg.health.watchdog = watchdog_on;
        let coord = Coordinator::start_local_with_cfg(
            Arc::clone(&faulty),
            BatchPolicy::Greedy,
            4,
            None,
            cfg,
        );
        let srv = Server::start("127.0.0.1:0", coord).unwrap();
        let addr = srv.addr.to_string();
        // Warm the cost model on clean traffic first: a cold model has no
        // latency estimate, so the watchdog arm would run unbounded.
        {
            let mut c = Client::connect(&addr).unwrap();
            for k in 0..3u64 {
                let spec = SamplingSpec::builder()
                    .solver(Solver::Trapezoidal { theta: 0.5 })
                    .nfe(32)
                    .n_samples(1)
                    .seed(9_000 + k)
                    .build()
                    .unwrap();
                c.generate_spec(&spec).unwrap();
            }
        }
        faulty.set_plan(FaultPlan::new().flaky(515_151, 0.01, Duration::from_millis(300)));
        let handles: Vec<_> = (0..n_clients)
            .map(|ci| {
                let addr = addr.clone();
                std::thread::spawn(move || -> (Vec<f64>, usize) {
                    let mut c = Client::connect(&addr).unwrap();
                    let mut lat = Vec::with_capacity(reqs_per_client);
                    let mut failed = 0usize;
                    for k in 0..reqs_per_client {
                        let spec = SamplingSpec::builder()
                            .solver(Solver::Trapezoidal { theta: 0.5 })
                            .nfe(32)
                            .n_samples(1)
                            .seed((ci * 1_000 + k) as u64)
                            .build()
                            .unwrap();
                        let t0 = Instant::now();
                        match c.generate_spec(&spec) {
                            Ok(resp) => {
                                assert_eq!(resp.sequences.len(), 1);
                                lat.push(t0.elapsed().as_secs_f64() * 1e3);
                            }
                            // Exhausted retries / open breaker are typed and
                            // expected under heavy jitter; count, don't die.
                            Err(_) => failed += 1,
                        }
                    }
                    (lat, failed)
                })
            })
            .collect();
        let mut lats: Vec<f64> = Vec::new();
        let mut failed = 0usize;
        for h in handles {
            let (l, f) = h.join().unwrap();
            lats.extend(l);
            failed += f;
        }
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        report.value(&format!("serve stalled {arm} p99-ms"), percentile(&lats, 0.99));
        report.value(&format!("serve stalled {arm} failed-requests"), failed as f64);
        faulty.set_plan(FaultPlan::new());
        srv.stop();
    }

    report.write(quick);
}
