//! Coordinator/serving benchmarks (`cargo bench`): batching policies under
//! a workload trace, coordinator overhead vs raw runtime dispatch, and
//! end-to-end samples/s — the L3 §Perf numbers in EXPERIMENTS.md.

use std::time::Instant;

use fastdds::api::SamplingSpec;
use fastdds::bench::{bench, black_box};
use fastdds::coordinator::{BatchPolicy, Coordinator, GenerateRequest};
use fastdds::runtime::{Registry, RuntimeHandle, Value};
use fastdds::solvers::Solver;
use fastdds::util::rng::{Rng, Xoshiro256};

fn main() {
    println!("== fastdds benches: coordinator ==");
    if !fastdds::runtime::artifacts_available("artifacts") {
        println!("(skipped: run `make artifacts`)");
        return;
    }
    let runtime = RuntimeHandle::spawn("artifacts").unwrap();
    runtime
        .preload(&["markov_step_trapezoidal", "markov_step_tau", "markov_step_tweedie"])
        .unwrap();

    // --- raw runtime dispatch baseline ----------------------------------
    let (b, l) = (8usize, 32usize);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let mut u = vec![0.0f32; 2 * 2 * b * l];
    let raw = bench("raw pjrt trapezoidal step (batch 8)", 3, 40, || {
        rng.fill_f32(&mut u);
        black_box(
            runtime
                .execute(
                    "markov_step_trapezoidal",
                    vec![
                        Value::i32(vec![16; b * l], vec![b, l]),
                        Value::scalar_f32(0.9),
                        Value::scalar_f32(0.8),
                        Value::scalar_f32(0.5),
                        Value::f32(u.clone(), vec![2, 2, b, l]),
                    ],
                )
                .unwrap(),
        );
    });
    println!("{}", raw.report());

    // --- full coordinator request (16 steps -> 17 dispatches) -----------
    let registry = Registry::load("artifacts").unwrap();
    for (pname, policy) in [
        ("greedy", BatchPolicy::Greedy),
        ("timeout-5ms", BatchPolicy::Timeout(std::time::Duration::from_millis(5))),
    ] {
        let coord = Coordinator::start(runtime.clone(), registry.clone(), policy);
        let mut id = 0u64;
        let r = bench(
            &format!("coordinator request nfe=32 n=8 ({pname})"),
            2,
            15,
            || {
                id += 1;
                black_box(
                    coord
                        .generate(GenerateRequest::new(
                            id,
                            SamplingSpec::builder()
                                .family("markov")
                                .solver(Solver::Trapezoidal { theta: 0.5 })
                                .nfe(32)
                                .n_samples(8)
                                .seed(id)
                                .build()
                                .unwrap(),
                        ))
                        .unwrap(),
                );
            },
        );
        println!("{}  ({:.1} samples/s)", r.report(), r.items_per_sec(8.0));
        // Coordinator overhead vs raw dispatches: nfe=32 trap = 16 steps
        // (+1 possible finalize) => ~17 dispatches of the raw cost.
        let dispatch_cost = raw.mean_ns * 17.0;
        println!(
            "    overhead vs {:.0} ns of raw dispatches: {:.1}%",
            dispatch_cost,
            (r.mean_ns - dispatch_cost) / dispatch_cost * 100.0
        );
        coord.shutdown();
    }

    // --- concurrent-load throughput --------------------------------------
    let coord = Coordinator::start(
        runtime.clone(),
        registry.clone(),
        BatchPolicy::Timeout(std::time::Duration::from_millis(2)),
    );
    let started = Instant::now();
    let handles: Vec<_> = (0..32)
        .map(|i| {
            coord.submit(GenerateRequest::new(
                10_000 + i,
                SamplingSpec::builder()
                    .family("markov")
                    .solver(Solver::TauLeaping)
                    .nfe(32)
                    .n_samples(4)
                    .seed(i)
                    .build()
                    .unwrap(),
            ))
        })
        .collect();
    let mut n = 0usize;
    for h in handles {
        n += h.wait().unwrap().sequences.len();
    }
    let wall = started.elapsed().as_secs_f64();
    let m = coord.metrics();
    println!(
        "concurrent load: {n} samples in {wall:.2}s = {:.1} samples/s; {}",
        n as f64 / wall,
        m.report()
    );
    coord.shutdown();
}
