//! Wire-protocol compatibility + round-trip suite.
//!
//! 1. **v1 golden corpus** — one legacy flat request per historical knob
//!    combination, replayed over TCP through the v2 upgrade shim.  Every
//!    response must be *bit-identical in every deterministic field*
//!    (sequences, nfe_used, echo fields, key set) to what the
//!    pre-redesign server produced — pinned here by re-deriving the
//!    expected sequences from the documented serving semantics (lane
//!    seeding stride, fixed/tuned/adaptive grid construction, per-lane
//!    solver streams), which the pre-redesign tests proved equal to the
//!    server's output.  Only `latency_ms` (timing) and `id` (allocation
//!    order) are non-deterministic, and they are checked for presence and
//!    type instead.
//!
//! 2. **v2 equivalence** — each corpus entry re-sent as a structured v2
//!    spec must produce the same sequences, proving the shim and the
//!    native path share one execution.
//!
//! 3. **Spec fuzz round-trip** — randomized valid specs survive
//!    spec → JSON text → spec bit-exactly.
//!
//! 4. **u64 identity fields** — seeds above 2^53 serve losslessly (the
//!    old `f64` path silently corrupted them).

use std::sync::Arc;

use fastdds::api::{wire, SamplingSpec};
use fastdds::coordinator::{BatchPolicy, Coordinator};
use fastdds::schedule::adaptive::{AdaptiveController, NfeBudget, StepController};
use fastdds::schedule::{ScheduleSpec, ScheduleTuner};
use fastdds::score::hmm::HmmUniformOracle;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::score::{ScoreSource, Tok};
use fastdds::server::client::Client;
use fastdds::server::Server;
use fastdds::solvers::{grid, masked, Solver};
use fastdds::testkit::{check, Gen};
use fastdds::util::json::Json;
use fastdds::util::rng::Xoshiro256;

const DELTA: f64 = 1e-3;
const LANE_STRIDE: u64 = 0x9E3779B97F4A7C15;

fn markov_oracle() -> MarkovOracle {
    let mut rng = Xoshiro256::seed_from_u64(23);
    MarkovOracle::new(MarkovChain::generate(&mut rng, 6, 0.5), 16)
}

fn hmm_oracle() -> HmmUniformOracle {
    let mut rng = Xoshiro256::seed_from_u64(29);
    HmmUniformOracle::new(MarkovChain::generate(&mut rng, 5, 0.6), 12)
}

fn lane_seeds(seed: u64, n: usize) -> Vec<u64> {
    (0..n)
        .map(|i| seed.wrapping_add((i as u64).wrapping_mul(LANE_STRIDE)))
        .collect()
}

/// One corpus entry: the raw v1 line (minus cmd), its expected per-lane
/// sequences + nfe, and the echo fields the response must carry.
struct Entry {
    name: &'static str,
    v1_body: String,
    expected: Vec<(Vec<Tok>, usize)>,
    /// (key, exact expected value) pairs beyond the base response shape.
    echo: Vec<(&'static str, Json)>,
}

/// Pre-redesign serving semantics, re-derived: fixed grids run
/// `masked::generate` per lane over `steps_for_nfe(min(nfe, budget-1))`
/// steps; nfe_used is the max across lanes (the assembler's rule).
fn expect_fixed(
    oracle: &MarkovOracle,
    solver: Solver,
    grid_ts: &[f64],
    seed: u64,
    n: usize,
) -> Vec<(Vec<Tok>, usize)> {
    lane_seeds(seed, n)
        .into_iter()
        .map(|s| {
            let mut rng = Xoshiro256::seed_from_u64(s);
            let (toks, stats) = masked::generate(oracle, solver, grid_ts, &mut rng);
            (toks, stats.nfe)
        })
        .collect()
}

fn corpus(oracle: &MarkovOracle) -> Vec<Entry> {
    let mut entries = Vec::new();
    let uniform16 = grid::masked_uniform(16, DELTA);

    // --- one-stage schemes, uniform grid, the original PR-1 surface -----
    for (name, solver) in [
        ("euler", Solver::Euler),
        ("tau", Solver::TauLeaping),
        ("tweedie", Solver::Tweedie),
        ("parallel", Solver::ParallelDecoding),
    ] {
        entries.push(Entry {
            name,
            v1_body: format!(r#""solver": "{name}", "nfe": 16, "n_samples": 2, "seed": 11"#),
            expected: expect_fixed(oracle, solver, &uniform16, 11, 2),
            echo: vec![("schedule", Json::from("uniform"))],
        });
    }

    // --- two-stage θ-schemes --------------------------------------------
    let trap = Solver::Trapezoidal { theta: 0.5 };
    entries.push(Entry {
        name: "trapezoidal-uniform",
        v1_body: r#""solver": "trapezoidal:0.5", "nfe": 32, "n_samples": 3, "seed": 7"#.into(),
        expected: expect_fixed(oracle, trap, &grid::masked_uniform(16, DELTA), 7, 3),
        echo: vec![("schedule", Json::from("uniform"))],
    });

    // --- PR-2 surface: log schedule, budget, adaptive, tuned ------------
    let rk2 = Solver::Rk2 { theta: 0.3 };
    entries.push(Entry {
        name: "rk2-log",
        v1_body: r#""solver": "rk2:0.3", "nfe": 32, "n_samples": 2, "seed": 5, "schedule": "log""#
            .into(),
        expected: expect_fixed(oracle, rk2, &grid::masked_log(16, DELTA), 5, 2),
        echo: vec![("schedule", Json::from("log"))],
    });

    entries.push(Entry {
        name: "trapezoidal-budgeted",
        v1_body: r#""solver": "trapezoidal:0.5", "nfe": 64, "n_samples": 2, "seed": 3,
                     "nfe_budget": 33"#
            .into(),
        // Budget folds into the step count: min(64, 32) NFE = 16 steps.
        expected: expect_fixed(oracle, trap, &grid::masked_uniform(16, DELTA), 3, 2),
        echo: vec![
            ("schedule", Json::from("uniform")),
            ("nfe_budget", Json::from(33usize)),
        ],
    });

    // Adaptive: lanes of the (single) request vote on one shared dt; the
    // pre-redesign scheduler seeded dt0 from (1-δ)/steps_for_nfe(nfe).
    {
        let (nfe, tol, budget, seed, n) = (64usize, 1e-3f64, 24usize, 9u64, 2usize);
        let dt0 = (1.0 - DELTA) / trap.steps_for_nfe(nfe) as f64;
        let ctl = StepController::new(AdaptiveController::for_span(tol, 1.0, DELTA), dt0)
            .with_budget(NfeBudget { total: budget, nfe_per_step: 2, reserve: 1 });
        let results =
            masked::generate_batch_adaptive(oracle, trap, ctl, DELTA, &lane_seeds(seed, n)).0;
        entries.push(Entry {
            name: "trapezoidal-adaptive-budgeted",
            v1_body: format!(
                r#""solver": "trapezoidal:0.5", "nfe": {nfe}, "n_samples": {n},
                   "seed": {seed}, "schedule": "adaptive:tol=0.001", "nfe_budget": {budget}"#
            ),
            expected: results.into_iter().map(|(t, s)| (t, s.nfe)).collect(),
            echo: vec![
                ("schedule", Json::from("adaptive:tol=0.001")),
                ("nfe_budget", Json::from(budget)),
            ],
        });
    }

    // Tuned: the serving-time fit (2 pilots, tol 1e-3) on a fresh cache,
    // then the fixed-grid run over the fitted grid.
    {
        let steps = 8usize;
        let tuned = ScheduleTuner { pilots: 2, tol: 1e-3, ..Default::default() }
            .fit_masked(oracle, trap, steps, DELTA, "markov");
        let results = masked::generate_batch(oracle, trap, &tuned.grid, &lane_seeds(13, 2));
        entries.push(Entry {
            name: "trapezoidal-tuned",
            v1_body: r#""solver": "trapezoidal:0.5", "nfe": 16, "n_samples": 2, "seed": 13,
                         "schedule": "tuned:steps=8""#
                .into(),
            expected: results.into_iter().map(|(t, s)| (t, s.nfe)).collect(),
            echo: vec![("schedule", Json::from("tuned:steps=8"))],
        });
    }

    // --- PR-3 surface: exact simulation (FHS on the markov family) ------
    {
        let results: Vec<(Vec<Tok>, usize)> = lane_seeds(21, 2)
            .into_iter()
            .map(|s| {
                let mut rng = Xoshiro256::seed_from_u64(s);
                let (toks, stats, _) = masked::fhs_generate(oracle, DELTA, &mut rng);
                (toks, stats.nfe)
            })
            .collect();
        entries.push(Entry {
            name: "exact-fhs",
            v1_body: r#""solver": "exact", "nfe": 16, "n_samples": 2, "seed": 21"#.into(),
            expected: results,
            echo: vec![("schedule", Json::from("uniform"))],
        });
    }

    entries
}

/// Field-for-field check of a v1 response against the expected lanes and
/// the exact legacy key set.
fn assert_v1_response(name: &str, r: &Json, expected: &[(Vec<Tok>, usize)], echo: &[(&str, Json)]) {
    assert_eq!(r.get("ok").unwrap().as_bool().unwrap(), true, "{name}: {r:?}");
    let seqs = r.get("sequences").unwrap().as_arr().unwrap();
    assert_eq!(seqs.len(), expected.len(), "{name}: lane count");
    for (k, (want, _)) in expected.iter().enumerate() {
        let got: Vec<Tok> = seqs[k]
            .as_arr()
            .unwrap()
            .iter()
            .map(|t| t.as_f64().unwrap() as Tok)
            .collect();
        assert_eq!(&got, want, "{name}: lane {k} sequence must be bit-identical");
    }
    let want_nfe = expected.iter().map(|(_, n)| *n).max().unwrap();
    assert_eq!(
        r.get("nfe_used").unwrap().as_usize().unwrap(),
        want_nfe,
        "{name}: nfe_used"
    );
    for (key, want) in echo {
        assert_eq!(r.get(key).unwrap(), want, "{name}: echo field {key}");
    }
    // Non-deterministic fields: present + typed.
    assert!(r.get("latency_ms").unwrap().as_f64().is_ok(), "{name}");
    assert!(r.get("id").unwrap().as_u64().is_ok(), "{name}");
    // EXACT legacy key set: base response + ok + schedule echo + the
    // optional echoes this entry carries — nothing else (no v2 leakage).
    if let Json::Obj(m) = r {
        let mut want_keys: Vec<String> = vec![
            "id".into(),
            "latency_ms".into(),
            "nfe_used".into(),
            "ok".into(),
            "sequences".into(),
        ];
        for (k, _) in echo {
            want_keys.push((*k).to_string());
        }
        want_keys.sort();
        let got_keys: Vec<String> = m.keys().cloned().collect();
        assert_eq!(got_keys, want_keys, "{name}: v1 response key set drifted");
    } else {
        panic!("{name}: response not an object");
    }
}

#[test]
fn v1_compat_corpus_replays_bit_identical() {
    let oracle = markov_oracle();
    let entries = corpus(&oracle);
    let coord = Coordinator::start_local(Arc::new(markov_oracle()), BatchPolicy::Greedy, 8);
    let srv = Server::start("127.0.0.1:0", coord).unwrap();
    let mut c = Client::connect(&srv.addr.to_string()).unwrap();
    for e in &entries {
        let line = format!(r#"{{"cmd": "generate", {}}}"#, e.v1_body);
        let r = c.raw(&line).unwrap();
        assert_v1_response(e.name, &r, &e.expected, &e.echo);
    }

    // The same requests through the v2 envelope produce the same samples:
    // the upgrade shim and the native path share one execution.
    for e in &entries {
        let flat = Json::parse(&format!("{{{}}}", e.v1_body)).unwrap();
        let spec = wire::request_from_json(&flat).unwrap().spec;
        let resp = c.generate_spec(&spec).unwrap();
        for (k, (want, _)) in e.expected.iter().enumerate() {
            assert_eq!(&resp.sequences[k], want, "{}: v2 lane {k} diverged", e.name);
        }
        let want_nfe = e.expected.iter().map(|(_, n)| *n).max().unwrap();
        assert_eq!(resp.nfe_used, want_nfe, "{}: v2 nfe_used", e.name);
    }
    srv.stop();
}

#[test]
fn v1_exact_knobs_replay_on_hmm_family() {
    // The PR-4 surface: exact + window_ratio/slack on the uniform-state
    // oracle — expected lanes re-derived from the per-lane simulator.
    let oracle = hmm_oracle();
    let cfg = fastdds::ctmc::uniformization::ExactCfg { window_ratio: 0.6, slack: 3.0 };
    let expected: Vec<(Vec<Tok>, usize)> = lane_seeds(9, 2)
        .into_iter()
        .map(|s| {
            let mut rng = Xoshiro256::seed_from_u64(s);
            let (toks, stats) = oracle.exact_uniform(DELTA, &cfg, &mut rng).unwrap();
            (toks, stats.nfe)
        })
        .collect();
    let coord = Coordinator::start_local(Arc::new(hmm_oracle()), BatchPolicy::Greedy, 8);
    let srv = Server::start("127.0.0.1:0", coord).unwrap();
    let mut c = Client::connect(&srv.addr.to_string()).unwrap();
    let r = c
        .raw(
            r#"{"cmd": "generate", "solver": "exact", "nfe": 16,
                "window_ratio": 0.6, "slack": 3.0, "n_samples": 2, "seed": 9}"#,
        )
        .unwrap();
    assert_v1_response(
        "exact-hmm-knobs",
        &r,
        &expected,
        &[
            ("schedule", Json::from("uniform")),
            ("slack", Json::Num(3.0)),
            ("window_ratio", Json::Num(0.6)),
        ],
    );
    srv.stop();
}

#[test]
fn spec_fuzz_round_trips_bit_exact() {
    check("spec_wire_roundtrip", 200, |g| {
        let families = ["markov", "toy", "transformer"];
        let spec = if g.bool(0.3) {
            // Exact spec: random knobs respecting the builder's floors.
            let wr = g.f64_in(0.3, 0.95);
            let slack = g.f64_in(1.5 / wr + 0.1, 12.0);
            let mut b = SamplingSpec::builder()
                .family(*g.choose(&families))
                .n_samples(g.usize_in(1, 8))
                .seed(g.usize_in(0, 1 << 30) as u64)
                .solver(Solver::Exact)
                .window_ratio(Some(wr))
                .slack(Some(slack));
            if g.bool(0.5) {
                b = b.max_events(Some(g.usize_in(1, 10_000)));
            }
            b.build().expect("valid exact spec")
        } else {
            let solver = match g.usize_in(0, 5) {
                0 => Solver::Euler,
                1 => Solver::TauLeaping,
                2 => Solver::Tweedie,
                3 => Solver::Trapezoidal { theta: g.f64_in(0.05, 0.95) },
                4 => Solver::Rk2 { theta: g.f64_in(0.05, 0.5) },
                _ => Solver::ParallelDecoding,
            };
            let two_stage = solver.nfe_per_step() == 2;
            let schedule = match g.usize_in(0, if two_stage { 3 } else { 1 }) {
                0 => ScheduleSpec::Uniform,
                1 => ScheduleSpec::Log,
                2 => ScheduleSpec::Adaptive { tol: g.f64_in(1e-6, 1e-1) },
                _ => ScheduleSpec::Tuned { steps: g.usize_in(0, 64) },
            };
            let nfe = g.usize_in(2, 256);
            let mut b = SamplingSpec::builder()
                .family(*g.choose(&families))
                .n_samples(g.usize_in(1, 8))
                .seed(g.usize_in(0, 1 << 30) as u64)
                .solver(solver)
                .nfe(nfe)
                .schedule(schedule);
            if g.bool(0.4) {
                b = b.nfe_budget(Some(g.usize_in(3, 512)));
            }
            b.build().expect("valid scheme spec")
        };
        // Through the structured object AND through wire text.
        let j = wire::spec_to_json(&spec);
        let back = wire::spec_from_json(&j).map_err(|e| format!("{e}"))?;
        fastdds::prop_assert!(back == spec, "object round-trip diverged: {j:?}");
        let text = j.to_string();
        let re = Json::parse(&text).map_err(|e| format!("{e:#}"))?;
        let back = wire::spec_from_json(&re).map_err(|e| format!("{e}"))?;
        fastdds::prop_assert!(back == spec, "text round-trip diverged: {text}");
        Ok(())
    });
}

#[test]
fn u64_seed_serves_losslessly_above_2_53() {
    // Two seeds that collide under f64 rounding must produce DIFFERENT
    // samples (the pre-redesign parse collapsed them).
    let big = (1u64 << 53) + 1;
    let coord = Coordinator::start_local(Arc::new(markov_oracle()), BatchPolicy::Greedy, 8);
    let srv = Server::start("127.0.0.1:0", coord).unwrap();
    let mut c = Client::connect(&srv.addr.to_string()).unwrap();
    let r1 = c
        .raw(&format!(
            r#"{{"cmd": "generate", "solver": "tau", "nfe": 16, "seed": {}}}"#,
            big
        ))
        .unwrap();
    let r2 = c
        .raw(&format!(
            r#"{{"cmd": "generate", "solver": "tau", "nfe": 16, "seed": {}}}"#,
            big - 1 // rounds to the same f64
        ))
        .unwrap();
    assert_eq!((big as f64) as u64, ((big - 1) as f64) as u64, "premise");
    let s1 = r1.get("sequences").unwrap().to_string();
    let s2 = r2.get("sequences").unwrap().to_string();
    assert_ne!(s1, s2, "adjacent >2^53 seeds must not collide anymore");
    // And the exact seed drives the documented lane stream.
    let mut rng = Xoshiro256::seed_from_u64(big);
    let (want, _) = masked::generate(
        &markov_oracle(),
        Solver::TauLeaping,
        &grid::masked_uniform(16, DELTA),
        &mut rng,
    );
    let got: Vec<Tok> = r1.get("sequences").unwrap().as_arr().unwrap()[0]
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as Tok)
        .collect();
    assert_eq!(got, want, "big seed must drive the exact u64 lane stream");
    srv.stop();
}
