//! Artifact-registry integration suite: the content-addressed store
//! driven end-to-end over TCP.
//!
//! Three guarantees, each proven against a real server + client:
//!
//!   1. **Round trip** — `registry_put` → `registry_list` →
//!      `registry_stat` → `registry_get` returns the manifest and every
//!      blob bit-identical, with content addressing deduplicating a
//!      repeated put to the same digest.
//!   2. **Integrity** — a bit-flipped blob on disk answers a typed
//!      `integrity_failure` and leaks nothing: no partial bytes, no
//!      mutated manifests, healthy artifacts keep serving on the same
//!      connection, and only the failure counter moves.
//!   3. **Digest-pulled schedules** — a second coordinator sharing the
//!      registry directory serves bit-identical samples from the first
//!      coordinator's published tuned grid without ever running the
//!      tuner (the pull satisfies the cache miss; the fit closure is a
//!      panic).

use std::sync::Arc;

use fastdds::api::SamplingSpec;
use fastdds::coordinator::{BatchPolicy, Coordinator, CoordinatorCfg};
use fastdds::registry::{ArtifactKind, ArtifactRegistry, ManifestV1};
use fastdds::schedule::{ScheduleCache, ScheduleSpec, TuneKey};
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::server::client::Client;
use fastdds::server::Server;
use fastdds::solvers::Solver;
use fastdds::util::rng::Xoshiro256;

const VOCAB: usize = 6;
const SEQ_LEN: usize = 14;

fn temp_root(tag: &str) -> String {
    let root = std::env::temp_dir()
        .join(format!("fastdds_it_registry_{}_{tag}", std::process::id()));
    let root = root.to_str().unwrap().to_string();
    let _ = std::fs::remove_dir_all(&root);
    root
}

fn oracle() -> Arc<MarkovOracle> {
    let mut rng = Xoshiro256::seed_from_u64(23);
    Arc::new(MarkovOracle::new(MarkovChain::generate(&mut rng, VOCAB, 0.5), SEQ_LEN))
}

/// A local-oracle server with the registry attached (the `serve
/// --registry-dir` wiring) plus a handle on the same registry.
fn registry_server(
    root: &str,
    schedule_dir: Option<&str>,
) -> (Server, Arc<ArtifactRegistry>) {
    let reg = ArtifactRegistry::open(root).unwrap();
    let coordinator = Coordinator::start_local_with_registry(
        oracle(),
        BatchPolicy::Greedy,
        8,
        schedule_dir,
        CoordinatorCfg::default(),
        Some(Arc::clone(&reg)),
    );
    let srv = Server::start("127.0.0.1:0", coordinator).unwrap();
    (srv, reg)
}

fn corpus_manifest(name: &str) -> ManifestV1 {
    let mut m = ManifestV1::new(ArtifactKind::CompatCorpus, name);
    m.family = "markov".into();
    m.vocab = VOCAB;
    m.seq_len = SEQ_LEN;
    m.created_by = "registry-it".into();
    m
}

// ===========================================================================
// 1. Full verb round trip, bit-identical content
// ===========================================================================

#[test]
fn put_list_stat_get_roundtrip_bit_identical() {
    let root = temp_root("roundtrip");
    let (srv, _reg) = registry_server(&root, None);
    let mut c = Client::connect(&srv.addr.to_string()).unwrap();

    // One textual blob and one spanning every byte value — hex transport
    // must be 8-bit clean.
    let text = b"{\"corpus\": \"v1-replay\"}".to_vec();
    let binary: Vec<u8> = (0..=255u8).cycle().take(1024).collect();
    let blobs = vec![text.clone(), binary.clone()];

    let digest = c.registry_put(&corpus_manifest("wire-replay"), &blobs).unwrap();
    assert_eq!(digest.len(), 64, "digest must be 64 hex chars: {digest}");

    // Content addressing: the identical put lands on the identical digest.
    let again = c.registry_put(&corpus_manifest("wire-replay"), &blobs).unwrap();
    assert_eq!(again, digest, "same content must address the same artifact");

    // list: present unfiltered and under its own kind/family, absent
    // under a foreign kind filter.
    let all = c.registry_list(None, None).unwrap();
    assert_eq!(all.len(), 1);
    assert_eq!(all[0].0, digest);
    let filtered = c
        .registry_list(Some(ArtifactKind::CompatCorpus), Some("markov"))
        .unwrap();
    assert_eq!(filtered.len(), 1);
    assert!(c
        .registry_list(Some(ArtifactKind::ScoreModel), None)
        .unwrap()
        .is_empty());

    // stat: manifest coordinates plus per-blob sizes, no content.
    let (stat_m, stat_blobs) = c.registry_stat(&digest).unwrap();
    let v1 = stat_m.v1();
    assert_eq!(v1.kind, ArtifactKind::CompatCorpus);
    assert_eq!(v1.name, "wire-replay");
    assert_eq!((v1.vocab, v1.seq_len), (VOCAB, SEQ_LEN));
    assert_eq!(stat_blobs.len(), 2);
    assert_eq!(stat_blobs[0].1, Some(text.len() as u64));
    assert_eq!(stat_blobs[1].1, Some(binary.len() as u64));

    // get: bit-identical blobs in order, same manifest.
    let (got_m, got_blobs) = c.registry_get(&digest).unwrap();
    assert_eq!(got_m, stat_m);
    assert_eq!(got_blobs, blobs, "round trip must be bit-identical");

    srv.stop();
    let _ = std::fs::remove_dir_all(&root);
}

// ===========================================================================
// 2. Corruption chaos: typed failure, zero leaked state
// ===========================================================================

#[test]
fn corrupted_blob_fails_typed_with_zero_leaked_state() {
    let root = temp_root("corrupt");
    let (srv, reg) = registry_server(&root, None);
    let mut c = Client::connect(&srv.addr.to_string()).unwrap();

    let doomed_blob = b"soon to be bit-flipped".to_vec();
    let healthy_blob = b"unharmed bystander bytes".to_vec();
    let doomed = c
        .registry_put(&corpus_manifest("doomed"), &[doomed_blob.clone()])
        .unwrap();
    let healthy = c
        .registry_put(&corpus_manifest("healthy"), &[healthy_blob.clone()])
        .unwrap();

    // Flip one bit of the doomed artifact's content blob on disk.
    let (_, stat_blobs) = c.registry_stat(&doomed).unwrap();
    let blob_path = format!("{root}/blobs/{}", stat_blobs[0].0);
    let mut bytes = std::fs::read(&blob_path).unwrap();
    bytes[5] ^= 0x01;
    std::fs::write(&blob_path, &bytes).unwrap();

    // Every fetch fails typed — repeatedly, with no partial content ever
    // cached or served.
    for round in 0..3 {
        let err = c.registry_get(&doomed).unwrap_err();
        assert!(
            err.to_string().contains("[integrity_failure]"),
            "round {round}: {err:#}"
        );
    }

    // Zero leaked state: both manifests still listed, the healthy
    // artifact still serves bit-identical on the SAME connection, and the
    // store gauges are untouched (corruption is detected, not deleted).
    assert_eq!(c.registry_list(None, None).unwrap().len(), 2);
    let (_, got) = c.registry_get(&healthy).unwrap();
    assert_eq!(got, vec![healthy_blob], "bystander artifact corrupted");
    let s = reg.stats();
    assert_eq!(s.integrity_failures, 3, "one count per failed fetch");
    assert_eq!(s.manifests, 2, "manifests must survive a blob corruption");
    assert_eq!(s.blobs, 2, "detection must not delete blobs");

    // The counters also surface in the serving ledger over the wire.
    let stats = c.stats().unwrap();
    assert_eq!(
        stats.get("registry_integrity_failures").unwrap().as_u64().unwrap(),
        3
    );
    assert_eq!(stats.get("registry_blobs").unwrap().as_u64().unwrap(), 2);

    srv.stop();
    let _ = std::fs::remove_dir_all(&root);
}

// ===========================================================================
// 3. Two coordinators, one registry: pull beats re-fit, bit-identically
// ===========================================================================

#[test]
fn digest_pulled_schedule_is_bit_identical_across_coordinators() {
    let root = temp_root("shared");
    let dir_a = temp_root("sched_a");
    let dir_b = temp_root("sched_b");

    let solver = Solver::Trapezoidal { theta: 0.5 };
    let spec = SamplingSpec::builder()
        .solver(solver)
        .nfe(16)
        .n_samples(2)
        .seed(77)
        .schedule(ScheduleSpec::Tuned { steps: 8 })
        .build()
        .unwrap();

    // Node A: cold everywhere — fits the tuned grid, publishes it.
    let (srv_a, reg_a) = registry_server(&root, Some(dir_a.as_str()));
    let mut ca = Client::connect(&srv_a.addr.to_string()).unwrap();
    let resp_a = ca.generate_spec(&spec).unwrap();
    assert_eq!(reg_a.stats().puts, 1, "node A must publish its fit");
    srv_a.stop();
    drop(reg_a);

    // Node B: different schedule dir, fresh process-equivalent, same
    // registry root.  Its cache miss is satisfied by the digest pull, and
    // the samples must be bit-identical to node A's.
    let (srv_b, reg_b) = registry_server(&root, Some(dir_b.as_str()));
    let mut cb = Client::connect(&srv_b.addr.to_string()).unwrap();
    let resp_b = cb.generate_spec(&spec).unwrap();
    assert_eq!(
        resp_b.sequences, resp_a.sequences,
        "digest-pulled schedule must reproduce node A bit-identically"
    );
    assert_eq!(resp_b.nfe_used, resp_a.nfe_used);
    assert_eq!(reg_b.stats().puts, 0, "node B must pull, never re-publish");

    // Direct proof the tuner cannot have run on the pull path: the same
    // miss against the shared registry with a panicking fit closure.
    let key = TuneKey::new("markov", VOCAB, SEQ_LEN, solver, 8);
    let mut probe = ScheduleCache::with_store(None, Some(Arc::clone(&reg_b)));
    let pulled = probe.get_or_fit(key, || panic!("pull path must not run the tuner"));
    assert_eq!(pulled.steps(), 8);

    srv_b.stop();
    for d in [&root, &dir_a, &dir_b] {
        let _ = std::fs::remove_dir_all(d);
    }
}
