//! Property tests for the schedule/ subsystem (ISSUE 2 checklist):
//!
//! (a) the adaptive controller with tol → 0 and pinned step bounds
//!     reproduces the fixed-grid θ-trapezoidal output bit for bit;
//! (b) NFE-budgeted runs never exceed their budget;
//! (c) `generate_batch` under a shared adaptive schedule stays
//!     bit-identical to per-lane `generate` over the realized grid.

use fastdds::ctmc::ToyModel;
use fastdds::prop_assert;
use fastdds::schedule::adaptive::{AdaptiveController, NfeBudget, StepController};
use fastdds::schedule::grid;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::{masked, toy, Solver};
use fastdds::testkit::{check, Gen};
use fastdds::util::rng::Xoshiro256;

fn theta_solver(g: &mut Gen) -> Solver {
    if g.bool(0.5) {
        Solver::Trapezoidal { theta: g.f64_in(0.1, 0.9) }
    } else {
        Solver::Rk2 { theta: g.f64_in(0.1, 1.0) }
    }
}

fn oracle(vocab: usize, seq_len: usize, seed: u64) -> MarkovOracle {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    MarkovOracle::new(MarkovChain::generate(&mut rng, vocab, 0.5), seq_len)
}

#[test]
fn prop_zero_tol_pinned_bounds_is_fixed_uniform_grid_bitwise() {
    // (a): tol = 0 forces maximal shrink, min_dt = max_dt = h pins every
    // step to h, and h is an exact binary fraction so the realized times
    // coincide bit for bit with grid::masked_uniform's 1 - h*i.
    let o = oracle(6, 16, 11);
    check("zero_tol_fixed_grid", 20, |g| {
        let solver = theta_solver(g);
        // h = 2^-k: steps = (1 - delta)/h with delta = 0.5 -> 2^(k-1) steps.
        let k = g.usize_in(3, 5);
        let h = (2.0f64).powi(-(k as i32));
        let delta = 0.5;
        let steps = ((1.0 - delta) / h).round() as usize;
        let cfg = AdaptiveController::for_span(0.0, 1.0, delta).with_bounds(h, h);
        let ctl = StepController::new(cfg, h);
        let seed = g.usize_in(0, 1 << 20) as u64;

        let mut ra = Xoshiro256::seed_from_u64(seed);
        let (toks_a, stats_a, trace) =
            masked::generate_adaptive(&o, solver, ctl, delta, &mut ra);
        let fixed = grid::masked_uniform(steps, delta);
        let mut rf = Xoshiro256::seed_from_u64(seed);
        let (toks_f, stats_f) = masked::generate(&o, solver, &fixed, &mut rf);

        prop_assert!(toks_a == toks_f, "tokens diverged for {}", solver.name());
        prop_assert!(
            stats_a.nfe == stats_f.nfe,
            "nfe diverged: {} vs {}",
            stats_a.nfe,
            stats_f.nfe
        );
        // The realized grid is the uniform grid (prefix, if a lane finished
        // early and the adaptive loop stopped stepping).
        prop_assert!(trace.grid.len() <= fixed.len(), "too many steps");
        for (i, (&a, &f)) in trace.grid.iter().zip(&fixed).enumerate() {
            prop_assert!(a == f, "time {i} diverged: {a} vs {f}");
        }
        Ok(())
    });
}

#[test]
fn prop_budgeted_runs_never_exceed_budget() {
    // (b): whatever the tolerance, solver, and budget, spend <= budget —
    // single lane, batch lanes, and the toy family.
    let o = oracle(5, 14, 23);
    let mut mrng = Xoshiro256::seed_from_u64(7);
    let model = ToyModel::paper_default(&mut mrng);
    check("nfe_budget_hard_cap", 30, |g| {
        let solver = theta_solver(g);
        let tol = *g.choose(&[0.0, 1e-4, 1e-2, 1.0]);
        let budget = g.usize_in(3, 40);
        let seed = g.usize_in(0, 1 << 20) as u64;

        let cfg = AdaptiveController::for_span(tol, 1.0, 1e-3);
        let ctl = StepController::new(cfg, 0.1).with_budget(NfeBudget {
            total: budget,
            nfe_per_step: solver.nfe_per_step(),
            reserve: 1,
        });
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let (toks, stats, _) =
            masked::generate_adaptive(&o, solver, ctl.clone(), 1e-3, &mut rng);
        prop_assert!(
            stats.nfe <= budget,
            "single lane overdrew: {} > {budget} ({})",
            stats.nfe,
            solver.name()
        );
        prop_assert!(toks.iter().all(|&t| t < 5), "masks left");

        let seeds: Vec<u64> = (0..g.usize_in(1, 4)).map(|i| seed ^ (i as u64)).collect();
        let (lanes, _) =
            masked::generate_batch_adaptive(&o, solver, ctl.clone(), 1e-3, &seeds);
        for (b, (toks, stats)) in lanes.iter().enumerate() {
            prop_assert!(
                stats.nfe <= budget,
                "batch lane {b} overdrew: {} > {budget}",
                stats.nfe
            );
            prop_assert!(toks.iter().all(|&t| t < 5), "batch lane {b} masks left");
        }

        // Toy family: no terminal denoise, reserve 0.
        let toy_cfg = AdaptiveController::for_span(tol, model.horizon, 1e-3);
        let toy_ctl = StepController::new(toy_cfg, 0.5).with_budget(NfeBudget {
            total: budget,
            nfe_per_step: 2,
            reserve: 0,
        });
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let (x, stats, _) = toy::generate_adaptive(&model, solver, toy_ctl, 1e-3, &mut rng);
        prop_assert!(x < model.n_states(), "bad toy state");
        prop_assert!(stats.nfe <= budget, "toy overdrew: {} > {budget}", stats.nfe);
        Ok(())
    });
}

#[test]
fn prop_batch_adaptive_bit_identical_to_per_lane_replay() {
    // (c): lanes stepping a shared adaptive schedule in lock-step are
    // bit-identical to independent per-lane generate calls over the
    // realized grid, and a 1-lane batch realizes the single-lane schedule.
    let o = oracle(5, 18, 31);
    check("batch_adaptive_equivalence", 20, |g| {
        let solver = theta_solver(g);
        let tol = *g.choose(&[1e-4, 1e-3, 1e-2]);
        let b = g.usize_in(1, 5);
        let seeds: Vec<u64> = (0..b).map(|_| g.usize_in(0, 1 << 20) as u64).collect();
        let cfg = AdaptiveController::for_span(tol, 1.0, 1e-3);
        let dt0 = g.f64_in(0.01, 0.2);
        let ctl = StepController::new(cfg, dt0);

        let (lanes, trace) =
            masked::generate_batch_adaptive(&o, solver, ctl.clone(), 1e-3, &seeds);
        prop_assert!(lanes.len() == b, "lane count");
        prop_assert!(grid::is_valid_grid(&trace.grid), "invalid realized grid");
        for (i, ((toks, stats), &seed)) in lanes.iter().zip(&seeds).enumerate() {
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let (want, wstats) = masked::generate(&o, solver, &trace.grid, &mut rng);
            prop_assert!(toks == &want, "lane {i} tokens diverged ({})", solver.name());
            prop_assert!(
                stats.nfe == wstats.nfe && stats.steps == wstats.steps,
                "lane {i} stats diverged: ({}, {}) vs ({}, {})",
                stats.nfe,
                stats.steps,
                wstats.nfe,
                wstats.steps
            );
        }

        // Single lane: batch vote == single-lane controller, same schedule.
        let mut rng = Xoshiro256::seed_from_u64(seeds[0]);
        let (stoks, _, strace) =
            masked::generate_adaptive(&o, solver, ctl.clone(), 1e-3, &mut rng);
        if b == 1 {
            prop_assert!(strace.grid == trace.grid, "1-lane schedule diverged");
            prop_assert!(stoks == lanes[0].0, "1-lane tokens diverged");
        }
        Ok(())
    });
}

#[test]
fn prop_toy_adaptive_replay_is_bitwise() {
    // Toy counterpart of (c): replaying toy::generate over the realized
    // grid with the same stream reproduces the adaptive sample exactly.
    let mut mrng = Xoshiro256::seed_from_u64(9);
    let model = ToyModel::paper_default(&mut mrng);
    check("toy_adaptive_replay", 30, |g| {
        let solver = theta_solver(g);
        let tol = *g.choose(&[1e-4, 1e-3, 1e-2]);
        let seed = g.usize_in(0, 1 << 20) as u64;
        let cfg = AdaptiveController::for_span(tol, model.horizon, 1e-3);
        let ctl = StepController::new(cfg, g.f64_in(0.05, 2.0));
        let mut ra = Xoshiro256::seed_from_u64(seed);
        let (x, stats, trace) = toy::generate_adaptive(&model, solver, ctl, 1e-3, &mut ra);
        prop_assert!(grid::is_valid_grid(&trace.grid), "invalid realized grid");
        prop_assert!(stats.nfe == 2 * stats.steps, "toy NFE accounting");
        prop_assert!(
            stats.steps == trace.grid.len() - 1,
            "trace length mismatch"
        );
        let mut rf = Xoshiro256::seed_from_u64(seed);
        let want = toy::generate(&model, solver, &trace.grid, &mut rf);
        prop_assert!(x == want, "toy replay diverged for {}", solver.name());
        Ok(())
    });
}

#[test]
fn prop_adaptive_error_control_refines_where_needed() {
    // Sanity on the controller semantics: a tighter tolerance never takes
    // coarser schedules (more steps, monotone in tol) and realized grids
    // are strictly decreasing.
    let o = oracle(6, 16, 47);
    check("tolerance_monotone", 10, |g| {
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let seed = g.usize_in(0, 1 << 16) as u64;
        let mut steps_prev = 0usize;
        for &tol in &[1e-1, 1e-3, 1e-5] {
            let cfg = AdaptiveController::for_span(tol, 1.0, 1e-3);
            let ctl = StepController::new(cfg, 0.1);
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let (_, stats, trace) =
                masked::generate_adaptive(&o, solver, ctl, 1e-3, &mut rng);
            prop_assert!(grid::is_valid_grid(&trace.grid), "invalid grid at {tol}");
            prop_assert!(
                stats.steps + 2 >= steps_prev,
                "tighter tol took far fewer steps: {} after {}",
                stats.steps,
                steps_prev
            );
            steps_prev = stats.steps;
        }
        Ok(())
    });
}
