//! Shape-level reproduction tests: tiny-scale versions of the paper's
//! experiments must reproduce the qualitative claims (who wins, slopes,
//! regime boundaries).  These are the acceptance tests of DESIGN.md's
//! experiment index — absolute numbers are irrelevant, orderings are not.

use fastdds::ctmc::ToyModel;
use fastdds::exp::{fig2, tab2, Scale};
use fastdds::util::rng::Xoshiro256;

#[test]
fn fig2_shape_trapezoidal_second_order() {
    // Reduced Fig. 2: fewer samples, fewer grid points; the slope and the
    // absolute ordering must still hold.
    let mut rng = Xoshiro256::seed_from_u64(7);
    let model = ToyModel::paper_default(&mut rng);
    let cfg = fig2::Fig2Config {
        step_counts: vec![4, 8, 16, 32],
        n_samples: 60_000,
        n_boot: 100,
        threads: 8,
        seed: 99,
    };
    let result = fig2::run(&model, &cfg);
    assert!(
        fig2::shape_holds(&result),
        "Fig. 2 shape failed: {}",
        result.to_string()
    );
}

#[test]
fn tab2_shape_trapezoidal_wins_low_nfe() {
    let scale = Scale { full: false };
    let mut cfg = tab2::Tab2Config::new(scale);
    cfg.vocab = 16;
    cfg.seq_len = 64;
    cfg.nfe_values = vec![16, 32, 64];
    cfg.n_samples = 96;
    let result = tab2::run(&cfg);
    assert!(
        tab2::shape_holds(&result),
        "Tab. 2 shape failed: {}",
        result.to_string()
    );
    // Low-NFE regime: the paper's emphasised margin — trapezoidal strictly
    // below tau-leaping at NFE 16.
    let series = result.get("series").unwrap().as_arr().unwrap();
    let first = |name: &str| -> f64 {
        series
            .iter()
            .find(|s| s.get("solver").unwrap().as_str().unwrap() == name)
            .unwrap()
            .get("perplexity")
            .unwrap()
            .as_f64_vec()
            .unwrap()[0]
    };
    assert!(
        first("theta-trapezoidal") < first("tau-leaping"),
        "trap {} vs tau {} at NFE 16",
        first("theta-trapezoidal"),
        first("tau-leaping")
    );
}

#[test]
fn toy_trapezoidal_beats_rk2_at_equal_nfe() {
    // NFE-matched comparison (both two-stage, so equal steps = equal NFE):
    // the paper's Fig. 2 claim that trapezoidal dominates RK-2.
    use fastdds::solvers::{grid, toy, Solver};
    let mut rng = Xoshiro256::seed_from_u64(7);
    let model = ToyModel::paper_default(&mut rng);
    let g = grid::toy_uniform(16, model.horizon, 1e-3);
    let n = 150_000;
    let trap = toy::empirical_distribution(
        &model,
        Solver::Trapezoidal { theta: 0.5 },
        &g,
        n,
        1,
        8,
    );
    let rk2 = toy::empirical_distribution(&model, Solver::Rk2 { theta: 0.5 }, &g, n, 2, 8);
    let (kl_trap, kl_rk2) = (model.kl_from_p0(&trap), model.kl_from_p0(&rk2));
    assert!(
        kl_trap < kl_rk2,
        "trap {kl_trap} must beat rk2 {kl_rk2} at equal NFE"
    );
}

#[test]
fn rk2_extrapolation_regime_beats_interpolation() {
    // Thm. 5.5 / Fig. 5: RK-2 peaks deep in the extrapolation regime
    // (paper: theta in [0.15, 0.4]); theta = 0.2 must beat theta = 0.5 on
    // the toy model, where theta = 0.5 is merely an interpolation midpoint.
    use fastdds::solvers::{grid, toy, Solver};
    let mut rng = Xoshiro256::seed_from_u64(7);
    let model = ToyModel::paper_default(&mut rng);
    let g = grid::toy_uniform(32, model.horizon, 1e-3);
    let n = 300_000;
    let lo = toy::empirical_distribution(&model, Solver::Rk2 { theta: 0.2 }, &g, n, 3, 8);
    let hi = toy::empirical_distribution(&model, Solver::Rk2 { theta: 0.5 }, &g, n, 4, 8);
    let (kl_lo, kl_hi) = (model.kl_from_p0(&lo), model.kl_from_p0(&hi));
    assert!(
        kl_lo < kl_hi,
        "rk2 theta=0.2 ({kl_lo}) must beat theta=0.5 ({kl_hi})"
    );
}
