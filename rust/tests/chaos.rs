//! Chaos suite: deterministic fault injection against the serving stack.
//!
//! Every scenario drives a real coordinator (and, for the disconnect
//! test, a real TCP server) through one injected fault — a kernel panic
//! mid-batch, a stalled lane running past its deadline, a client gone
//! mid-stream, an admission-cap burst, a scheduler-loop crash, an
//! infeasible deadline — and then asserts the **same** three things:
//!
//!   1. the failing request gets a typed error (or a partial response),
//!      never a hang;
//!   2. innocent bystanders are untouched — co-batched sibling lanes
//!      complete bit-identical to an uninjected run;
//!   3. the coordinator keeps serving: ~50 follow-up requests after the
//!      fault return bit-identical results to a never-faulted
//!      coordinator, and the failure-ledger gauges (`in_flight`,
//!      `queued_lanes`, `registry_entries`) all drain to zero.
//!
//! Faults come from `testkit::fault`: plans keyed on score-evaluation
//! ticks, so each failure lands in exactly the same place on every run
//! (no sleeps-as-synchronisation, no flaky timing).  Where a test does
//! depend on wall time (stalls, deadlines) the margins are hundreds of
//! milliseconds against single-digit scheduling jitter.

use std::sync::Arc;
use std::time::{Duration, Instant};

use fastdds::api::SamplingSpec;
use fastdds::coordinator::{
    codes, BatchPolicy, Coordinator, CoordinatorCfg, GenerateResponse, HealthCfg, JobError,
};
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::score::ScoreSource;
use fastdds::solvers::Solver;
use fastdds::testkit::fault::{silence_injected_panics, FaultPlan, FaultyScore, INJECTED};
use fastdds::util::rng::Xoshiro256;

const VOCAB: usize = 6;
const SEQ_LEN: usize = 14;
const FOLLOW_UPS: usize = 50;

fn oracle() -> MarkovOracle {
    let mut rng = Xoshiro256::seed_from_u64(23);
    MarkovOracle::new(MarkovChain::generate(&mut rng, VOCAB, 0.5), SEQ_LEN)
}

fn spec(solver: Solver, nfe: usize, n: usize, seed: u64) -> SamplingSpec {
    SamplingSpec::builder()
        .solver(solver)
        .nfe(nfe)
        .n_samples(n)
        .seed(seed)
        .build()
        .unwrap()
}

fn pit_spec(solver: Solver, nfe: usize, n: usize, seed: u64) -> SamplingSpec {
    SamplingSpec::builder()
        .solver(solver)
        .nfe(nfe)
        .n_samples(n)
        .seed(seed)
        .pit(true)
        .build()
        .unwrap()
}

/// The uninjected ground truth: a fresh, fault-free coordinator serving
/// the same oracle.  Fixed-grid plans are batch-invariant (PR 1), so its
/// responses are the bit-exact expectation for any batching/policy the
/// faulted coordinator used.
fn clean_expect(spec: &SamplingSpec) -> GenerateResponse {
    let c = Coordinator::start_local(Arc::new(oracle()), BatchPolicy::Greedy, 8);
    let resp = c.generate_spec(spec.clone()).unwrap();
    c.shutdown();
    resp
}

fn typed_code(err: &anyhow::Error) -> &'static str {
    err.downcast_ref::<JobError>()
        .unwrap_or_else(|| panic!("error must carry a typed JobError: {err:#}"))
        .code
}

/// Post-fault health check: `n` sequential requests all bit-identical to
/// the never-faulted expectation, then every gauge drained to zero.
fn assert_serves_clean(c: &Coordinator, spec: &SamplingSpec, n: usize) {
    let want = clean_expect(spec);
    assert!(!want.partial);
    for i in 0..n {
        let got = c.generate_spec(spec.clone()).unwrap_or_else(|e| {
            panic!("follow-up request {i} failed after the fault: {e:#}")
        });
        assert_eq!(got.sequences, want.sequences, "follow-up {i} diverged");
        assert!(!got.partial, "follow-up {i} partial");
    }
    let m = c.metrics();
    assert_eq!(m.in_flight, 0, "in-flight requests leaked");
    assert_eq!(m.queued_lanes, 0, "queued lanes leaked");
    assert_eq!(m.registry_entries, 0, "cancel-registry entries leaked");
}

// ===========================================================================
// 1. Kernel panic during a batched dispatch
// ===========================================================================

#[test]
fn panic_in_batched_dispatch_isolates_the_lane() {
    silence_injected_panics();
    // Tick 0 = the co-batched dispatch; tick 1 = the first lane's solo
    // rerun.  So the batch panics, isolation reruns lane-by-lane, the
    // FIRST request fails typed, and its two siblings complete.
    let plan = FaultPlan::new().panic_at(0).panic_at(1);
    let faulty = Arc::new(FaultyScore::new(oracle(), plan));
    // Timeout policy with capacity 3: the batcher holds lanes until all
    // three single-lane requests are queued (full => dispatch), which
    // pins the tick alignment with zero timing assumptions.
    let c = Coordinator::start_local(
        faulty,
        BatchPolicy::Timeout(Duration::from_secs(10)),
        3,
    );
    let solver = Solver::TauLeaping;
    let specs: Vec<SamplingSpec> =
        (0..3).map(|i| spec(solver, 16, 1, 100 + i)).collect();
    let handles: Vec<_> =
        specs.iter().map(|s| c.submit_spec(s.clone())).collect();
    let mut results: Vec<Result<GenerateResponse, anyhow::Error>> =
        handles.into_iter().map(|h| h.wait()).collect();

    // The panicking lane's request: typed lane_failed, message naming the
    // injected fault.
    let err = results.remove(0).unwrap_err();
    assert_eq!(typed_code(&err), codes::LANE_FAILED);
    assert!(
        err.to_string().contains("panicked during dispatch"),
        "unexpected message: {err:#}"
    );
    assert!(err.to_string().contains(INJECTED), "message lost the payload");

    // Sibling lanes: bit-identical to a coordinator that never saw a
    // fault (per-lane seeded streams + fixed-grid batch invariance).
    for (s, got) in specs[1..].iter().zip(results) {
        let got = got.expect("sibling lane must complete");
        let want = clean_expect(s);
        assert_eq!(got.sequences, want.sequences, "sibling diverged");
        assert_eq!(got.nfe_used, want.nfe_used);
        assert!(!got.partial);
    }

    let m = c.metrics();
    assert_eq!(m.lane_failures, 1, "exactly one lane failure");
    assert_eq!(m.requests, 3);

    // The coordinator keeps serving (full batches dispatch immediately
    // under the timeout policy).
    assert_serves_clean(&c, &spec(solver, 16, 3, 900), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 2. Stalled lane runs past its deadline
// ===========================================================================

#[test]
fn stalled_lane_hits_deadline_and_returns_partial() {
    silence_injected_panics();
    // Tick 2 stalls for 400ms against a 100ms deadline: the solver's next
    // per-window poll sees the expired deadline and winds the run down
    // into a partial response — an expiry in the ledger, not an error.
    let plan = FaultPlan::new().stall_at(2, Duration::from_millis(400));
    let faulty = Arc::new(FaultyScore::new(oracle(), plan));
    let c = Coordinator::start_local(faulty, BatchPolicy::Greedy, 8);

    let stalled = SamplingSpec::builder()
        .solver(Solver::TauLeaping)
        .nfe(32)
        .n_samples(1)
        .seed(7)
        .deadline_ms(Some(100))
        .build()
        .unwrap();
    let t0 = Instant::now();
    let resp = c.generate_spec(stalled).expect("expiry is not an error");
    assert!(resp.partial, "deadline expiry must surface as partial");
    assert!(
        t0.elapsed() >= Duration::from_millis(300),
        "the stall itself must have happened"
    );
    // Far fewer evaluations than the 33 the plan would spend.
    assert!(resp.nfe_used < 33, "nfe_used={}", resp.nfe_used);

    let m = c.metrics();
    assert_eq!(m.deadline_expiries, 1);
    assert_eq!(m.deadline_rejects, 0, "a cold cost model must not reject");

    // Un-deadlined follow-ups (ticks past the stall) serve clean.
    assert_serves_clean(&c, &spec(Solver::TauLeaping, 16, 2, 40), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 3. Client disconnects mid-stream (server level)
// ===========================================================================

#[test]
fn client_disconnect_mid_stream_leaks_nothing() {
    use fastdds::server::client::Client;
    use fastdds::server::Server;

    silence_injected_panics();
    // The first dispatch stalls 300ms, guaranteeing the job is still
    // running when the client vanishes right after the accepted frame.
    let plan = FaultPlan::new().stall_at(0, Duration::from_millis(300));
    let faulty = Arc::new(FaultyScore::new(oracle(), plan));
    let coord = Coordinator::start_local(faulty, BatchPolicy::Greedy, 8);
    let srv = Server::start("127.0.0.1:0", coord).unwrap();
    let addr = srv.addr.to_string();
    let timeout = Some(Duration::from_secs(10));

    let streamed = spec(Solver::TauLeaping, 16, 2, 55);
    {
        let mut doomed = Client::connect_with(&addr, timeout).unwrap();
        let id = doomed.start_stream(&streamed).unwrap();
        assert!(id > 0);
        // Drop without reading a single chunk: the handler's next write
        // fails, it cancels the job and exits; the coordinator completes
        // the job into the void and clears every registry entry.
    }

    let mut c = Client::connect_with(&addr, timeout).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let stats = c.stats().unwrap();
        let in_flight = stats.get("in_flight").unwrap().as_u64().unwrap();
        let queued = stats.get("queued_lanes").unwrap().as_u64().unwrap();
        let registry = stats.get("registry_entries").unwrap().as_u64().unwrap();
        if in_flight == 0 && queued == 0 && registry == 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "gauges never drained: in_flight={in_flight} queued={queued} \
             registry={registry}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // The abandoned job must not have poisoned the serving path: the same
    // spec (and others) now serve bit-identical to a clean coordinator,
    // over fresh connections and streams alike.
    let want = clean_expect(&streamed);
    for i in 0..FOLLOW_UPS {
        let got = if i % 10 == 0 {
            c.generate_stream(&streamed).unwrap().response
        } else {
            c.generate_spec(&streamed).unwrap()
        };
        assert_eq!(got.sequences, want.sequences, "follow-up {i} diverged");
        assert!(!got.partial);
    }
    let stats = c.stats().unwrap();
    assert_eq!(stats.get("registry_entries").unwrap().as_u64().unwrap(), 0);
    srv.stop();
}

// ===========================================================================
// 4. Over-cap burst: load shedding + priority displacement
// ===========================================================================

#[test]
fn overload_burst_sheds_typed_and_respects_priority() {
    silence_injected_panics();
    // queue_cap 2 with a hold-forever policy and batch capacity 2.  The
    // burst: A (tau, prio 1) and B (euler, prio 1) fill the queue in two
    // DIFFERENT batch-key queues, so neither can dispatch early (1 < 2
    // lanes each) — admission order is the only ordering that matters.
    let c = Coordinator::start_local_with_cfg(
        Arc::new(oracle()),
        BatchPolicy::Timeout(Duration::from_secs(10)),
        2,
        None,
        CoordinatorCfg { max_inflight: None, queue_cap: Some(2), ..Default::default() },
    );
    let a = spec(Solver::TauLeaping, 16, 1, 5);
    let b = spec(Solver::Euler, 16, 1, 6);
    let c_req = spec(Solver::TauLeaping, 16, 1, 7);
    let d = SamplingSpec::builder()
        .solver(Solver::TauLeaping)
        .nfe(16)
        .n_samples(1)
        .seed(8)
        .priority(2)
        .build()
        .unwrap();

    let ha = c.submit_spec(a.clone());
    let hb = c.submit_spec(b);
    // C (same priority as everything queued): nothing strictly lower to
    // displace — C itself is shed, typed.
    let hc = c.submit_spec(c_req);
    // D (priority 2): displaces the NEWEST queued lower-priority request
    // (B), joins A's batch-key queue, fills it (2 = capacity) and both
    // dispatch immediately.
    let hd = c.submit_spec(d.clone());

    let err_b = hb.wait().unwrap_err();
    assert_eq!(typed_code(&err_b), codes::OVERLOADED);
    assert!(
        err_b.to_string().contains("displaced"),
        "B must be the priority victim: {err_b:#}"
    );
    let err_c = hc.wait().unwrap_err();
    assert_eq!(typed_code(&err_c), codes::OVERLOADED);
    assert!(
        err_c.to_string().contains("caps"),
        "C must be shed at the cap: {err_c:#}"
    );

    let got_a = ha.wait().expect("A was admitted first and must complete");
    let got_d = hd.wait().expect("D displaced its way in and must complete");
    assert_eq!(got_a.sequences, clean_expect(&a).sequences, "A diverged");
    assert_eq!(got_d.sequences, clean_expect(&d).sequences, "D diverged");

    let m = c.metrics();
    assert_eq!(m.sheds, 2, "exactly B and C shed");
    assert_eq!(m.requests, 4);

    // Full (2-lane) follow-ups dispatch immediately and fit the cap.
    assert_serves_clean(&c, &spec(Solver::TauLeaping, 16, 2, 41), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 5. Scheduler-loop crash: supervisor restart with a job in flight
// ===========================================================================

#[test]
fn supervisor_restart_fails_inflight_typed_and_keeps_serving() {
    silence_injected_panics();
    // Hold-forever policy: the submitted job is guaranteed still queued
    // (capacity 2 > its 1 lane) when the crash lands right behind it in
    // the same FIFO channel.
    let c = Coordinator::start_local(
        Arc::new(oracle()),
        BatchPolicy::Timeout(Duration::from_secs(10)),
        2,
    );
    let doomed = c.submit_spec(spec(Solver::TauLeaping, 16, 1, 70));
    c.inject_loop_panic(&format!("{INJECTED} supervisor drill"));

    let err = doomed.wait().unwrap_err();
    assert_eq!(typed_code(&err), codes::COORDINATOR_RESTARTED);
    assert!(err.to_string().contains("restarted"), "{err:#}");

    // The restarted loop serves from a fresh batcher/assembler: full
    // batches dispatch immediately, results bit-identical to clean.
    assert_serves_clean(&c, &spec(Solver::TauLeaping, 16, 2, 42), FOLLOW_UPS);
    let m = c.metrics();
    assert_eq!(m.supervisor_restarts, 1);
    assert_eq!(m.requests, 1 + FOLLOW_UPS as u64);
    c.shutdown();
}

// ===========================================================================
// 6. Panic mid-sweep in a parallel-in-time dispatch
// ===========================================================================

#[test]
fn pit_sweep_panic_isolates_the_lane_and_keeps_parity() {
    silence_injected_panics();
    // A PIT dispatch's first score call is sweep 1's pooled slice
    // evaluation (`probs_masked_slices`, one tick for the whole batch) —
    // tick 0 panics there, mid-sweep with zero lanes converged.  Tick 1
    // is the first lane's solo rerun (its own sweep-1 pooled eval), so
    // the FIRST request fails typed and its two siblings complete.
    let plan = FaultPlan::new().panic_at(0).panic_at(1);
    let faulty = Arc::new(FaultyScore::new(oracle(), plan));
    let c = Coordinator::start_local(
        faulty,
        BatchPolicy::Timeout(Duration::from_secs(10)),
        3,
    );
    let solver = Solver::TauLeaping;
    let specs: Vec<SamplingSpec> =
        (0..3).map(|i| pit_spec(solver, 16, 1, 300 + i)).collect();
    let handles: Vec<_> =
        specs.iter().map(|s| c.submit_spec(s.clone())).collect();
    let mut results: Vec<Result<GenerateResponse, anyhow::Error>> =
        handles.into_iter().map(|h| h.wait()).collect();

    let err = results.remove(0).unwrap_err();
    assert_eq!(typed_code(&err), codes::LANE_FAILED);
    assert!(err.to_string().contains(INJECTED), "message lost the payload");

    // Bystander lanes: bit-identical to a never-faulted PIT run AND to
    // the sequential twin of the same seed — the tol = 0 parity guarantee
    // must survive fault isolation's solo re-dispatch.
    for (i, (s, got)) in specs[1..].iter().zip(results).enumerate() {
        let got = got.expect("sibling lane must complete");
        let want = clean_expect(s);
        assert_eq!(got.sequences, want.sequences, "sibling {i} diverged");
        assert!(!got.partial);
        let twin = spec(solver, 16, 1, 301 + i as u64);
        assert_eq!(
            got.sequences,
            clean_expect(&twin).sequences,
            "sibling {i} broke PIT/sequential parity"
        );
    }

    let m = c.metrics();
    assert_eq!(m.lane_failures, 1, "exactly one lane failure");
    assert_eq!(m.pit_sweep_limit_hits, 0, "no sweep-limit partials here");
    assert!(
        m.pit_converged_lanes >= 2,
        "both siblings must count as converged, got {}",
        m.pit_converged_lanes
    );

    // Post-fault health, through the PIT path itself.
    assert_serves_clean(&c, &pit_spec(solver, 16, 3, 910), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 8. Transient backend fault: retried under the budget, bit-identical
// ===========================================================================

#[test]
fn transient_fault_retries_to_a_bit_identical_response() {
    silence_injected_panics();
    // Tick 0 — the first attempt's first score call — fails with the
    // `[transient]` marker.  The health layer retries under backoff; the
    // second attempt (tick 1 onward) runs clean.  Evals are pure and each
    // lane re-seeds per attempt, so the retried request must come back
    // bit-identical to a never-faulted coordinator.
    let plan = FaultPlan::new().err_at(0);
    let faulty = Arc::new(FaultyScore::new(oracle(), plan));
    let c = Coordinator::start_local(faulty, BatchPolicy::Greedy, 8);

    let s = spec(Solver::TauLeaping, 16, 2, 500);
    let got = c.generate_spec(s.clone()).expect("transient fault must be retried");
    let want = clean_expect(&s);
    assert_eq!(got.sequences, want.sequences, "retry parity broken");
    assert_eq!(got.nfe_used, want.nfe_used);
    assert!(!got.partial);
    assert_eq!(got.degraded, None, "retry is not a degradation");

    let m = c.metrics();
    assert_eq!(m.retries, 1, "exactly one retry");
    assert_eq!(m.lane_failures, 0, "transient faults never isolate lanes");
    assert_eq!(m.backend_unavailable, 0);
    assert_eq!(m.breaker_state, "closed", "one recovered fault must not trip");

    assert_serves_clean(&c, &spec(Solver::TauLeaping, 16, 2, 501), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 9. Circuit breaker: exhausted retries trip it; cooldown, probe, close
// ===========================================================================

#[test]
fn breaker_opens_fast_fails_then_probe_recovers() {
    silence_injected_panics();
    // Ticks 0..=2 all fail transient: with retry_budget = 2 the first
    // request burns exactly attempts 0, 1, 2 and exhausts.  threshold = 1
    // trips the breaker on that single exhausted dispatch.  Brownout is
    // off so the breaker's effect is observed in isolation.
    let plan = FaultPlan::new().err_at(0).err_at(1).err_at(2);
    let faulty = Arc::new(FaultyScore::new(oracle(), plan));
    let cooldown = Duration::from_millis(400);
    let c = Coordinator::start_local_with_cfg(
        faulty,
        BatchPolicy::Greedy,
        8,
        None,
        CoordinatorCfg {
            max_inflight: None,
            queue_cap: None,
            health: HealthCfg {
                failure_threshold: 1,
                cooldown,
                retry_budget: 2,
                backoff_initial: Duration::from_millis(1),
                backoff_cap: Duration::from_millis(5),
                brownout: false,
                ..Default::default()
            },
        },
    );

    // Request 1: every attempt fails -> typed backend_unavailable, and
    // the exhausted dispatch trips the breaker open.
    let err = c.generate_spec(spec(Solver::TauLeaping, 16, 1, 600)).unwrap_err();
    assert_eq!(typed_code(&err), codes::BACKEND_UNAVAILABLE);
    assert!(err.to_string().contains("retries exhausted"), "{err:#}");

    // Request 2 (well inside the cooldown): fails fast at the gate — no
    // score call is ever made against the sick backend.
    let err = c.generate_spec(spec(Solver::TauLeaping, 16, 1, 601)).unwrap_err();
    assert_eq!(typed_code(&err), codes::BACKEND_UNAVAILABLE);
    assert!(err.to_string().contains("circuit breaker open"), "{err:#}");
    let m = c.metrics();
    assert_eq!(m.breaker_state, "open");
    assert_eq!(m.retries, 2, "budget spent once, fast-fail spends none");
    assert_eq!(m.backend_unavailable, 2);

    // Cooldown elapses: the next dispatch is the half-open probe.  Ticks
    // 3+ are clean, so the probe succeeds, closes the breaker, and its
    // response is bit-identical to a never-faulted run.
    std::thread::sleep(cooldown + Duration::from_millis(100));
    let s = spec(Solver::TauLeaping, 16, 1, 602);
    let got = c.generate_spec(s.clone()).expect("probe must succeed");
    assert_eq!(got.sequences, clean_expect(&s).sequences, "probe diverged");
    let m = c.metrics();
    assert_eq!(m.breaker_state, "closed");
    assert!(m.breaker_probes >= 1, "the recovery dispatch must be a probe");

    assert_serves_clean(&c, &spec(Solver::TauLeaping, 16, 2, 603), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 10. Stalled backend eval: watchdog abandons it, nothing else is delayed
// ===========================================================================

#[test]
fn stalled_eval_does_not_block_unrelated_requests() {
    silence_injected_panics();
    let faulty = Arc::new(FaultyScore::new(oracle(), FaultPlan::new()));
    let c = Coordinator::start_local_with_cfg(
        Arc::clone(&faulty) as Arc<dyn ScoreSource>,
        BatchPolicy::Greedy,
        8,
        None,
        CoordinatorCfg {
            max_inflight: None,
            queue_cap: None,
            health: HealthCfg {
                watchdog_floor: Duration::from_millis(100),
                ..Default::default()
            },
        },
    );

    // Warm the cost model so the watchdog can price a bound (a cold model
    // never times anything out) — then arm a 1500ms stall on the next
    // score evaluation, whichever dispatch lands on it.
    let warm = spec(Solver::TauLeaping, 16, 1, 700);
    for _ in 0..3 {
        c.generate_spec(warm.clone()).unwrap();
    }
    faulty.set_plan(
        FaultPlan::new().stall_at(faulty.calls(), Duration::from_millis(1500)),
    );

    // Two unrelated single-lane requests (different batch keys).  One of
    // them eats the stall on its first attempt; the watchdog abandons the
    // worker at ~100ms and the retry serves it clean.  NEITHER may be
    // delayed anywhere near the 1500ms stall.
    let a = spec(Solver::TauLeaping, 16, 1, 701);
    let b = spec(Solver::Euler, 16, 1, 702);
    let t0 = Instant::now();
    let ha = c.submit_spec(a.clone());
    let hb = c.submit_spec(b.clone());
    let got_a = ha.wait().expect("stalled-then-retried request must complete");
    let got_b = hb.wait().expect("unrelated request must complete");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_millis(1200),
        "watchdog bound violated: both requests took {elapsed:?} against a \
         1500ms stall"
    );
    assert_eq!(got_a.sequences, clean_expect(&a).sequences, "A diverged");
    assert_eq!(got_b.sequences, clean_expect(&b).sequences, "B diverged");
    assert!(!got_a.partial && !got_b.partial);

    let m = c.metrics();
    assert_eq!(m.eval_timeouts, 1, "exactly one watchdog expiry");
    assert_eq!(m.retries, 1, "the abandoned attempt retried once");
    assert_eq!(m.backend_unavailable, 0, "the retry succeeded");
    assert_eq!(m.breaker_state, "closed");

    // The abandoned worker is still asleep inside its 1500ms stall and
    // keeps ticking the shared counter once it wakes — clear the plan so
    // no pinned tick can ever collide with the follow-ups.
    faulty.set_plan(FaultPlan::new());
    assert_serves_clean(&c, &spec(Solver::TauLeaping, 16, 2, 703), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 11. Brownout: an overload burst degrades instead of shedding everything
// ===========================================================================

/// Pin the coordinator loop inside a known stall so a burst of
/// submissions provably queues up behind it and is admitted in one drain —
/// the only way to make queue-pressure rungs deterministic without
/// sleeps-as-synchronisation.  Returns the stall job's handle.
fn stall_the_loop(
    c: &Coordinator,
    faulty: &Arc<FaultyScore<MarkovOracle>>,
    stall: Duration,
) -> fastdds::coordinator::JobHandle {
    faulty.set_plan(FaultPlan::new().stall_at(faulty.calls(), stall));
    // n_samples = 2 fills the capacity-2 batch: due immediately.
    let hs = c.submit_spec(spec(Solver::TauLeaping, 16, 2, 800));
    // The tick counter increments the moment the stall begins — the loop
    // (via its dispatch worker) is now provably blocked inside it.
    let t0 = Instant::now();
    while faulty.calls() == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "stall dispatch never started"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    hs
}

#[test]
fn brownout_burst_degrades_echoes_and_sheds_typed() {
    silence_injected_panics();
    let faulty = Arc::new(FaultyScore::new(oracle(), FaultPlan::new()));
    let c = Coordinator::start_local_with_cfg(
        Arc::clone(&faulty) as Arc<dyn ScoreSource>,
        BatchPolicy::Greedy,
        2,
        None,
        CoordinatorCfg {
            max_inflight: None,
            queue_cap: Some(8),
            health: HealthCfg::default(),
        },
    );
    let hs = stall_the_loop(&c, &faulty, Duration::from_millis(500));

    // 12 uniform-schedule nfe-256 requests, all queued while the loop is
    // blocked, admitted back to back in one drain.  queue utilization
    // (pending + 1) / 8 walks the ladder deterministically: requests 1-2
    // admit clean, 3-6 hit rungs 1-2 (no-ops on a uniform non-PIT spec,
    // so they stay undegraded), 7-8 hit rung 3 (NFE clamped to the
    // floor), 9-12 overflow the queue cap and shed typed.
    let burst: Vec<SamplingSpec> =
        (0..12).map(|i| spec(Solver::Euler, 256, 1, 810 + i)).collect();
    let handles: Vec<_> = burst.iter().map(|s| c.submit_spec(s.clone())).collect();
    let results: Vec<Result<GenerateResponse, anyhow::Error>> =
        handles.into_iter().map(|h| h.wait()).collect();

    for (i, (s, r)) in burst.iter().zip(&results).enumerate() {
        match i {
            0..=5 => {
                // Undegraded: bit-identical to a coordinator that never
                // browned out, and no echo.
                let got = r.as_ref().expect("undegraded request must complete");
                assert_eq!(got.degraded, None, "request {i} falsely degraded");
                assert_eq!(
                    got.sequences,
                    clean_expect(s).sequences,
                    "undegraded request {i} diverged"
                );
            }
            6 | 7 => {
                // Degraded to the NFE floor: the echo names rung 3 and
                // the sequences are exactly a clean run of the degraded
                // twin spec.
                let got = r.as_ref().expect("degraded request must complete");
                assert_eq!(got.degraded, Some(3), "request {i} missing the echo");
                let (twin, applied) = s.degrade(3).expect("nfe 256 must degrade");
                assert_eq!(applied, 3);
                assert_eq!(
                    got.sequences,
                    clean_expect(&twin).sequences,
                    "degraded request {i} is not the twin spec's clean run"
                );
            }
            _ => {
                let err = r.as_ref().expect_err("over-cap request must shed");
                assert_eq!(typed_code(err), codes::OVERLOADED, "request {i}");
            }
        }
    }

    // The stall request itself: merely slow, never degraded.
    let got_s = hs.wait().expect("the stalled batch must complete");
    assert_eq!(got_s.degraded, None);

    let m = c.metrics();
    assert_eq!(m.degraded_rung3, 2, "exactly requests 7 and 8 degraded");
    assert_eq!(m.degraded_rung1 + m.degraded_rung2, 0, "rungs 1-2 were no-ops");
    assert_eq!(m.sheds, 4, "exactly requests 9-12 shed");

    // Pressure gone: follow-ups are admitted undegraded and bit-identical.
    faulty.set_plan(FaultPlan::new());
    assert_serves_clean(&c, &spec(Solver::Euler, 16, 2, 830), FOLLOW_UPS);
    assert_eq!(c.metrics().degraded_rung3, 2, "follow-ups must not degrade");
    c.shutdown();
}

// ===========================================================================
// 12. no_degrade: the ladder is never applied, overload sheds typed
// ===========================================================================

#[test]
fn no_degrade_requests_shed_typed_instead_of_degrading() {
    silence_injected_panics();
    let faulty = Arc::new(FaultyScore::new(oracle(), FaultPlan::new()));
    let c = Coordinator::start_local_with_cfg(
        Arc::clone(&faulty) as Arc<dyn ScoreSource>,
        BatchPolicy::Greedy,
        2,
        None,
        CoordinatorCfg {
            max_inflight: None,
            queue_cap: Some(8),
            health: HealthCfg::default(),
        },
    );
    let hs = stall_the_loop(&c, &faulty, Duration::from_millis(500));

    // The same burst shape as the brownout scenario, but every spec opts
    // out: rung 3 must never fire — requests 7-8 are admitted at their
    // full 256 NFE, and the overflow sheds typed exactly as it did before
    // the ladder existed.
    let burst: Vec<SamplingSpec> = (0..12)
        .map(|i| {
            SamplingSpec::builder()
                .solver(Solver::Euler)
                .nfe(256)
                .n_samples(1)
                .seed(850 + i)
                .no_degrade(true)
                .build()
                .unwrap()
        })
        .collect();
    let handles: Vec<_> = burst.iter().map(|s| c.submit_spec(s.clone())).collect();
    let results: Vec<Result<GenerateResponse, anyhow::Error>> =
        handles.into_iter().map(|h| h.wait()).collect();

    for (i, (s, r)) in burst.iter().zip(&results).enumerate() {
        if i <= 7 {
            let got = r.as_ref().expect("admitted request must complete");
            assert_eq!(got.degraded, None, "no_degrade request {i} was degraded");
            assert_eq!(
                got.sequences,
                clean_expect(s).sequences,
                "no_degrade request {i} diverged"
            );
        } else {
            let err = r.as_ref().expect_err("over-cap request must shed");
            assert_eq!(typed_code(err), codes::OVERLOADED, "request {i}");
        }
    }
    hs.wait().expect("the stalled batch must complete");

    let m = c.metrics();
    assert_eq!(
        m.degraded_rung1 + m.degraded_rung2 + m.degraded_rung3,
        0,
        "the ladder must never touch an opted-out spec"
    );
    assert_eq!(m.sheds, 4, "exactly requests 9-12 shed");

    faulty.set_plan(FaultPlan::new());
    assert_serves_clean(&c, &spec(Solver::Euler, 16, 2, 870), FOLLOW_UPS);
    c.shutdown();
}

// ===========================================================================
// 7. Deadline admission control: infeasible plans rejected at intake
// ===========================================================================

#[test]
fn infeasible_deadline_rejected_after_cost_model_warms() {
    silence_injected_panics();
    let c = Coordinator::start_local(Arc::new(oracle()), BatchPolicy::Greedy, 8);
    let warm = spec(Solver::TauLeaping, 16, 1, 90);

    // Warm the ms/NFE cost model: a cold model never rejects.
    for _ in 0..3 {
        c.generate_spec(warm.clone()).unwrap();
    }

    // 20M planned evaluations against a 1ms deadline: infeasible at any
    // physically possible rate the EWMA can have learned.
    let hopeless = SamplingSpec::builder()
        .solver(Solver::TauLeaping)
        .nfe(20_000_000)
        .n_samples(1)
        .seed(91)
        .deadline_ms(Some(1))
        .build()
        .unwrap();
    let err = c.generate_spec(hopeless).unwrap_err();
    assert_eq!(typed_code(&err), codes::DEADLINE_INFEASIBLE);
    assert!(err.to_string().contains("infeasible"), "{err:#}");

    let m = c.metrics();
    assert_eq!(m.deadline_rejects, 1);
    assert_eq!(m.deadline_expiries, 0, "rejection, not expiry");

    // A generous deadline on the same warm model admits and completes
    // bit-identical to the deadline-free run (the token is armed but
    // never fires, and arming draws no RNG).
    let deadlined = SamplingSpec::builder()
        .solver(Solver::TauLeaping)
        .nfe(16)
        .n_samples(1)
        .seed(90)
        .deadline_ms(Some(600_000))
        .build()
        .unwrap();
    let got = c.generate_spec(deadlined).unwrap();
    let want = clean_expect(&warm);
    assert_eq!(got.sequences, want.sequences, "deadline perturbed sampling");
    assert!(!got.partial);

    assert_serves_clean(&c, &warm, FOLLOW_UPS);
    c.shutdown();
}
