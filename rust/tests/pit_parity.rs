//! Parallel-in-time parity suite: the PIT driver's load-bearing invariant
//! is that at `tol = 0` with `sweeps_max >= steps` its output is
//! **bit-identical** to the sequential driver on the same seed and grid —
//! for every solver kernel, every state family, and through every public
//! entry point (single, lock-step batch).  This file sweeps that product
//! space through the public shims (`masked::pit_generate`,
//! `toy::pit_generate`, the `_batch_ctl` twins) the serving stack
//! dispatches to, plus the divergence guard: a starved `sweeps_max`
//! returns a typed partial (`PitOutcome::SweepLimit`), never a wrong
//! sample and never a spin.

use fastdds::score::hmm::HmmUniformOracle;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::pit::{PitCfg, PitOutcome};
use fastdds::solvers::{grid, masked, toy, Solver};
use fastdds::util::cancel::CancelToken;
use fastdds::util::rng::{Rng, Xoshiro256};

/// Every solver the PIT driver serves (all grid schemes; exact simulation
/// has no grid to iterate).  Midpoint rides at θ = 1/2 (the RK-2 anchor
/// point) AND θ = 0.7, where it is a genuinely distinct scheme.
fn pit_solvers() -> Vec<Solver> {
    vec![
        Solver::Euler,
        Solver::TauLeaping,
        Solver::Tweedie,
        Solver::Trapezoidal { theta: 0.5 },
        Solver::Trapezoidal { theta: 0.3 },
        Solver::Rk2 { theta: 0.5 },
        Solver::Rk2 { theta: 0.3 },
        Solver::Midpoint { theta: 0.5 },
        Solver::Midpoint { theta: 0.7 },
        Solver::ParallelDecoding,
    ]
}

fn oracle(vocab: usize, seq_len: usize, seed: u64) -> MarkovOracle {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    MarkovOracle::new(MarkovChain::generate(&mut rng, vocab, 0.5), seq_len)
}

#[test]
fn masked_pit_bit_parity_across_solvers_and_seeds() {
    let o = oracle(6, 16, 11);
    for steps in [4usize, 10] {
        let g = grid::masked_uniform(steps, 1e-3);
        let cfg = PitCfg::new(steps, 0.0);
        for solver in pit_solvers() {
            for seed in [0u64, 7, 99] {
                let mut sr = Xoshiro256::seed_from_u64(seed);
                let (want, _) = masked::generate(&o, solver, &g, &mut sr);
                let mut pr = Xoshiro256::seed_from_u64(seed);
                let lane = masked::pit_generate(&o, solver, &g, &cfg, &mut pr);
                let tag = format!("{} steps={steps} seed={seed}", solver.name());
                assert_eq!(lane.outcome, PitOutcome::Exact, "{tag}");
                assert_eq!(lane.out, want, "{tag}");
                assert!(lane.sweeps >= 1 && lane.sweeps <= steps, "{tag}: sweeps {}", lane.sweeps);
                // Caller-stream continuation: the PIT run consumed exactly
                // the sequential draws, so both streams stay in lock-step.
                assert_eq!(sr.gen_u64(), pr.gen_u64(), "{tag}: rng continuation");
            }
        }
    }
}

#[test]
fn masked_pit_hmm_source_parity() {
    // The time-inhomogeneous HMM source evaluates at per-stage times; the
    // cached-slice bookkeeping must keep parity there too.
    let mut rng = Xoshiro256::seed_from_u64(17);
    let chain = MarkovChain::generate(&mut rng, 5, 0.6);
    let o = HmmUniformOracle::new(chain, 10);
    let steps = 8usize;
    let g = grid::masked_uniform(steps, 1e-3);
    let cfg = PitCfg::new(steps, 0.0);
    for solver in [
        Solver::Tweedie,
        Solver::Trapezoidal { theta: 0.5 },
        Solver::Rk2 { theta: 0.3 },
        Solver::Midpoint { theta: 0.7 },
    ] {
        for seed in [4u64, 31] {
            let mut sr = Xoshiro256::seed_from_u64(seed);
            let (want, _) = masked::generate(&o, solver, &g, &mut sr);
            let mut pr = Xoshiro256::seed_from_u64(seed);
            let lane = masked::pit_generate(&o, solver, &g, &cfg, &mut pr);
            assert_eq!(lane.outcome, PitOutcome::Exact, "{} seed={seed}", solver.name());
            assert_eq!(lane.out, want, "{} seed={seed}", solver.name());
        }
    }
}

#[test]
fn toy_pit_bit_parity_across_solvers_and_seeds() {
    let mut mrng = Xoshiro256::seed_from_u64(7);
    let model = fastdds::ctmc::ToyModel::paper_default(&mut mrng);
    for steps in [8usize, 24] {
        let g = grid::toy_uniform(steps, model.horizon, 1e-3);
        let cfg = PitCfg::new(steps, 0.0);
        for solver in pit_solvers() {
            if matches!(solver, Solver::ParallelDecoding) {
                continue; // undefined for the toy model
            }
            // Share one sequential stream across reps so diverse states
            // are hit; any divergence desynchronises everything after it.
            for seed in [13u64, 77, 900] {
                let mut sr = Xoshiro256::seed_from_u64(seed);
                let want = toy::generate(&model, solver, &g, &mut sr);
                let mut pr = Xoshiro256::seed_from_u64(seed);
                let lane = toy::pit_generate(&model, solver, &g, &cfg, &mut pr);
                let tag = format!("{} steps={steps} seed={seed}", solver.name());
                assert_eq!(lane.outcome, PitOutcome::Exact, "{tag}");
                assert_eq!(lane.out, want, "{tag}");
                assert!(lane.sweeps <= steps, "{tag}");
                assert_eq!(sr.gen_u64(), pr.gen_u64(), "{tag}: rng continuation");
            }
        }
    }
}

#[test]
fn masked_pit_batch_matches_single() {
    let o = oracle(6, 16, 11);
    let steps = 8usize;
    let g = grid::masked_uniform(steps, 1e-3);
    let cfg = PitCfg::new(steps, 0.0);
    let seeds = [3u64, 141, 59, 2653, 0];
    for solver in [
        Solver::TauLeaping,
        Solver::Trapezoidal { theta: 0.5 },
        Solver::Midpoint { theta: 0.7 },
    ] {
        let batch = masked::pit_generate_batch_ctl(
            &o,
            solver,
            &g,
            &seeds,
            &cfg,
            &CancelToken::never(),
            None,
        );
        assert_eq!(batch.len(), seeds.len());
        for (b, &s) in seeds.iter().enumerate() {
            let mut r = Xoshiro256::seed_from_u64(s);
            let single = masked::pit_generate(&o, solver, &g, &cfg, &mut r);
            let tag = format!("{} lane {b}", solver.name());
            assert_eq!(batch[b].out, single.out, "{tag}");
            assert_eq!(batch[b].outcome, single.outcome, "{tag}");
            assert_eq!(batch[b].sweeps, single.sweeps, "{tag}");
            assert_eq!(batch[b].stats.nfe, single.stats.nfe, "{tag}");
        }
    }
}

#[test]
fn toy_pit_batch_matches_single() {
    let mut mrng = Xoshiro256::seed_from_u64(7);
    let model = fastdds::ctmc::ToyModel::paper_default(&mut mrng);
    let steps = 12usize;
    let g = grid::toy_uniform(steps, model.horizon, 1e-3);
    let cfg = PitCfg::new(steps, 0.0);
    let seeds = [5u64, 6, 7, 8, 9];
    let solver = Solver::Midpoint { theta: 0.5 };
    let batch = toy::pit_generate_batch_ctl(
        &model,
        solver,
        &g,
        &seeds,
        &cfg,
        &CancelToken::never(),
        None,
    );
    for (b, &s) in seeds.iter().enumerate() {
        let mut r = Xoshiro256::seed_from_u64(s);
        let single = toy::pit_generate(&model, solver, &g, &cfg, &mut r);
        assert_eq!(batch[b].out, single.out, "lane {b}");
        assert_eq!(batch[b].sweeps, single.sweeps, "lane {b}");
    }
}

#[test]
fn starved_sweep_budget_is_a_typed_partial_not_a_wrong_sample() {
    // Divergence guard: one sweep cannot converge a cold 16-step grid
    // (the prefix advances at most 1 + inline-budget steps per sweep), so
    // the driver must return `SweepLimit` — a typed, incomplete result —
    // rather than spinning or passing a non-fixed-point off as converged.
    let o = oracle(6, 16, 11);
    let steps = 16usize;
    let g = grid::masked_uniform(steps, 1e-3);
    let starved = PitCfg::new(1, 0.0);
    let mut r = Xoshiro256::seed_from_u64(5);
    let lane = masked::pit_generate(&o, Solver::Trapezoidal { theta: 0.5 }, &g, &starved, &mut r);
    assert_eq!(lane.outcome, PitOutcome::SweepLimit);
    assert!(!lane.outcome.converged());
    assert!(!lane.outcome.complete());
    assert_eq!(lane.sweeps, 1);

    // The same request with the spec-layer default budget (steps) must
    // converge to the exact fixed point — starvation is a budget property,
    // not a trajectory property.
    let healthy = PitCfg::new(steps, 0.0);
    let mut r = Xoshiro256::seed_from_u64(5);
    let lane = masked::pit_generate(&o, Solver::Trapezoidal { theta: 0.5 }, &g, &healthy, &mut r);
    assert_eq!(lane.outcome, PitOutcome::Exact);
}

#[test]
fn tol_acceptance_never_needs_more_sweeps_than_exact() {
    // tol > 0 accepts a superset of the stopping states (exact
    // convergence still short-circuits), so for identical streams the
    // within-tol run stops at or before the exact run's sweep count.
    let o = oracle(6, 16, 11);
    let steps = 12usize;
    let g = grid::masked_uniform(steps, 1e-3);
    let solver = Solver::Rk2 { theta: 0.5 };
    for seed in [2u64, 44, 777] {
        let exact_cfg = PitCfg::new(steps, 0.0);
        let mut r = Xoshiro256::seed_from_u64(seed);
        let exact = masked::pit_generate(&o, solver, &g, &exact_cfg, &mut r);
        assert_eq!(exact.outcome, PitOutcome::Exact);
        for tol in [1e-3, 1e-1] {
            let cfg = PitCfg::new(steps, tol);
            let mut r = Xoshiro256::seed_from_u64(seed);
            let lane = masked::pit_generate(&o, solver, &g, &cfg, &mut r);
            assert!(lane.outcome.converged(), "tol={tol} seed={seed}");
            assert!(
                lane.sweeps <= exact.sweeps,
                "tol={tol} seed={seed}: {} > exact {}",
                lane.sweeps,
                exact.sweeps
            );
        }
    }
}
