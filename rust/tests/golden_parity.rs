//! Golden parity suite for the kernel/driver refactor.
//!
//! The `legacy_*` modules below are verbatim copies of the PRE-refactor
//! drivers (`solvers/masked.rs`, `solvers/toy.rs`, `ctmc/uniformization.rs`
//! as of the schedule-subsystem PR), kept private to this test.  Every
//! public entry point must produce **bit-identical** token/state streams,
//! NFE/step statistics and adaptive traces against its legacy twin for
//! fixed seeds, across every (solver × family × fixed/adaptive ×
//! single/batch) combination — the refactor moves code, it must not move
//! a single RNG draw or floating-point operation.
//!
//! (Exception, by design: toy uniformization now answers the thinning
//! accept test with the closed-form total instead of the summed vector —
//! equal to the sum only up to the last ulp — so its parity here is via
//! the text-jump process, whose total IS the summed pass, plus the
//! `split_total_matches_full_fill` invariant in `ctmc::uniformization`.)
//!
//! The bracketed-thinning tests at the bottom additionally pin the new
//! free-reject bracket: jump streams bit-identical to the naive
//! always-evaluate loop (both the embedded legacy copy and the
//! `NoBracket` wrapper) across seeds × window ratios × slacks, with the
//! true evaluation NFE strictly dropping whenever the bracket fires.
//! Those sweeps run under debug_assertions (asserted below), so every
//! free reject is re-verified by a full evaluation as it happens.

use fastdds::schedule::adaptive::{
    AdaptiveController, NfeBudget, StepController,
};
use fastdds::score::hmm::HmmUniformOracle;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::solvers::{grid, masked, toy, Solver};
use fastdds::util::rng::Xoshiro256;

// ===========================================================================
// Legacy masked drivers (pre-refactor solvers/masked.rs, verbatim)
// ===========================================================================
mod legacy_masked {
    use fastdds::schedule::adaptive::{
        rk2_gate_discrepancy, trap_gate_discrepancy, AdaptiveTrace, StepController,
    };
    use fastdds::score::{ScoreSource, Tok};
    use fastdds::solvers::{GenStats, Solver};
    use fastdds::util::dist::categorical;
    use fastdds::util::rng::{Rng, Xoshiro256};
    use fastdds::util::threadpool::{par_zip_mut2, ThreadPool};

    struct Scratch {
        probs: Vec<f64>,
        probs_star: Vec<f64>,
    }

    impl Scratch {
        fn new(l: usize, v: usize) -> Self {
            Self {
                probs: vec![0.0; l * v],
                probs_star: vec![0.0; l * v],
            }
        }
    }

    struct LaneState {
        tokens: Vec<Tok>,
        active: Vec<usize>,
        sub: Vec<usize>,
        comb: Vec<f64>,
        scored: Vec<(f64, usize, Tok)>,
        stats: GenStats,
    }

    impl LaneState {
        fn new(l: usize, v: usize, mask: Tok) -> Self {
            Self {
                tokens: vec![mask; l],
                active: (0..l).collect(),
                sub: Vec::with_capacity(l),
                comb: vec![0.0; v],
                scored: Vec::with_capacity(l),
                stats: GenStats::default(),
            }
        }
    }

    fn validate_solver(solver: Solver) {
        match solver {
            Solver::Trapezoidal { theta } => {
                assert!(theta > 0.0 && theta < 1.0, "trapezoidal needs theta in (0,1)");
            }
            Solver::Rk2 { theta } => {
                assert!(theta > 0.0 && theta <= 1.0, "rk2 needs theta in (0,1]");
            }
            _ => {}
        }
    }

    pub fn generate<S: ScoreSource + ?Sized, R: Rng>(
        score: &S,
        solver: Solver,
        grid: &[f64],
        rng: &mut R,
    ) -> (Vec<Tok>, GenStats) {
        assert!(fastdds::solvers::grid::is_valid_grid(grid), "invalid time grid");
        validate_solver(solver);
        let l = score.seq_len();
        let v = score.vocab();
        let mask = score.mask_id();
        let mut st = LaneState::new(l, v, mask);
        let mut sc = Scratch::new(l, v);

        match solver {
            Solver::ParallelDecoding => {
                let n_steps = grid.len() - 1;
                for n in 0..n_steps {
                    if st.active.is_empty() {
                        break;
                    }
                    let (k_reveal, t) = pd_schedule(l, st.active.len(), n, n_steps);
                    if k_reveal == 0 {
                        continue;
                    }
                    let m = st.active.len();
                    score.probs_masked_into(&st.tokens, &st.active, t, &mut sc.probs[..m * v]);
                    st.stats.nfe += 1;
                    st.stats.steps += 1;
                    pd_apply(v, mask, t, k_reveal, &sc.probs, &mut st, rng);
                }
            }
            _ => {
                for w in grid.windows(2) {
                    let (t, t_next) = (w[0], w[1]);
                    let m = st.active.len();
                    if m > 0 {
                        score.probs_masked_into(&st.tokens, &st.active, t, &mut sc.probs[..m * v]);
                        apply_stage1(solver, v, t, t_next, &mut st, &mut sc, rng);
                        if solver.nfe_per_step() == 2 {
                            if !st.sub.is_empty() {
                                let rho = stage2_time(solver, t, t_next);
                                let m2 = st.sub.len();
                                score.probs_masked_into(
                                    &st.tokens,
                                    &st.sub,
                                    rho,
                                    &mut sc.probs_star[..m2 * v],
                                );
                            }
                            apply_stage2(solver, v, mask, t, t_next, &mut st, &mut sc, rng);
                        }
                    }
                    st.stats.steps += 1;
                }
            }
        }

        finalize(score, *grid.last().unwrap(), &mut st, &mut sc.probs, rng);
        (st.tokens, st.stats)
    }

    struct BatchLane {
        state: LaneState,
        rng: Xoshiro256,
    }

    enum Sel {
        Active,
        Sub,
        Pd { n: usize, n_steps: usize },
    }

    fn selected<'a>(sel: &Sel, st: &'a LaneState) -> Option<&'a [usize]> {
        match sel {
            Sel::Active => (!st.active.is_empty()).then(|| st.active.as_slice()),
            Sel::Sub => (!st.sub.is_empty()).then(|| st.sub.as_slice()),
            Sel::Pd { n, n_steps } => {
                if st.active.is_empty() {
                    return None;
                }
                let (k, _) = pd_schedule(st.tokens.len(), st.active.len(), *n, *n_steps);
                (k > 0).then(|| st.active.as_slice())
            }
        }
    }

    fn eval_stage<S: ScoreSource + ?Sized>(
        score: &S,
        lanes: &[BatchLane],
        bufs: &mut [Scratch],
        t: f64,
        sel: &Sel,
        star: bool,
    ) {
        let v = score.vocab();
        let mut reqs: Vec<(&[Tok], &[usize])> = Vec::new();
        let mut outs: Vec<&mut [f64]> = Vec::new();
        for (lane, sc) in lanes.iter().zip(bufs.iter_mut()) {
            let Some(idx) = selected(sel, &lane.state) else {
                continue;
            };
            let buf = if star { &mut sc.probs_star } else { &mut sc.probs };
            reqs.push((lane.state.tokens.as_slice(), idx));
            outs.push(&mut buf[..idx.len() * v]);
        }
        if !reqs.is_empty() {
            score.probs_masked_batch(&reqs, t, &mut outs);
        }
    }

    pub fn generate_batch<S: ScoreSource + ?Sized>(
        score: &S,
        solver: Solver,
        grid: &[f64],
        seeds: &[u64],
    ) -> Vec<(Vec<Tok>, GenStats)> {
        assert!(fastdds::solvers::grid::is_valid_grid(grid), "invalid time grid");
        validate_solver(solver);
        if seeds.is_empty() {
            return Vec::new();
        }
        let l = score.seq_len();
        let v = score.vocab();
        let mask = score.mask_id();
        let threads = ThreadPool::default_size().min(seeds.len());

        let mut lanes: Vec<BatchLane> = seeds
            .iter()
            .map(|&s| BatchLane {
                state: LaneState::new(l, v, mask),
                rng: Xoshiro256::seed_from_u64(s),
            })
            .collect();
        let mut bufs: Vec<Scratch> = seeds.iter().map(|_| Scratch::new(l, v)).collect();

        match solver {
            Solver::ParallelDecoding => {
                let n_steps = grid.len() - 1;
                for n in 0..n_steps {
                    let t = pd_time(n, n_steps);
                    eval_stage(score, &lanes, &mut bufs, t, &Sel::Pd { n, n_steps }, false);
                    par_zip_mut2(&mut lanes, &mut bufs, threads, |_, lane, sc| {
                        let st = &mut lane.state;
                        if st.active.is_empty() {
                            return;
                        }
                        let (k_reveal, t) = pd_schedule(l, st.active.len(), n, n_steps);
                        if k_reveal == 0 {
                            return;
                        }
                        st.stats.nfe += 1;
                        st.stats.steps += 1;
                        pd_apply(v, mask, t, k_reveal, &sc.probs, st, &mut lane.rng);
                    });
                }
            }
            _ => {
                for w in grid.windows(2) {
                    let (t, t_next) = (w[0], w[1]);
                    eval_stage(score, &lanes, &mut bufs, t, &Sel::Active, false);
                    par_zip_mut2(&mut lanes, &mut bufs, threads, |_, lane, sc| {
                        if !lane.state.active.is_empty() {
                            apply_stage1(solver, v, t, t_next, &mut lane.state, sc, &mut lane.rng);
                        }
                    });
                    if solver.nfe_per_step() == 2 {
                        let rho = stage2_time(solver, t, t_next);
                        eval_stage(score, &lanes, &mut bufs, rho, &Sel::Sub, true);
                        par_zip_mut2(&mut lanes, &mut bufs, threads, |_, lane, sc| {
                            if !lane.state.active.is_empty() {
                                apply_stage2(
                                    solver,
                                    v,
                                    mask,
                                    t,
                                    t_next,
                                    &mut lane.state,
                                    sc,
                                    &mut lane.rng,
                                );
                            }
                        });
                    }
                    for lane in &mut lanes {
                        lane.state.stats.steps += 1;
                    }
                }
            }
        }

        let delta = *grid.last().unwrap();
        eval_stage(score, &lanes, &mut bufs, delta, &Sel::Active, false);
        par_zip_mut2(&mut lanes, &mut bufs, threads, |_, lane, sc| {
            let st = &mut lane.state;
            if st.active.is_empty() {
                return;
            }
            st.stats.nfe += 1;
            finalize_apply(v, &sc.probs, st, &mut lane.rng);
        });

        lanes
            .into_iter()
            .map(|lane| (lane.state.tokens, lane.state.stats))
            .collect()
    }

    fn lane_step_error(
        solver: Solver,
        v: usize,
        t: f64,
        t_next: f64,
        st: &LaneState,
        sc: &Scratch,
    ) -> f64 {
        let dt = t - t_next;
        let rho = stage2_time(solver, t, t_next);
        let mu_tot = 1.0 / t;
        match solver {
            Solver::Trapezoidal { theta } => {
                let a1 = 1.0 / (2.0 * theta * (1.0 - theta));
                let a2 = a1 - 1.0;
                let mut err = 0.0f64;
                for j in 0..st.sub.len() {
                    let mut tot = 0.0;
                    for c in 0..v {
                        let mu_star = sc.probs_star[j * v + c] / rho;
                        let mu_t = sc.probs[j * v + c] / t;
                        tot += (a1 * mu_star - a2 * mu_t).max(0.0);
                    }
                    err = err.max(trap_gate_discrepancy(theta, dt, mu_tot, tot));
                }
                err
            }
            Solver::Rk2 { theta } => {
                let w_coef = 1.0 / (2.0 * theta);
                let mut err = 0.0f64;
                let mut j = 0usize;
                for (k, &i) in st.active.iter().enumerate() {
                    let star = j < st.sub.len() && st.sub[j] == i;
                    let mut tot = 0.0;
                    for c in 0..v {
                        let mu_t = sc.probs[k * v + c] / t;
                        let mu_star = if star {
                            sc.probs_star[j * v + c] / rho
                        } else {
                            0.0
                        };
                        tot += ((1.0 - w_coef) * mu_t + w_coef * mu_star).max(0.0);
                    }
                    if star {
                        j += 1;
                    }
                    err = err.max(rk2_gate_discrepancy(dt, mu_tot, tot));
                }
                err
            }
            _ => unreachable!("error estimator needs a two-stage solver"),
        }
    }

    fn validate_adaptive(solver: Solver, delta: f64) {
        validate_solver(solver);
        assert!(solver.nfe_per_step() == 2);
        assert!((0.0..1.0).contains(&delta) && delta > 0.0);
    }

    pub fn generate_adaptive<S: ScoreSource + ?Sized, R: Rng>(
        score: &S,
        solver: Solver,
        mut ctl: StepController,
        delta: f64,
        rng: &mut R,
    ) -> (Vec<Tok>, GenStats, AdaptiveTrace) {
        validate_adaptive(solver, delta);
        let v = score.vocab();
        let mask = score.mask_id();
        let mut st = LaneState::new(score.seq_len(), v, mask);
        let mut sc = Scratch::new(score.seq_len(), v);
        let mut trace = AdaptiveTrace { grid: vec![1.0], errors: Vec::new() };
        let mut t = 1.0f64;

        while let Some(dt) = ctl.propose_dt(t, delta, st.stats.nfe) {
            let t_next = if dt >= t - delta { delta } else { t - dt };
            let m = st.active.len();
            let mut err = 0.0;
            if m > 0 {
                score.probs_masked_into(&st.tokens, &st.active, t, &mut sc.probs[..m * v]);
                apply_stage1(solver, v, t, t_next, &mut st, &mut sc, rng);
                if !st.sub.is_empty() {
                    let rho = stage2_time(solver, t, t_next);
                    let m2 = st.sub.len();
                    score.probs_masked_into(
                        &st.tokens,
                        &st.sub,
                        rho,
                        &mut sc.probs_star[..m2 * v],
                    );
                }
                err = lane_step_error(solver, v, t, t_next, &st, &sc);
                apply_stage2(solver, v, mask, t, t_next, &mut st, &mut sc, rng);
            }
            st.stats.steps += 1;
            trace.grid.push(t_next);
            trace.errors.push(err);
            ctl.observe(err);
            t = t_next;
            if st.active.is_empty() {
                break;
            }
        }

        finalize(score, t, &mut st, &mut sc.probs, rng);
        (st.tokens, st.stats, trace)
    }

    pub fn generate_batch_adaptive<S: ScoreSource + ?Sized>(
        score: &S,
        solver: Solver,
        mut ctl: StepController,
        delta: f64,
        seeds: &[u64],
    ) -> (Vec<(Vec<Tok>, GenStats)>, AdaptiveTrace) {
        validate_adaptive(solver, delta);
        if seeds.is_empty() {
            return (Vec::new(), AdaptiveTrace::default());
        }
        let l = score.seq_len();
        let v = score.vocab();
        let mask = score.mask_id();
        let threads = ThreadPool::default_size().min(seeds.len());
        let mut lanes: Vec<BatchLane> = seeds
            .iter()
            .map(|&s| BatchLane {
                state: LaneState::new(l, v, mask),
                rng: Xoshiro256::seed_from_u64(s),
            })
            .collect();
        let mut bufs: Vec<Scratch> = seeds.iter().map(|_| Scratch::new(l, v)).collect();
        let mut trace = AdaptiveTrace { grid: vec![1.0], errors: Vec::new() };
        let mut t = 1.0f64;

        loop {
            let spent = lanes.iter().map(|l| l.state.stats.nfe).max().unwrap_or(0);
            let Some(dt) = ctl.propose_dt(t, delta, spent) else { break };
            let t_next = if dt >= t - delta { delta } else { t - dt };
            eval_stage(score, &lanes, &mut bufs, t, &Sel::Active, false);
            par_zip_mut2(&mut lanes, &mut bufs, threads, |_, lane, sc| {
                if !lane.state.active.is_empty() {
                    apply_stage1(solver, v, t, t_next, &mut lane.state, sc, &mut lane.rng);
                }
            });
            let rho = stage2_time(solver, t, t_next);
            eval_stage(score, &lanes, &mut bufs, rho, &Sel::Sub, true);
            let mut err = 0.0f64;
            for (lane, sc) in lanes.iter().zip(&bufs) {
                if !lane.state.active.is_empty() {
                    err = err.max(lane_step_error(solver, v, t, t_next, &lane.state, sc));
                }
            }
            par_zip_mut2(&mut lanes, &mut bufs, threads, |_, lane, sc| {
                if !lane.state.active.is_empty() {
                    apply_stage2(solver, v, mask, t, t_next, &mut lane.state, sc, &mut lane.rng);
                }
            });
            for lane in &mut lanes {
                lane.state.stats.steps += 1;
            }
            trace.grid.push(t_next);
            trace.errors.push(err);
            ctl.observe(err);
            t = t_next;
            if lanes.iter().all(|l| l.state.active.is_empty()) {
                break;
            }
        }

        eval_stage(score, &lanes, &mut bufs, t, &Sel::Active, false);
        par_zip_mut2(&mut lanes, &mut bufs, threads, |_, lane, sc| {
            let st = &mut lane.state;
            if st.active.is_empty() {
                return;
            }
            st.stats.nfe += 1;
            finalize_apply(v, &sc.probs, st, &mut lane.rng);
        });

        (
            lanes
                .into_iter()
                .map(|lane| (lane.state.tokens, lane.state.stats))
                .collect(),
            trace,
        )
    }

    #[derive(Clone, Copy)]
    enum Gate {
        Linear,
        Poisson,
        Exact,
    }

    impl Gate {
        #[inline]
        fn prob(self, t: f64, t_next: f64) -> f64 {
            let dt = t - t_next;
            match self {
                Gate::Linear => (dt / t).min(1.0),
                Gate::Poisson => 1.0 - (-dt / t).exp(),
                Gate::Exact => dt / t,
            }
        }
    }

    fn stage2_time(solver: Solver, t: f64, t_next: f64) -> f64 {
        match solver {
            Solver::Trapezoidal { theta } | Solver::Rk2 { theta } => t - theta * (t - t_next),
            _ => unreachable!("stage2_time on a one-stage solver"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn apply_stage1<R: Rng>(
        solver: Solver,
        v: usize,
        t: f64,
        t_next: f64,
        st: &mut LaneState,
        sc: &mut Scratch,
        rng: &mut R,
    ) {
        debug_assert!(!st.active.is_empty());
        st.stats.nfe += 1;
        let dt = t - t_next;
        match solver {
            Solver::Euler | Solver::TauLeaping | Solver::Tweedie => {
                st.sub.clear();
                let gate = match solver {
                    Solver::Euler => Gate::Linear,
                    Solver::TauLeaping => Gate::Poisson,
                    _ => Gate::Exact,
                };
                one_stage_apply(
                    v,
                    gate.prob(t, t_next),
                    &sc.probs,
                    &mut st.tokens,
                    &mut st.active,
                    rng,
                );
            }
            Solver::Trapezoidal { theta } => {
                let p1 = 1.0 - (-(theta * dt) / t).exp();
                st.sub.clear();
                for k in 0..st.active.len() {
                    let i = st.active[k];
                    let mut still_masked = true;
                    if rng.gen_f64() < p1 {
                        if let Some(tok) = categorical(rng, &sc.probs[k * v..(k + 1) * v]) {
                            st.tokens[i] = tok as Tok;
                            still_masked = false;
                        }
                    }
                    if still_masked {
                        let w = st.sub.len();
                        if w != k {
                            sc.probs.copy_within(k * v..(k + 1) * v, w * v);
                        }
                        st.sub.push(i);
                    }
                }
            }
            Solver::Rk2 { theta } => {
                let p1 = 1.0 - (-(theta * dt) / t).exp();
                st.sub.clear();
                for (k, &i) in st.active.iter().enumerate() {
                    let mut still_masked = true;
                    if rng.gen_f64() < p1 {
                        if let Some(tok) = categorical(rng, &sc.probs[k * v..(k + 1) * v]) {
                            st.tokens[i] = tok as Tok;
                            still_masked = false;
                        }
                    }
                    if still_masked {
                        st.sub.push(i);
                    }
                }
            }
            _ => unreachable!("apply_stage1 covers the approximate kernels"),
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn apply_stage2<R: Rng>(
        solver: Solver,
        v: usize,
        mask: Tok,
        t: f64,
        t_next: f64,
        st: &mut LaneState,
        sc: &mut Scratch,
        rng: &mut R,
    ) {
        let dt = t - t_next;
        let rho = stage2_time(solver, t, t_next);
        match solver {
            Solver::Trapezoidal { theta } => {
                if st.sub.is_empty() {
                    st.active.clear();
                    return;
                }
                st.stats.nfe += 1;
                let a1 = 1.0 / (2.0 * theta * (1.0 - theta));
                let a2 = a1 - 1.0;
                let tail = (1.0 - theta) * dt;
                st.active.clear();
                for j in 0..st.sub.len() {
                    let i = st.sub[j];
                    let mut tot = 0.0;
                    for c in 0..v {
                        let mu_star = sc.probs_star[j * v + c] / rho;
                        let mu_t = sc.probs[j * v + c] / t;
                        let m = (a1 * mu_star - a2 * mu_t).max(0.0);
                        st.comb[c] = m;
                        tot += m;
                    }
                    let p2 = 1.0 - (-tot * tail).exp();
                    let mut still_masked = true;
                    if rng.gen_f64() < p2 {
                        if let Some(tok) = categorical(rng, &st.comb) {
                            st.tokens[i] = tok as Tok;
                            still_masked = false;
                        }
                    }
                    if still_masked {
                        st.active.push(i);
                    }
                }
                st.sub.clear();
            }
            Solver::Rk2 { theta } => {
                if !st.sub.is_empty() {
                    st.stats.nfe += 1;
                }
                let w_coef = 1.0 / (2.0 * theta);
                for &i in st.active.iter() {
                    st.tokens[i] = mask;
                }
                let m = st.active.len();
                let mut j = 0usize;
                let mut w = 0usize;
                for k in 0..m {
                    let i = st.active[k];
                    let star = j < st.sub.len() && st.sub[j] == i;
                    let mut tot = 0.0;
                    for c in 0..v {
                        let mu_t = sc.probs[k * v + c] / t;
                        let mu_star = if star {
                            sc.probs_star[j * v + c] / rho
                        } else {
                            0.0
                        };
                        let mc = ((1.0 - w_coef) * mu_t + w_coef * mu_star).max(0.0);
                        st.comb[c] = mc;
                        tot += mc;
                    }
                    if star {
                        j += 1;
                    }
                    let p2 = 1.0 - (-tot * dt).exp();
                    let mut still_masked = true;
                    if rng.gen_f64() < p2 {
                        if let Some(tok) = categorical(rng, &st.comb) {
                            st.tokens[i] = tok as Tok;
                            still_masked = false;
                        }
                    }
                    if still_masked {
                        st.active[w] = i;
                        w += 1;
                    }
                }
                st.active.truncate(w);
                st.sub.clear();
            }
            _ => unreachable!("apply_stage2 on a one-stage solver"),
        }
    }

    fn one_stage_apply<R: Rng>(
        v: usize,
        p_gate: f64,
        probs: &[f64],
        tokens: &mut [Tok],
        active: &mut Vec<usize>,
        rng: &mut R,
    ) {
        let m = active.len();
        let mut w = 0usize;
        for k in 0..m {
            let i = active[k];
            let mut still_masked = true;
            if rng.gen_f64() < p_gate {
                if let Some(tok) = categorical(rng, &probs[k * v..(k + 1) * v]) {
                    tokens[i] = tok as Tok;
                    still_masked = false;
                }
            }
            if still_masked {
                active[w] = i;
                w += 1;
            }
        }
        active.truncate(w);
    }

    fn pd_schedule(l: usize, m: usize, n: usize, n_steps: usize) -> (usize, f64) {
        let frac = (n + 1) as f64 / n_steps as f64;
        let target = if n + 1 == n_steps {
            0
        } else {
            ((std::f64::consts::FRAC_PI_2 * frac).cos() * l as f64).ceil() as usize
        };
        (m.saturating_sub(target), pd_time(n, n_steps))
    }

    fn pd_time(n: usize, n_steps: usize) -> f64 {
        1.0 - n as f64 / n_steps as f64
    }

    #[allow(clippy::too_many_arguments)]
    fn pd_apply<R: Rng>(
        v: usize,
        mask: Tok,
        t: f64,
        k_reveal: usize,
        probs: &[f64],
        st: &mut LaneState,
        rng: &mut R,
    ) {
        st.scored.clear();
        for (k, &i) in st.active.iter().enumerate() {
            let row = &probs[k * v..(k + 1) * v];
            let tok = categorical(rng, row).unwrap_or(0);
            let conf = row[tok].max(1e-30).ln() + t * fastdds::util::dist::gumbel(rng, 1e-9);
            st.scored.push((conf, i, tok as Tok));
        }
        st.scored.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(_, i, tok) in st.scored.iter().take(k_reveal) {
            st.tokens[i] = tok;
        }
        let tokens = &st.tokens;
        st.active.retain(|&i| tokens[i] == mask);
    }

    fn finalize<S: ScoreSource + ?Sized, R: Rng>(
        score: &S,
        delta: f64,
        st: &mut LaneState,
        probs: &mut Vec<f64>,
        rng: &mut R,
    ) {
        if st.active.is_empty() {
            return;
        }
        let v = score.vocab();
        let m = st.active.len();
        if probs.len() < m * v {
            probs.resize(m * v, 0.0);
        }
        score.probs_masked_into(&st.tokens, &st.active, delta, &mut probs[..m * v]);
        st.stats.nfe += 1;
        finalize_apply(v, probs, st, rng);
    }

    fn finalize_apply<R: Rng>(v: usize, probs: &[f64], st: &mut LaneState, rng: &mut R) {
        for (k, &i) in st.active.iter().enumerate() {
            let row = &probs[k * v..(k + 1) * v];
            if let Some(tok) = categorical(rng, row) {
                st.tokens[i] = tok as Tok;
            } else {
                st.tokens[i] = rng.gen_usize(v) as Tok;
            }
        }
        st.active.clear();
    }

    pub fn fhs_generate<S: ScoreSource + ?Sized, R: Rng>(
        score: &S,
        delta: f64,
        rng: &mut R,
    ) -> (Vec<Tok>, GenStats, Vec<f64>) {
        let l = score.seq_len();
        let v = score.vocab();
        let mask = score.mask_id();
        let mut st = LaneState::new(l, v, mask);
        let mut jump_times = Vec::with_capacity(l);
        let mut row = vec![0.0; v];

        let mut t = 1.0;
        loop {
            if st.active.is_empty() {
                break;
            }
            let m = st.active.len() as f64;
            t *= rng.gen_f64().powf(1.0 / m);
            if t <= delta {
                break;
            }
            let pos = rng.gen_usize(st.active.len());
            let i = st.active[pos];
            score.probs_masked_into(&st.tokens, &st.active[pos..pos + 1], t, &mut row);
            st.stats.nfe += 1;
            st.stats.steps += 1;
            if let Some(tok) = categorical(rng, &row) {
                st.tokens[i] = tok as Tok;
                st.active.remove(pos);
            }
            jump_times.push(t);
        }
        finalize(score, delta, &mut st, &mut row, rng);
        (st.tokens, st.stats, jump_times)
    }
}

// ===========================================================================
// Legacy toy drivers (pre-refactor solvers/toy.rs, verbatim)
// ===========================================================================
mod legacy_toy {
    use fastdds::ctmc::ToyModel;
    use fastdds::schedule::adaptive::{
        rk2_gate_discrepancy, trap_gate_discrepancy, AdaptiveTrace, StepController,
    };
    use fastdds::solvers::{GenStats, Solver};
    use fastdds::util::dist::categorical_f64;
    use fastdds::util::rng::Rng;

    fn sub_step<R: Rng>(
        model: &ToyModel,
        x: usize,
        mu: &[f64],
        dt: f64,
        poisson_gate: bool,
        rng: &mut R,
    ) -> usize {
        let tot: f64 = mu.iter().sum();
        if tot <= 0.0 {
            return x;
        }
        let p = if poisson_gate {
            1.0 - (-tot * dt).exp()
        } else {
            (tot * dt).min(1.0)
        };
        if rng.gen_f64() < p {
            let nu = categorical_f64(rng, mu);
            (x + nu) % model.n_states()
        } else {
            x
        }
    }

    pub fn step<R: Rng>(
        model: &ToyModel,
        solver: Solver,
        x: usize,
        t: f64,
        t_next: f64,
        rng: &mut R,
    ) -> usize {
        let s = model.n_states();
        let mut mu = vec![0.0; s];
        let dt = t - t_next;
        match solver {
            Solver::Euler => {
                model.reverse_intensities(x, t, &mut mu);
                sub_step(model, x, &mu, dt, false, rng)
            }
            Solver::TauLeaping | Solver::Tweedie => {
                model.reverse_intensities(x, t, &mut mu);
                sub_step(model, x, &mu, dt, true, rng)
            }
            Solver::Trapezoidal { .. } | Solver::Rk2 { .. } => {
                two_stage_step(model, solver, x, t, t_next, rng).0
            }
            _ => panic!("legacy toy step: unsupported solver"),
        }
    }

    fn two_stage_step<R: Rng>(
        model: &ToyModel,
        solver: Solver,
        x: usize,
        t: f64,
        t_next: f64,
        rng: &mut R,
    ) -> (usize, f64, f64) {
        let s = model.n_states();
        let mut mu = vec![0.0; s];
        let dt = t - t_next;
        match solver {
            Solver::Trapezoidal { theta } => {
                assert!(theta > 0.0 && theta < 1.0);
                let rho = t - theta * dt;
                let a1 = 1.0 / (2.0 * theta * (1.0 - theta));
                let a2 = a1 - 1.0;
                model.reverse_intensities(x, t, &mut mu);
                let y_star = sub_step(model, x, &mu, theta * dt, true, rng);
                let mut mu_star = vec![0.0; s];
                model.reverse_intensities(y_star, rho, &mut mu_star);
                let mut comb = vec![0.0; s];
                for nu in 0..s {
                    comb[nu] = (a1 * mu_star[nu] - a2 * mu[nu]).max(0.0);
                }
                let y = sub_step(model, y_star, &comb, (1.0 - theta) * dt, true, rng);
                (y, mu.iter().sum(), comb.iter().sum())
            }
            Solver::Rk2 { theta } => {
                assert!(theta > 0.0 && theta <= 1.0);
                let rho = t - theta * dt;
                let w = 1.0 / (2.0 * theta);
                model.reverse_intensities(x, t, &mut mu);
                let y_star = sub_step(model, x, &mu, theta * dt, true, rng);
                let mut mu_star = vec![0.0; s];
                model.reverse_intensities(y_star, rho, &mut mu_star);
                let mut comb = vec![0.0; s];
                for nu in 0..s {
                    comb[nu] = ((1.0 - w) * mu[nu] + w * mu_star[nu]).max(0.0);
                }
                let y = sub_step(model, x, &comb, dt, true, rng);
                (y, mu.iter().sum(), comb.iter().sum())
            }
            _ => unreachable!("two_stage_step needs a θ-scheme"),
        }
    }

    pub fn generate<R: Rng>(
        model: &ToyModel,
        solver: Solver,
        grid: &[f64],
        rng: &mut R,
    ) -> usize {
        assert!(fastdds::solvers::grid::is_valid_grid(grid));
        let mut x = model.sample_stationary(rng);
        for w in grid.windows(2) {
            x = step(model, solver, x, w[0], w[1], rng);
        }
        x
    }

    pub fn generate_adaptive<R: Rng>(
        model: &ToyModel,
        solver: Solver,
        mut ctl: StepController,
        delta: f64,
        rng: &mut R,
    ) -> (usize, GenStats, AdaptiveTrace) {
        assert!(matches!(solver, Solver::Trapezoidal { .. } | Solver::Rk2 { .. }));
        assert!(delta > 0.0 && delta < model.horizon);
        let mut x = model.sample_stationary(rng);
        let mut t = model.horizon;
        let mut stats = GenStats::default();
        let mut trace = AdaptiveTrace { grid: vec![t], errors: Vec::new() };
        while let Some(dt) = ctl.propose_dt(t, delta, stats.nfe) {
            let t_next = if dt >= t - delta { delta } else { t - dt };
            let (nx, tot_mu, tot_comb) = two_stage_step(model, solver, x, t, t_next, rng);
            x = nx;
            stats.nfe += 2;
            stats.steps += 1;
            let err = match solver {
                Solver::Trapezoidal { theta } => {
                    trap_gate_discrepancy(theta, t - t_next, tot_mu, tot_comb)
                }
                Solver::Rk2 { .. } => rk2_gate_discrepancy(t - t_next, tot_mu, tot_comb),
                _ => unreachable!(),
            };
            trace.grid.push(t_next);
            trace.errors.push(err);
            ctl.observe(err);
            t = t_next;
        }
        (x, stats, trace)
    }
}

// ===========================================================================
// Legacy uniformization (pre-refactor ctmc/uniformization.rs, verbatim)
// ===========================================================================
mod legacy_uniformization {
    use fastdds::util::dist::{categorical_f64, exponential};
    use fastdds::util::rng::Rng;

    pub trait JumpProcess {
        type State: Clone;
        fn n_jumps(&self) -> usize;
        fn intensities(&self, x: &Self::State, t: f64, out: &mut [f64]);
        fn total_bound(&self, x: &Self::State, t_lo: f64, t_hi: f64) -> f64;
        fn apply(&self, x: &mut Self::State, nu: usize);
    }

    #[derive(Clone, Debug, Default)]
    pub struct ExactStats {
        pub nfe: usize,
        pub jumps: Vec<(f64, usize)>,
        pub candidates: Vec<f64>,
    }

    pub fn simulate_backward<P: JumpProcess, R: Rng>(
        proc: &P,
        x0: P::State,
        t_start: f64,
        t_end: f64,
        window_ratio: f64,
        rng: &mut R,
    ) -> (P::State, ExactStats) {
        assert!(t_end > 0.0 && t_end < t_start);
        assert!(window_ratio > 0.0 && window_ratio < 1.0);
        let mut x = x0;
        let mut stats = ExactStats::default();
        let mut mu = vec![0.0; proc.n_jumps()];

        let mut t_hi = t_start;
        while t_hi > t_end {
            let t_lo = (t_hi * window_ratio).max(t_end);
            let bound = proc.total_bound(&x, t_lo, t_hi).max(1e-12);
            let mut t = t_hi;
            loop {
                t -= exponential(rng, bound);
                if t <= t_lo {
                    break;
                }
                proc.intensities(&x, t, &mut mu);
                stats.nfe += 1;
                stats.candidates.push(t);
                let tot: f64 = mu.iter().sum();
                if rng.gen_f64() * bound < tot {
                    let nu = categorical_f64(rng, &mu);
                    proc.apply(&mut x, nu);
                    stats.jumps.push((t, nu));
                    t_hi = t;
                    break;
                }
            }
            if t <= t_lo {
                t_hi = t_lo;
            }
        }
        (x, stats)
    }

    /// Legacy text jump: allocates per window, sums the vector per candidate.
    pub struct LegacyTextJump<'a> {
        pub oracle: &'a fastdds::score::hmm::HmmUniformOracle,
        pub slack: f64,
    }

    impl JumpProcess for LegacyTextJump<'_> {
        type State = Vec<fastdds::score::Tok>;

        fn n_jumps(&self) -> usize {
            self.oracle.seq_len * self.oracle.chain.vocab
        }

        fn intensities(&self, x: &Self::State, t: f64, out: &mut [f64]) {
            self.oracle.intensities(x, t, out);
        }

        fn total_bound(&self, x: &Self::State, t_lo: f64, _t_hi: f64) -> f64 {
            let mut buf = vec![0.0; self.n_jumps()];
            let tot = self.oracle.intensities(x, t_lo, &mut buf);
            tot * self.slack
        }

        fn apply(&self, x: &mut Self::State, nu: usize) {
            let v = self.oracle.chain.vocab;
            x[nu / v] = (nu % v) as fastdds::score::Tok;
        }
    }
}

// ===========================================================================
// Parity assertions
// ===========================================================================

fn oracle(vocab: usize, seq_len: usize, seed: u64) -> MarkovOracle {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    MarkovOracle::new(MarkovChain::generate(&mut rng, vocab, 0.5), seq_len)
}

fn approx_solvers() -> Vec<Solver> {
    vec![
        Solver::Euler,
        Solver::TauLeaping,
        Solver::Tweedie,
        Solver::Trapezoidal { theta: 0.5 },
        Solver::Trapezoidal { theta: 0.3 },
        Solver::Rk2 { theta: 0.5 },
        Solver::Rk2 { theta: 0.3 },
        Solver::ParallelDecoding,
    ]
}

#[test]
fn masked_fixed_single_parity() {
    let o = oracle(6, 16, 11);
    for steps in [4usize, 12] {
        let g = grid::masked_uniform(steps, 1e-3);
        for solver in approx_solvers() {
            for seed in [0u64, 7, 99, 12345] {
                let mut r_new = Xoshiro256::seed_from_u64(seed);
                let mut r_old = Xoshiro256::seed_from_u64(seed);
                let (toks, stats) = masked::generate(&o, solver, &g, &mut r_new);
                let (want, wstats) = legacy_masked::generate(&o, solver, &g, &mut r_old);
                assert_eq!(toks, want, "{} steps={steps} seed={seed}", solver.name());
                assert_eq!(stats.nfe, wstats.nfe, "{} nfe", solver.name());
                assert_eq!(stats.steps, wstats.steps, "{} steps", solver.name());
            }
        }
    }
}

#[test]
fn masked_fixed_batch_parity() {
    let o = oracle(6, 16, 11);
    let g = grid::masked_uniform(10, 1e-3);
    let seeds = [3u64, 141, 59, 2653, 0];
    for solver in approx_solvers() {
        let new = masked::generate_batch(&o, solver, &g, &seeds);
        let old = legacy_masked::generate_batch(&o, solver, &g, &seeds);
        assert_eq!(new.len(), old.len());
        for (k, (n, w)) in new.iter().zip(&old).enumerate() {
            assert_eq!(n.0, w.0, "{} lane {k} tokens", solver.name());
            assert_eq!(n.1.nfe, w.1.nfe, "{} lane {k} nfe", solver.name());
            assert_eq!(n.1.steps, w.1.steps, "{} lane {k} steps", solver.name());
        }
    }
}

#[test]
fn masked_adaptive_single_parity() {
    let o = oracle(6, 16, 11);
    for solver in [
        Solver::Trapezoidal { theta: 0.5 },
        Solver::Trapezoidal { theta: 0.3 },
        Solver::Rk2 { theta: 0.4 },
    ] {
        for tol in [1e-2, 1e-3] {
            let cfg = AdaptiveController::for_span(tol, 1.0, 1e-3);
            let mut r_new = Xoshiro256::seed_from_u64(21);
            let mut r_old = Xoshiro256::seed_from_u64(21);
            let (toks, stats, trace) =
                masked::generate_adaptive(&o, solver, StepController::new(cfg, 0.1), 1e-3, &mut r_new);
            let (want, wstats, wtrace) = legacy_masked::generate_adaptive(
                &o,
                solver,
                StepController::new(cfg, 0.1),
                1e-3,
                &mut r_old,
            );
            assert_eq!(toks, want, "{} tol={tol}", solver.name());
            assert_eq!(stats.nfe, wstats.nfe);
            assert_eq!(stats.steps, wstats.steps);
            assert_eq!(trace.grid, wtrace.grid, "realized grids must match");
            assert_eq!(trace.errors, wtrace.errors, "error traces must match");
        }
    }
}

#[test]
fn masked_adaptive_batch_parity() {
    let o = oracle(6, 16, 11);
    let seeds = [5u64, 77, 901];
    let solver = Solver::Trapezoidal { theta: 0.5 };
    for budget in [None, Some(24usize)] {
        let mk_ctl = || {
            let cfg = AdaptiveController::for_span(1e-3, 1.0, 1e-3);
            let ctl = StepController::new(cfg, 0.1);
            match budget {
                Some(total) => ctl.with_budget(NfeBudget {
                    total,
                    nfe_per_step: 2,
                    reserve: 1,
                }),
                None => ctl,
            }
        };
        let (new, trace) =
            masked::generate_batch_adaptive(&o, solver, mk_ctl(), 1e-3, &seeds);
        let (old, wtrace) =
            legacy_masked::generate_batch_adaptive(&o, solver, mk_ctl(), 1e-3, &seeds);
        assert_eq!(trace.grid, wtrace.grid, "budget={budget:?}");
        assert_eq!(trace.errors, wtrace.errors);
        for (k, (n, w)) in new.iter().zip(&old).enumerate() {
            assert_eq!(n.0, w.0, "lane {k} budget={budget:?}");
            assert_eq!(n.1.nfe, w.1.nfe, "lane {k}");
            assert_eq!(n.1.steps, w.1.steps, "lane {k}");
        }
    }
}

#[test]
fn masked_hmm_source_parity() {
    // The time-dependent HMM score source exercises different eval times
    // per stage; parity must hold there too.
    let mut rng = Xoshiro256::seed_from_u64(17);
    let chain = MarkovChain::generate(&mut rng, 5, 0.6);
    let o = HmmUniformOracle::new(chain, 10);
    let g = grid::masked_uniform(8, 1e-3);
    for solver in [
        Solver::Tweedie,
        Solver::Trapezoidal { theta: 0.5 },
        Solver::Rk2 { theta: 0.3 },
    ] {
        let mut r_new = Xoshiro256::seed_from_u64(4);
        let mut r_old = Xoshiro256::seed_from_u64(4);
        let (toks, stats) = masked::generate(&o, solver, &g, &mut r_new);
        let (want, wstats) = legacy_masked::generate(&o, solver, &g, &mut r_old);
        assert_eq!(toks, want, "{}", solver.name());
        assert_eq!(stats.nfe, wstats.nfe);
    }
}

#[test]
fn fhs_parity() {
    let o = oracle(6, 16, 11);
    for seed in [0u64, 3, 888] {
        let mut r_new = Xoshiro256::seed_from_u64(seed);
        let mut r_old = Xoshiro256::seed_from_u64(seed);
        let (toks, stats, times) = masked::fhs_generate(&o, 1e-3, &mut r_new);
        let (want, wstats, wtimes) = legacy_masked::fhs_generate(&o, 1e-3, &mut r_old);
        assert_eq!(toks, want, "seed={seed}");
        assert_eq!(stats.nfe, wstats.nfe);
        assert_eq!(stats.steps, wstats.steps);
        assert_eq!(times, wtimes, "jump times must match bitwise");
    }
}

#[test]
fn toy_fixed_parity() {
    let mut mrng = Xoshiro256::seed_from_u64(7);
    let model = fastdds::ctmc::ToyModel::paper_default(&mut mrng);
    for steps in [8usize, 32] {
        let g = grid::toy_uniform(steps, model.horizon, 1e-3);
        for solver in [
            Solver::Euler,
            Solver::TauLeaping,
            Solver::Tweedie,
            Solver::Trapezoidal { theta: 0.5 },
            Solver::Rk2 { theta: 0.5 },
            Solver::Rk2 { theta: 0.9 }, // library-permissive θ past 1/2
        ] {
            // Share one stream across many reps so diverse states are hit;
            // a single divergence desynchronises everything after it.
            let mut r_new = Xoshiro256::seed_from_u64(13);
            let mut r_old = Xoshiro256::seed_from_u64(13);
            for rep in 0..200 {
                let x_new = toy::generate(&model, solver, &g, &mut r_new);
                let x_old = legacy_toy::generate(&model, solver, &g, &mut r_old);
                assert_eq!(x_new, x_old, "{} steps={steps} rep={rep}", solver.name());
            }
        }
    }
}

#[test]
fn toy_step_parity() {
    let mut mrng = Xoshiro256::seed_from_u64(7);
    let model = fastdds::ctmc::ToyModel::paper_default(&mut mrng);
    let mut r_new = Xoshiro256::seed_from_u64(2);
    let mut r_old = Xoshiro256::seed_from_u64(2);
    for solver in [
        Solver::Euler,
        Solver::TauLeaping,
        Solver::Trapezoidal { theta: 0.4 },
        Solver::Rk2 { theta: 0.5 },
    ] {
        for x in 0..model.n_states() {
            for &(t, t_next) in &[(6.0, 4.0), (1.0, 0.4), (0.2, 0.05)] {
                let a = toy::step(&model, solver, x, t, t_next, &mut r_new);
                let b = legacy_toy::step(&model, solver, x, t, t_next, &mut r_old);
                assert_eq!(a, b, "{} x={x} t={t}", solver.name());
            }
        }
    }
}

#[test]
fn toy_adaptive_parity() {
    let mut mrng = Xoshiro256::seed_from_u64(7);
    let model = fastdds::ctmc::ToyModel::paper_default(&mut mrng);
    for solver in [Solver::Trapezoidal { theta: 0.5 }, Solver::Rk2 { theta: 0.4 }] {
        for tol in [1e-2, 1e-4] {
            let cfg = AdaptiveController::for_span(tol, model.horizon, 1e-3);
            let mut r_new = Xoshiro256::seed_from_u64(31);
            let mut r_old = Xoshiro256::seed_from_u64(31);
            let (x, stats, trace) = toy::generate_adaptive(
                &model,
                solver,
                StepController::new(cfg, model.horizon / 32.0),
                1e-3,
                &mut r_new,
            );
            let (wx, wstats, wtrace) = legacy_toy::generate_adaptive(
                &model,
                solver,
                StepController::new(cfg, model.horizon / 32.0),
                1e-3,
                &mut r_old,
            );
            assert_eq!(x, wx, "{} tol={tol}", solver.name());
            assert_eq!(stats.nfe, wstats.nfe);
            assert_eq!(stats.steps, wstats.steps);
            assert_eq!(trace.grid, wtrace.grid);
            assert_eq!(trace.errors, wtrace.errors);
        }
    }
}

#[test]
fn text_uniformization_parity() {
    // The HMM text process answers the split total with the filled vector,
    // so the new thinning loop must be bit-identical to the legacy one.
    use fastdds::ctmc::uniformization as new_uni;
    use fastdds::score::hmm::UniformTextJump;
    use legacy_uniformization as old_uni;

    let mut rng = Xoshiro256::seed_from_u64(19);
    let chain = MarkovChain::generate(&mut rng, 4, 0.7);
    let o = HmmUniformOracle::new(chain, 6);
    let new_jump = UniformTextJump { oracle: &o, slack: 4.0 };
    let old_jump = old_uni::LegacyTextJump { oracle: &o, slack: 4.0 };

    for seed in [1u64, 23, 456] {
        let mut r_new = Xoshiro256::seed_from_u64(seed);
        let mut r_old = Xoshiro256::seed_from_u64(seed);
        // Identical (arbitrary mask-free) start states.
        let x0: Vec<fastdds::score::Tok> = (0..6).map(|i| (i % 4) as u32).collect();
        let (x_new, s_new) =
            new_uni::simulate_backward(&new_jump, x0.clone(), 0.9, 0.05, 0.7, &mut r_new);
        let (x_old, s_old) =
            old_uni::simulate_backward(&old_jump, x0, 0.9, 0.05, 0.7, &mut r_old);
        assert_eq!(x_new, x_old, "seed={seed}");
        // The legacy loop evaluated every candidate: its nfe is the
        // candidate count.  The bracketed loop proposes the same
        // candidates but EVALUATES only the unbracketed ones (plus one
        // bound evaluation per window).
        assert_eq!(s_new.n_candidates, s_old.nfe, "candidate counts must match");
        assert_eq!(s_new.jumps, s_old.jumps, "jump streams must match bitwise");
        assert_eq!(s_new.candidate_times, s_old.candidates);
        assert!(
            s_new.nfe <= s_old.nfe + s_new.bound_evals,
            "bracketed evals {} cannot exceed naive evals {} + bounds {}",
            s_new.nfe,
            s_old.nfe,
            s_new.bound_evals
        );
    }
}

#[test]
fn bracket_verification_requires_debug_assertions() {
    // The bracketed-thinning property sweeps below rely on the simulator's
    // debug-mode re-verification of every free reject.  If a profile
    // override ever disables debug_assertions for tests, fail loud
    // instead of silently skipping that verification (tier1.sh greps for
    // the same condition in the manifests).
    assert!(
        cfg!(debug_assertions),
        "test profile must keep debug-assertions enabled: the bracket \
         verification inside ctmc::uniformization depends on them"
    );
}

#[test]
fn bracketed_thinning_matches_nobracket_bitwise_and_cuts_nfe() {
    // Property sweep across seeds × window ratios × slacks: the bracketed
    // loop and the NoBracket (always-evaluate) loop must realize identical
    // jump streams, candidate streams and final states, while the
    // bracketed loop's ACTUAL evaluation count is strictly lower (free
    // rejects cost zero evaluations; both loops pay the same per-window
    // bound evaluations).  Running this under debug_assertions re-verifies
    // every single free reject by full evaluation inside the simulator.
    // (Slacks stay >= 2.5: the window bound itself — bracketed or not —
    // needs the slack to cover the in-window rise of data-consistent
    // positions, ~1/window_ratio at small t.)
    use fastdds::ctmc::uniformization::{simulate_backward, NoBracket};
    use fastdds::score::hmm::UniformTextJump;
    use fastdds::util::rng::Rng;

    let mut rng = Xoshiro256::seed_from_u64(101);
    let chain = MarkovChain::generate(&mut rng, 5, 0.4);
    let o = HmmUniformOracle::new(chain, 8);

    let mut total_free = 0usize;
    for seed in [2u64, 77, 901, 4242] {
        for &ratio in &[0.7, 0.9] {
            for &slack in &[2.5, 4.0] {
                let bracketed = UniformTextJump { oracle: &o, slack };
                let naive = NoBracket(UniformTextJump { oracle: &o, slack });
                let mut seeder = Xoshiro256::seed_from_u64(seed);
                let x0: Vec<fastdds::score::Tok> =
                    (0..8).map(|_| seeder.gen_usize(5) as u32).collect();
                let mut r_b = Xoshiro256::seed_from_u64(seed ^ 0xB00);
                let mut r_n = Xoshiro256::seed_from_u64(seed ^ 0xB00);
                let (x_b, s_b) =
                    simulate_backward(&bracketed, x0.clone(), 1.2, 0.02, ratio, &mut r_b);
                let (x_n, s_n) =
                    simulate_backward(&naive, x0, 1.2, 0.02, ratio, &mut r_n);
                let tag = format!("seed={seed} ratio={ratio} slack={slack}");
                assert_eq!(x_b, x_n, "{tag}: final states");
                assert_eq!(s_b.jumps, s_n.jumps, "{tag}: jump streams");
                assert_eq!(s_b.candidate_times, s_n.candidate_times, "{tag}");
                assert_eq!(s_b.n_candidates, s_n.n_candidates, "{tag}");
                assert_eq!(s_b.bound_evals, s_n.bound_evals, "{tag}: same bound cost");
                // NoBracket never resolves a candidate for free.
                assert_eq!(s_n.free_rejects, 0, "{tag}");
                assert_eq!(s_n.nfe, s_n.n_candidates + s_n.bound_evals, "{tag}");
                // Each free reject saves exactly one evaluation.
                assert_eq!(
                    s_b.nfe + s_b.free_rejects,
                    s_n.nfe,
                    "{tag}: eval accounting"
                );
                if s_b.free_rejects > 0 {
                    assert!(s_b.nfe < s_n.nfe, "{tag}: NFE must strictly drop");
                }
                total_free += s_b.free_rejects;
            }
        }
    }
    // The sweep as a whole must actually exercise the bracket.
    assert!(total_free > 0, "no bracket decision fired across the sweep");
}

#[test]
fn armed_deadline_token_preserves_bit_parity() {
    // The per-window cancel poll is also the deadline-enforcement point
    // (serving specs with `deadline_ms` arm the token).  An armed deadline
    // that never fires must leave every stream bit-identical to the
    // legacy pre-refactor driver: polling draws no randomness, arming
    // draws no randomness, so parity holds through the _ctl entry points
    // exactly as through the plain ones.
    use fastdds::util::cancel::CancelToken;
    use std::time::{Duration, Instant};

    let o = oracle(6, 16, 11);
    let g = grid::masked_uniform(10, 1e-3);
    let seeds = [3u64, 141, 59, 2653, 0];
    let far_future =
        CancelToken::with_deadline(Some(Instant::now() + Duration::from_secs(3600)));
    for solver in approx_solvers() {
        let (new, completed) =
            masked::generate_batch_ctl(&o, solver, &g, &seeds, &far_future);
        assert!(completed, "{}: a future deadline must not interrupt", solver.name());
        let old = legacy_masked::generate_batch(&o, solver, &g, &seeds);
        assert_eq!(new.len(), old.len());
        for (k, (n, w)) in new.iter().zip(&old).enumerate() {
            assert_eq!(n.0, w.0, "{} lane {k} tokens (deadline armed)", solver.name());
            assert_eq!(n.1.nfe, w.1.nfe, "{} lane {k} nfe", solver.name());
        }
    }

    // Adaptive path: same controller, same armed token, same streams.
    let solver = Solver::Trapezoidal { theta: 0.5 };
    let seeds = [5u64, 77, 901];
    let mk_ctl = || {
        let cfg = AdaptiveController::for_span(1e-3, 1.0, 1e-3);
        StepController::new(cfg, 0.1)
    };
    let (new, trace, completed) = masked::generate_batch_adaptive_ctl(
        &o,
        solver,
        mk_ctl(),
        1e-3,
        &seeds,
        &far_future,
    );
    assert!(completed);
    let (old, wtrace) =
        legacy_masked::generate_batch_adaptive(&o, solver, mk_ctl(), 1e-3, &seeds);
    assert_eq!(trace.grid, wtrace.grid, "armed deadline moved the realized grid");
    assert_eq!(trace.errors, wtrace.errors);
    for (k, (n, w)) in new.iter().zip(&old).enumerate() {
        assert_eq!(n.0, w.0, "adaptive lane {k} tokens (deadline armed)");
        assert_eq!(n.1.nfe, w.1.nfe, "adaptive lane {k} nfe");
    }
}

#[test]
fn midpoint_half_anchors_to_rk2_half_masked() {
    // θ-midpoint has no legacy twin (it is new), so its golden anchor is
    // the θ = 1/2 coincidence: the RK-2 combine weight 1/(2θ) is exactly
    // 1.0 there, and the midpoint kernel keeps the RK-2 float expressions,
    // so token streams, NFE and step counts must match bit for bit —
    // single and batch, Markov and (time-inhomogeneous) HMM sources.
    let mid = Solver::Midpoint { theta: 0.5 };
    let rk2 = Solver::Rk2 { theta: 0.5 };
    let o = oracle(6, 16, 11);
    for steps in [4usize, 12] {
        let g = grid::masked_uniform(steps, 1e-3);
        for seed in [0u64, 7, 99, 12345] {
            let mut r_m = Xoshiro256::seed_from_u64(seed);
            let mut r_r = Xoshiro256::seed_from_u64(seed);
            let (toks, stats) = masked::generate(&o, mid, &g, &mut r_m);
            let (want, wstats) = masked::generate(&o, rk2, &g, &mut r_r);
            assert_eq!(toks, want, "steps={steps} seed={seed}");
            assert_eq!(stats.nfe, wstats.nfe, "steps={steps} seed={seed} nfe");
            assert_eq!(stats.steps, wstats.steps);
        }
    }

    let g = grid::masked_uniform(10, 1e-3);
    let seeds = [3u64, 141, 59, 2653, 0];
    let new = masked::generate_batch(&o, mid, &g, &seeds);
    let old = masked::generate_batch(&o, rk2, &g, &seeds);
    for (k, (n, w)) in new.iter().zip(&old).enumerate() {
        assert_eq!(n.0, w.0, "batch lane {k} tokens");
        assert_eq!(n.1.nfe, w.1.nfe, "batch lane {k} nfe");
    }

    let mut rng = Xoshiro256::seed_from_u64(17);
    let chain = MarkovChain::generate(&mut rng, 5, 0.6);
    let h = HmmUniformOracle::new(chain, 10);
    let g = grid::masked_uniform(8, 1e-3);
    let mut r_m = Xoshiro256::seed_from_u64(4);
    let mut r_r = Xoshiro256::seed_from_u64(4);
    let (toks, stats) = masked::generate(&h, mid, &g, &mut r_m);
    let (want, wstats) = masked::generate(&h, rk2, &g, &mut r_r);
    assert_eq!(toks, want, "hmm source");
    assert_eq!(stats.nfe, wstats.nfe);
}

#[test]
fn midpoint_half_anchors_to_rk2_half_toy() {
    // Toy-family anchor, fixed grids and the adaptive controller: the
    // midpoint step_error keeps the RK-2 gate-discrepancy shape, so at
    // θ = 1/2 even the realized adaptive grids and error traces coincide.
    let mid = Solver::Midpoint { theta: 0.5 };
    let rk2 = Solver::Rk2 { theta: 0.5 };
    let mut mrng = Xoshiro256::seed_from_u64(7);
    let model = fastdds::ctmc::ToyModel::paper_default(&mut mrng);
    for steps in [8usize, 32] {
        let g = grid::toy_uniform(steps, model.horizon, 1e-3);
        let mut r_m = Xoshiro256::seed_from_u64(13);
        let mut r_r = Xoshiro256::seed_from_u64(13);
        for rep in 0..200 {
            let x_m = toy::generate(&model, mid, &g, &mut r_m);
            let x_r = toy::generate(&model, rk2, &g, &mut r_r);
            assert_eq!(x_m, x_r, "steps={steps} rep={rep}");
        }
    }

    for tol in [1e-2, 1e-4] {
        let cfg = AdaptiveController::for_span(tol, model.horizon, 1e-3);
        let mut r_m = Xoshiro256::seed_from_u64(31);
        let mut r_r = Xoshiro256::seed_from_u64(31);
        let (x, stats, trace) = toy::generate_adaptive(
            &model,
            mid,
            StepController::new(cfg, model.horizon / 32.0),
            1e-3,
            &mut r_m,
        );
        let (wx, wstats, wtrace) = toy::generate_adaptive(
            &model,
            rk2,
            StepController::new(cfg, model.horizon / 32.0),
            1e-3,
            &mut r_r,
        );
        assert_eq!(x, wx, "tol={tol}");
        assert_eq!(stats.nfe, wstats.nfe);
        assert_eq!(trace.grid, wtrace.grid, "realized grids must match");
        assert_eq!(trace.errors, wtrace.errors, "error traces must match");
    }
}

#[test]
fn hmm_evaluation_nfe_strictly_drops_at_default_slack() {
    // The acceptance headline on a Fig. 1-like configuration: at the
    // default slack the bracketed loop performs ~env/slack of the naive
    // candidate evaluations (env = the certified window rise envelope,
    // ~1.9 at these window ratios), so total evals (incl. the shared
    // window-bound passes) drop by over the required 1.5x.
    use fastdds::ctmc::uniformization::{simulate_backward, NoBracket, DEFAULT_SLACK};
    use fastdds::score::hmm::UniformTextJump;
    use fastdds::util::rng::Rng;

    let mut rng = Xoshiro256::seed_from_u64(55);
    let chain = MarkovChain::generate(&mut rng, 5, 0.15);
    let o = HmmUniformOracle::new(chain, 10);
    let bracketed = UniformTextJump { oracle: &o, slack: DEFAULT_SLACK };
    let naive = NoBracket(UniformTextJump { oracle: &o, slack: DEFAULT_SLACK });

    let (mut ev_b, mut ev_n) = (0usize, 0usize);
    for seed in 0..6u64 {
        let mut seeder = Xoshiro256::seed_from_u64(seed);
        let x0: Vec<fastdds::score::Tok> =
            (0..10).map(|_| seeder.gen_usize(5) as u32).collect();
        let mut r_b = Xoshiro256::seed_from_u64(seed ^ 0xFACE);
        let mut r_n = Xoshiro256::seed_from_u64(seed ^ 0xFACE);
        let (x_b, s_b) = simulate_backward(&bracketed, x0.clone(), 3.0, 0.02, 0.8, &mut r_b);
        let (x_n, s_n) = simulate_backward(&naive, x0, 3.0, 0.02, 0.8, &mut r_n);
        assert_eq!(x_b, x_n, "seed={seed}");
        assert_eq!(s_b.jumps, s_n.jumps, "seed={seed}");
        ev_b += s_b.nfe;
        ev_n += s_n.nfe;
    }
    assert!(ev_b < ev_n, "bracketed {ev_b} must beat naive {ev_n}");
    let reduction = ev_n as f64 / ev_b as f64;
    assert!(
        reduction >= 1.5,
        "eval reduction {reduction:.2}x below the 1.5x acceptance floor \
         (bracketed {ev_b}, naive {ev_n})"
    );
}
