//! Kernel parity suite: the blocked/SIMD score kernels and the SoA batched
//! paths must be **bit-identical** to the frozen scalar reference copies
//! (`fastdds::score::hmm::reference` — verbatim pre-rewrite loops) and to
//! the single-lane entry points.  This is the same contract the golden
//! parity / pit-parity / exact jump-stream suites pin end to end, asserted
//! here at the kernel boundary so a reordered reduction fails loudly and
//! locally.  Vocab sizes include non-multiples of the 4-wide block so the
//! block tails are exercised; lane counts 1..=9 exercise full SoA blocks,
//! remainder blocks of every size, and the single-request fast path.

use fastdds::score::hmm::{reference, HmmUniformOracle};
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::score::{masked_indices, ScoreSource, Tok};
use fastdds::util::rng::{Rng, Xoshiro256};

/// Odd sizes exercise the 4-wide block tails; 64 is the roofline headline.
const VOCABS: &[usize] = &[3, 4, 5, 8, 16, 33, 64];
const SEQ_LEN: usize = 10;

fn chain(vocab: usize) -> MarkovChain {
    let mut rng = Xoshiro256::seed_from_u64(1000 + vocab as u64);
    MarkovChain::generate(&mut rng, vocab, 0.7)
}

/// Random sequence over `vocab` real tokens plus the mask id, ~half masked.
fn masked_tokens(rng: &mut Xoshiro256, vocab: usize, mask: Tok) -> Vec<Tok> {
    (0..SEQ_LEN)
        .map(|_| if rng.gen_bool(0.5) { mask } else { rng.gen_usize(vocab) as Tok })
        .collect()
}

#[test]
fn hmm_blocked_masked_eval_bitwise_matches_scalar_reference() {
    for &v in VOCABS {
        let o = HmmUniformOracle::new(chain(v), SEQ_LEN);
        let mask = o.mask_id();
        let mut rng = Xoshiro256::seed_from_u64(7 + v as u64);
        let mut ws = reference::RefScratch::new();
        for case in 0..4 {
            let tokens = masked_tokens(&mut rng, v, mask);
            let idx = masked_indices(&tokens, mask);
            let t = 0.1 + 0.3 * case as f64;
            let mut got = vec![0.0; idx.len() * v];
            o.probs_masked_into(&tokens, &idx, t, &mut got);
            let mut want = vec![0.0; idx.len() * v];
            reference::probs_masked_scalar(&o.chain, &tokens, &idx, t, &mut ws, &mut want);
            assert_eq!(got, want, "V={v} case={case}");
        }
    }
}

#[test]
fn hmm_blocked_ratios_bitwise_match_scalar_reference() {
    for &v in VOCABS {
        let o = HmmUniformOracle::new(chain(v), SEQ_LEN);
        let mut rng = Xoshiro256::seed_from_u64(13 + v as u64);
        let mut ws = reference::RefScratch::new();
        for case in 0..4 {
            // Mask-free: ratios is the uniform-state (in-place corruption)
            // surface, there is no absorbing token.
            let tokens: Vec<Tok> = (0..SEQ_LEN).map(|_| rng.gen_usize(v) as Tok).collect();
            let t = 0.05 + 0.4 * case as f64;
            let mut got = vec![0.0; SEQ_LEN * v];
            o.ratios(&tokens, t, &mut got);
            let mut want = vec![0.0; SEQ_LEN * v];
            reference::ratios_scalar(&o.chain, &tokens, t, &mut ws, &mut want);
            assert_eq!(got, want, "V={v} case={case}");
        }
    }
}

#[test]
fn hmm_soa_batch_bitwise_matches_single_lane() {
    for &v in VOCABS {
        let o = HmmUniformOracle::new(chain(v), SEQ_LEN);
        let mask = o.mask_id();
        let mut rng = Xoshiro256::seed_from_u64(29 + v as u64);
        for n_lanes in 1..=9usize {
            let lanes: Vec<(Vec<Tok>, Vec<usize>)> = (0..n_lanes)
                .map(|_| {
                    let tokens = masked_tokens(&mut rng, v, mask);
                    let idx = masked_indices(&tokens, mask);
                    (tokens, idx)
                })
                .collect();
            let t = 0.35;
            let singles: Vec<Vec<f64>> = lanes
                .iter()
                .map(|(tk, ix)| {
                    let mut buf = vec![0.0; ix.len() * v];
                    o.probs_masked_into(tk, ix, t, &mut buf);
                    buf
                })
                .collect();
            let mut bufs: Vec<Vec<f64>> =
                lanes.iter().map(|(_, ix)| vec![1.0; ix.len() * v]).collect();
            {
                let reqs: Vec<(&[Tok], &[usize])> =
                    lanes.iter().map(|(tk, ix)| (tk.as_slice(), ix.as_slice())).collect();
                let mut outs: Vec<&mut [f64]> =
                    bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                o.probs_masked_batch(&reqs, t, &mut outs);
            }
            for (k, (got, want)) in bufs.iter().zip(&singles).enumerate() {
                assert_eq!(got, want, "V={v} lanes={n_lanes} lane {k}");
            }
        }
    }
}

#[test]
fn hmm_soa_slices_bitwise_match_single_lane() {
    for &v in VOCABS {
        let o = HmmUniformOracle::new(chain(v), SEQ_LEN);
        let mask = o.mask_id();
        let mut rng = Xoshiro256::seed_from_u64(41 + v as u64);
        for n_lanes in [1usize, 3, 4, 5, 8, 9] {
            // Mixed per-lane t: the SoA block must carry time as a lane
            // coordinate, not hoist it.
            let lanes: Vec<(Vec<Tok>, Vec<usize>, f64)> = (0..n_lanes)
                .map(|k| {
                    let tokens = masked_tokens(&mut rng, v, mask);
                    let idx = masked_indices(&tokens, mask);
                    (tokens, idx, 0.08 + 0.17 * k as f64)
                })
                .collect();
            let singles: Vec<Vec<f64>> = lanes
                .iter()
                .map(|(tk, ix, t)| {
                    let mut buf = vec![0.0; ix.len() * v];
                    o.probs_masked_into(tk, ix, *t, &mut buf);
                    buf
                })
                .collect();
            let mut bufs: Vec<Vec<f64>> =
                lanes.iter().map(|(_, ix, _)| vec![1.0; ix.len() * v]).collect();
            {
                let reqs: Vec<(&[Tok], &[usize], f64)> = lanes
                    .iter()
                    .map(|(tk, ix, t)| (tk.as_slice(), ix.as_slice(), *t))
                    .collect();
                let mut outs: Vec<&mut [f64]> =
                    bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                o.probs_masked_slices(&reqs, &mut outs);
            }
            for (k, (got, want)) in bufs.iter().zip(&singles).enumerate() {
                assert_eq!(got, want, "V={v} lanes={n_lanes} lane {k}");
            }
        }
    }
}

#[test]
fn markov_batch_overrides_bitwise_match_single_lane() {
    for &v in VOCABS {
        let o = MarkovOracle::new(chain(v), SEQ_LEN);
        let mask = o.mask_id();
        let mut rng = Xoshiro256::seed_from_u64(57 + v as u64);
        for n_lanes in [1usize, 2, 5] {
            let lanes: Vec<(Vec<Tok>, Vec<usize>, f64)> = (0..n_lanes)
                .map(|k| {
                    let tokens = masked_tokens(&mut rng, v, mask);
                    let idx = masked_indices(&tokens, mask);
                    (tokens, idx, 0.1 + 0.25 * k as f64)
                })
                .collect();
            let t = 0.6;
            let singles: Vec<Vec<f64>> = lanes
                .iter()
                .map(|(tk, ix, _)| {
                    let mut buf = vec![0.0; ix.len() * v];
                    o.probs_masked_into(tk, ix, t, &mut buf);
                    buf
                })
                .collect();
            let mut bufs: Vec<Vec<f64>> =
                lanes.iter().map(|(_, ix, _)| vec![1.0; ix.len() * v]).collect();
            {
                let reqs: Vec<(&[Tok], &[usize])> =
                    lanes.iter().map(|(tk, ix, _)| (tk.as_slice(), ix.as_slice())).collect();
                let mut outs: Vec<&mut [f64]> =
                    bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                o.probs_masked_batch(&reqs, t, &mut outs);
            }
            for (k, (got, want)) in bufs.iter().zip(&singles).enumerate() {
                assert_eq!(got, want, "V={v} batch lanes={n_lanes} lane {k}");
            }

            let slice_singles: Vec<Vec<f64>> = lanes
                .iter()
                .map(|(tk, ix, tl)| {
                    let mut buf = vec![0.0; ix.len() * v];
                    o.probs_masked_into(tk, ix, *tl, &mut buf);
                    buf
                })
                .collect();
            let mut bufs: Vec<Vec<f64>> =
                lanes.iter().map(|(_, ix, _)| vec![1.0; ix.len() * v]).collect();
            {
                let reqs: Vec<(&[Tok], &[usize], f64)> = lanes
                    .iter()
                    .map(|(tk, ix, tl)| (tk.as_slice(), ix.as_slice(), *tl))
                    .collect();
                let mut outs: Vec<&mut [f64]> =
                    bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
                o.probs_masked_slices(&reqs, &mut outs);
            }
            for (k, (got, want)) in bufs.iter().zip(&slice_singles).enumerate() {
                assert_eq!(got, want, "V={v} slices lanes={n_lanes} lane {k}");
            }
        }
    }
}

#[test]
fn hmm_dense_probs_bitwise_match_scalar_reference_rows() {
    // probs_into shares messages_into + posterior_row with the masked
    // path; pin the dense surface too (all positions, masked or not).
    for &v in [3usize, 8, 33].iter() {
        let o = HmmUniformOracle::new(chain(v), SEQ_LEN);
        let mask = o.mask_id();
        let mut rng = Xoshiro256::seed_from_u64(71 + v as u64);
        let tokens = masked_tokens(&mut rng, v, mask);
        let all: Vec<usize> = (0..SEQ_LEN).collect();
        let dense = o.probs(&tokens, 0.5);
        let mut want = vec![0.0; SEQ_LEN * v];
        let mut ws = reference::RefScratch::new();
        reference::probs_masked_scalar(&o.chain, &tokens, &all, 0.5, &mut ws, &mut want);
        assert_eq!(dense, want, "V={v}");
    }
}
