//! Property-based tests (testkit mini-framework): randomized invariants on
//! routing/batching state, grids, solvers, and metrics — the "L3 proptest"
//! coverage required by DESIGN.md.  Failures print a replay seed
//! (FASTDDS_PT_SEED).

use fastdds::api::{CancelToken, SamplingSpec};
use fastdds::coordinator::batcher::{BatchKey, BatchPolicy, DynamicBatcher};
use fastdds::coordinator::request::GenerateRequest;
use fastdds::prop_assert;
use fastdds::score::hmm::HmmUniformOracle;
use fastdds::score::markov::{MarkovChain, MarkovOracle};
use fastdds::score::{masked_indices, ScoreSource, Tok};
use fastdds::solvers::{grid, masked, Solver};
use fastdds::testkit::{check, Gen};
use fastdds::util::rng::Xoshiro256;
use std::time::Instant;

fn random_solver(g: &mut Gen) -> Solver {
    match g.usize_in(0, 5) {
        0 => Solver::Euler,
        1 => Solver::TauLeaping,
        2 => Solver::Tweedie,
        3 => Solver::Trapezoidal { theta: g.f64_in(0.05, 0.95) },
        // (0, 1/2] is the request-surface range (Thm. 5.5): the parse
        // roundtrip property below feeds these through Solver::parse.
        4 => Solver::Rk2 { theta: g.f64_in(0.05, 0.5) },
        _ => Solver::ParallelDecoding,
    }
}

#[test]
fn prop_batcher_conserves_lanes() {
    // Every enqueued lane comes out exactly once, whatever the mix.
    check("batcher_conserves_lanes", 50, |g| {
        let max_lanes = g.usize_in(1, 16);
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, max_lanes);
        let n_reqs = g.usize_in(1, 20);
        let mut expect = 0usize;
        for id in 0..n_reqs {
            let n_samples = g.usize_in(1, 12);
            expect += n_samples;
            let spec = SamplingSpec::builder()
                .family(if g.bool(0.5) { "markov" } else { "toy" })
                .solver(random_solver(g))
                .nfe(*g.choose(&[16usize, 32, 64]))
                .n_samples(n_samples)
                .seed(g.usize_in(0, 1000) as u64)
                .build()
                .expect("generated specs are valid");
            b.enqueue(GenerateRequest::new(id as u64, spec), CancelToken::never());
        }
        let mut got = 0usize;
        let mut batches = 0usize;
        while let Some((_, proto, lanes)) = b.next_batch(Instant::now()) {
            prop_assert!(!lanes.is_empty(), "empty batch dispatched");
            prop_assert!(
                lanes.len() <= max_lanes,
                "batch of {} exceeds max {max_lanes}",
                lanes.len()
            );
            // Every lane in a batch must share the prototype's key.
            let key = BatchKey::of(&proto);
            prop_assert!(
                lanes.iter().all(|_| true) && key == BatchKey::of(&proto),
                "key mismatch"
            );
            got += lanes.len();
            batches += 1;
            prop_assert!(batches < 10_000, "runaway dispatch loop");
        }
        prop_assert!(got == expect, "lanes lost: got {got} expect {expect}");
        prop_assert!(b.pending() == 0, "pending not drained");
        Ok(())
    });
}

#[test]
fn prop_batch_key_groups_iff_compatible() {
    check("batch_key_compatible", 100, |g| {
        let mk = |solver: Solver, nfe: usize, family: &str| {
            BatchKey::of(
                &SamplingSpec::builder()
                    .family(family)
                    .solver(solver)
                    .nfe(nfe)
                    .build()
                    .expect("valid spec"),
            )
        };
        let theta = g.f64_in(0.05, 0.95);
        let nfe = *g.choose(&[16usize, 32, 64]);
        // Identical parameters -> same key.
        prop_assert!(
            mk(Solver::Trapezoidal { theta }, nfe, "markov")
                == mk(Solver::Trapezoidal { theta }, nfe, "markov"),
            "identical requests must share a key"
        );
        // Any differing coordinate -> different key.
        prop_assert!(
            mk(Solver::Trapezoidal { theta }, nfe, "markov")
                != mk(Solver::Trapezoidal { theta: theta + 0.01 }, nfe, "markov"),
            "theta must split keys"
        );
        prop_assert!(
            mk(Solver::TauLeaping, nfe, "markov") != mk(Solver::TauLeaping, nfe * 2, "markov"),
            "nfe must split keys"
        );
        prop_assert!(
            mk(Solver::TauLeaping, nfe, "markov") != mk(Solver::TauLeaping, nfe, "toy"),
            "family must split keys"
        );
        Ok(())
    });
}

#[test]
fn prop_grids_monotone_and_bounded() {
    check("grids_valid", 100, |g| {
        let n = g.usize_in(1, 300);
        let delta = g.f64_in(1e-5, 0.5);
        for grid in [grid::masked_uniform(n, delta), grid::masked_log(n, delta)] {
            prop_assert!(grid.len() == n + 1, "wrong length");
            prop_assert!(grid[0] == 1.0, "must start at 1.0");
            prop_assert!(
                (grid.last().unwrap() - delta).abs() < 1e-12,
                "must end at delta"
            );
            prop_assert!(
                grid::is_valid_grid(&grid),
                "grid not strictly decreasing"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_masked_generation_invariants() {
    // For any solver/seed/grid: output has no masks, tokens in range, and
    // NFE within the accounting bound (steps * per-step + 1 finalize).
    let mut rng = Xoshiro256::seed_from_u64(5);
    let chain = MarkovChain::generate(&mut rng, 6, 0.5);
    let oracle = MarkovOracle::new(chain, 24);
    check("masked_generation", 40, |g| {
        let solver = random_solver(g);
        let steps = g.usize_in(2, 24);
        let grid = grid::masked_uniform(steps, 1e-3);
        let mut rng = Xoshiro256::seed_from_u64(g.seed);
        // Trapezoidal requires theta < 1; random_solver guarantees it.
        let (toks, stats) = masked::generate(&oracle, solver, &grid, &mut rng);
        prop_assert!(toks.len() == 24, "wrong length");
        prop_assert!(
            toks.iter().all(|&t| t < 6),
            "masks or out-of-range tokens: {toks:?}"
        );
        let bound = steps * solver.nfe_per_step() + 1;
        prop_assert!(
            stats.nfe <= bound,
            "nfe {} exceeds bound {bound} for {}",
            stats.nfe,
            solver.name()
        );
        Ok(())
    });
}

#[test]
fn prop_generate_batch_bit_identical_to_lanes() {
    // For any solver, lane count and seed set: generate_batch output is
    // bitwise equal to B independent generate calls — co-batching never
    // changes samples or stats.
    let mut rng = Xoshiro256::seed_from_u64(6);
    let chain = MarkovChain::generate(&mut rng, 5, 0.5);
    let oracle = MarkovOracle::new(chain, 20);
    check("generate_batch_equivalence", 25, |g| {
        let solver = random_solver(g);
        let steps = g.usize_in(2, 16);
        let grid = grid::masked_uniform(steps, 1e-3);
        let b = g.usize_in(1, 6);
        let seeds: Vec<u64> = (0..b).map(|_| g.usize_in(0, 1_000_000) as u64).collect();
        let batch = masked::generate_batch(&oracle, solver, &grid, &seeds);
        prop_assert!(batch.len() == b, "wrong lane count");
        for (lane, &seed) in batch.iter().zip(&seeds) {
            let mut r = Xoshiro256::seed_from_u64(seed);
            let (toks, stats) = masked::generate(&oracle, solver, &grid, &mut r);
            prop_assert!(
                lane.0 == toks,
                "{} diverged for seed {seed}: {:?} vs {toks:?}",
                solver.name(),
                lane.0
            );
            prop_assert!(
                lane.1.nfe == stats.nfe && lane.1.steps == stats.steps,
                "{} stats diverged for seed {seed}: ({}, {}) vs ({}, {})",
                solver.name(),
                lane.1.nfe,
                lane.1.steps,
                stats.nfe,
                stats.steps
            );
        }
        Ok(())
    });
}

#[test]
fn prop_sparse_rows_match_dense_on_both_oracles() {
    // probs_masked_into must agree with the dense probs_into rows on every
    // score source, for any masking pattern and time.
    let mut rng = Xoshiro256::seed_from_u64(12);
    let chain = MarkovChain::generate(&mut rng, 7, 0.4);
    let markov = MarkovOracle::new(chain.clone(), 14);
    let hmm = HmmUniformOracle::new(chain, 14);
    check("sparse_vs_dense_rows", 40, |g| {
        let t = g.f64_in(1e-3, 1.0);
        let sources: [&dyn ScoreSource; 2] = [&markov, &hmm];
        for (si, s) in sources.iter().enumerate() {
            let (l, v) = (s.seq_len(), s.vocab());
            let mask = s.mask_id();
            let tokens: Vec<Tok> = (0..l)
                .map(|_| {
                    if g.bool(0.5) {
                        mask
                    } else {
                        g.usize_in(0, v - 1) as Tok
                    }
                })
                .collect();
            let idx = masked_indices(&tokens, mask);
            let dense = s.probs(&tokens, t);
            let mut compact = vec![0.0; idx.len() * v];
            s.probs_masked_into(&tokens, &idx, t, &mut compact);
            for (k, &i) in idx.iter().enumerate() {
                prop_assert!(
                    compact[k * v..(k + 1) * v] == dense[i * v..(i + 1) * v],
                    "source {si}: sparse row {k} != dense row {i} at t={t}"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_oracle_rows_are_distributions() {
    let mut rng = Xoshiro256::seed_from_u64(8);
    let chain = MarkovChain::generate(&mut rng, 9, 0.4);
    let oracle = MarkovOracle::new(chain, 16);
    check("oracle_rows", 60, |g| {
        let mask = oracle.mask_id();
        let tokens: Vec<u32> = (0..16)
            .map(|_| {
                if g.bool(0.5) {
                    mask
                } else {
                    g.usize_in(0, 8) as u32
                }
            })
            .collect();
        let p = oracle.probs(&tokens, g.f64_in(1e-3, 1.0));
        for i in 0..16 {
            let row = &p[i * 9..(i + 1) * 9];
            let tot: f64 = row.iter().sum();
            prop_assert!(
                (tot - 1.0).abs() < 1e-6,
                "row {i} sums to {tot}"
            );
            prop_assert!(row.iter().all(|&x| x >= 0.0), "negative prob at {i}");
        }
        Ok(())
    });
}

#[test]
fn prop_solver_parse_string_roundtrip() {
    check("solver_parse", 60, |g| {
        let s = random_solver(g);
        let text = fastdds::coordinator::request::solver_string(s);
        let back = Solver::parse(&text).map_err(|e| format!("{e}"))?;
        prop_assert!(back == s, "{s:?} -> {text} -> {back:?}");
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    use fastdds::util::json::Json;
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 2) } else { g.usize_in(0, 4) } {
            0 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
            1 => Json::Bool(g.bool(0.5)),
            2 => {
                let n = g.usize_in(0, 8);
                Json::Str((0..n).map(|_| *g.choose(&['a', 'β', '"', '\\', '\n', 'z'])).collect())
            }
            3 => {
                let n = g.usize_in(0, 4);
                Json::Arr((0..n).map(|_| random_json(g, depth - 1)).collect())
            }
            _ => {
                let n = g.usize_in(0, 4);
                Json::Obj(
                    (0..n)
                        .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                        .collect(),
                )
            }
        }
    }
    check("json_roundtrip", 200, |g| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
        prop_assert!(back == v, "{text}");
        Ok(())
    });
}
