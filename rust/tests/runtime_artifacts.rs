//! Integration tests over the real AOT artifacts: the full L2/L1 -> PJRT ->
//! rust round trip.  Skipped (early-return) when `make artifacts` has not
//! been run.
//!
//! The key cross-validation: the JAX toy step artifacts and the pure-rust
//! toy solvers implement the same algorithms from the same p0
//! (artifacts/toy_model.json) — their one-step transition statistics must
//! agree, and both must drive the KL to p0 down.

use fastdds::ctmc::ToyModel;
use fastdds::runtime::{artifacts_available, Registry, RuntimeHandle, Value};
use fastdds::util::rng::{Rng, Xoshiro256};

const DIR: &str = "artifacts";

fn handle() -> Option<RuntimeHandle> {
    artifacts_available(DIR).then(|| RuntimeHandle::spawn(DIR).unwrap())
}

#[test]
fn kernel_attention_artifact_matches_rust_reference() {
    let Some(h) = handle() else { return };
    let (l, d) = (32usize, 16usize);
    let mut rng = Xoshiro256::seed_from_u64(1);
    let q: Vec<f32> = (0..l * d).map(|_| rng.gen_f32() - 0.5).collect();
    let k: Vec<f32> = (0..l * d).map(|_| rng.gen_f32() - 0.5).collect();
    let v: Vec<f32> = (0..l * d).map(|_| rng.gen_f32() - 0.5).collect();
    let out = h
        .execute(
            "kernel_attention",
            vec![
                Value::f32(q.clone(), vec![l, d]),
                Value::f32(k.clone(), vec![l, d]),
                Value::f32(v.clone(), vec![l, d]),
            ],
        )
        .unwrap();
    let got = out[0].as_f32().unwrap();

    // Rust reference: softmax(QK^T / sqrt(d)) V in f64.
    let scale = 1.0 / (d as f64).sqrt();
    for i in 0..l {
        let mut scores = vec![0.0f64; l];
        for j in 0..l {
            let mut acc = 0.0;
            for c in 0..d {
                acc += q[i * d + c] as f64 * k[j * d + c] as f64;
            }
            scores[j] = acc * scale;
        }
        let m = scores.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let exps: Vec<f64> = scores.iter().map(|s| (s - m).exp()).collect();
        let z: f64 = exps.iter().sum();
        for c in 0..d {
            let mut want = 0.0;
            for j in 0..l {
                want += exps[j] / z * v[j * d + c] as f64;
            }
            let gotv = got[i * d + c] as f64;
            assert!(
                (gotv - want).abs() < 1e-4,
                "attention mismatch at ({i},{c}): {gotv} vs {want}"
            );
        }
    }
}

#[test]
fn toy_step_artifact_statistically_matches_rust_solver() {
    let Some(h) = handle() else { return };
    let model = ToyModel::from_artifact("artifacts/toy_model.json").unwrap();
    let reg = Registry::load(DIR).unwrap();
    let spec = reg.step_artifact("toy", "tau").unwrap();
    let b = spec.batch().unwrap();
    let s = model.n_states();
    let (t, t_next) = (2.0f64, 1.6f64);

    // Artifact path: one batched tau step from a fixed state, many rounds.
    let mut rng = Xoshiro256::seed_from_u64(7);
    let x0 = 3usize;
    let mut counts_art = vec![0u64; s];
    let rounds = 40;
    for _ in 0..rounds {
        let mut u = vec![0.0f32; 2 * b];
        rng.fill_f32(&mut u);
        let out = h
            .execute(
                "toy_step_tau",
                vec![
                    Value::i32(vec![x0 as i32; b], vec![b]),
                    Value::scalar_f32(t as f32),
                    Value::scalar_f32(t_next as f32),
                    Value::f32(u, vec![1, 2, b]),
                ],
            )
            .unwrap();
        for &x in out[0].as_i32().unwrap() {
            counts_art[x as usize] += 1;
        }
    }

    // Rust path: same number of single-sample steps.
    let n = rounds * b;
    let mut counts_rs = vec![0u64; s];
    for _ in 0..n {
        let x = fastdds::solvers::toy::step(
            &model,
            fastdds::solvers::Solver::TauLeaping,
            x0,
            t,
            t_next,
            &mut rng,
        );
        counts_rs[x] += 1;
    }

    for state in 0..s {
        let pa = counts_art[state] as f64 / n as f64;
        let pr = counts_rs[state] as f64 / n as f64;
        // 4-sigma binomial band + slack.
        let sd = (pa.max(pr).max(1e-4) / n as f64).sqrt();
        assert!(
            (pa - pr).abs() < 4.0 * sd + 0.01,
            "state {state}: artifact {pa:.4} vs rust {pr:.4}"
        );
    }
}

#[test]
fn markov_score_artifact_matches_rust_oracle() {
    let Some(h) = handle() else { return };
    let chain =
        fastdds::score::markov::MarkovChain::from_artifact("artifacts/markov_model.json")
            .unwrap();
    let reg = Registry::load(DIR).unwrap();
    let spec = reg.get("markov_score").unwrap();
    let b = spec.batch().unwrap();
    let l = spec.seq_len().unwrap();
    let v = spec.vocab().unwrap();
    let oracle = fastdds::score::markov::MarkovOracle::new(chain, l);
    use fastdds::score::ScoreSource;

    // Random partially-masked batch.
    let mut rng = Xoshiro256::seed_from_u64(3);
    let mask = v as i32;
    let tokens: Vec<i32> = (0..b * l)
        .map(|_| {
            if rng.gen_bool(0.6) {
                mask
            } else {
                rng.gen_usize(v) as i32
            }
        })
        .collect();
    let out = h
        .execute(
            "markov_score",
            vec![
                Value::i32(tokens.clone(), vec![b, l]),
                Value::scalar_f32(0.5),
            ],
        )
        .unwrap();
    let probs = out[0].as_f32().unwrap();

    for seq in 0..b {
        let toks: Vec<u32> = tokens[seq * l..(seq + 1) * l]
            .iter()
            .map(|&x| x as u32)
            .collect();
        let want = oracle.probs(&toks, 0.5);
        for i in 0..l {
            if toks[i] != v as u32 {
                continue; // observed rows are delta-coded only in rust
            }
            for c in 0..v {
                let got = probs[seq * l * v + i * v + c] as f64;
                let w = want[i * v + c];
                assert!(
                    (got - w).abs() < 5e-5,
                    "seq {seq} pos {i} tok {c}: {got} vs {w}"
                );
            }
        }
    }
}

#[test]
fn markov_trapezoidal_artifact_runs_and_unmasks() {
    let Some(h) = handle() else { return };
    let reg = Registry::load(DIR).unwrap();
    let spec = reg.step_artifact("markov", "trapezoidal").unwrap();
    let b = spec.batch().unwrap();
    let l = spec.seq_len().unwrap();
    let v = spec.vocab().unwrap();
    let mask = v as i32;

    let mut rng = Xoshiro256::seed_from_u64(5);
    let mut tokens = vec![mask; b * l];
    let grid = fastdds::solvers::grid::masked_uniform(8, 1e-3);
    for w in grid.windows(2) {
        let mut u = vec![0.0f32; 2 * 2 * b * l];
        rng.fill_f32(&mut u);
        let out = h
            .execute(
                "markov_step_trapezoidal",
                vec![
                    Value::i32(tokens.clone(), vec![b, l]),
                    Value::scalar_f32(w[0] as f32),
                    Value::scalar_f32(w[1] as f32),
                    Value::scalar_f32(0.5),
                    Value::f32(u, vec![2, 2, b, l]),
                ],
            )
            .unwrap();
        tokens = out[0].as_i32().unwrap().to_vec();
    }
    let masked = tokens.iter().filter(|&&x| x == mask).count();
    // 8 trapezoidal steps unmask the overwhelming majority of dims.
    assert!(masked < b * l / 10, "still masked: {masked}/{}", b * l);
    assert!(tokens.iter().all(|&x| x >= 0 && x <= mask));
    // Dispatch accounting.
    let stats = h.dispatch_stats();
    let trap = stats
        .iter()
        .find(|(n, _)| n == "markov_step_trapezoidal")
        .unwrap();
    assert_eq!(trap.1, 8);
}

#[test]
fn artifact_score_sparse_and_batch_agree_with_dense() {
    let Some(h) = handle() else { return };
    let reg = Registry::load(DIR).unwrap();
    if reg.get("markov_score").is_err() {
        return;
    }
    use fastdds::score::{masked_indices, ScoreSource, Tok};
    let score = fastdds::runtime::ArtifactScore::new(h, &reg, "markov").unwrap();
    let (l, v) = (score.seq_len(), score.vocab());
    let mask = score.mask_id();
    let mut rng = Xoshiro256::seed_from_u64(9);
    let mk_tokens = |rng: &mut Xoshiro256| -> Vec<Tok> {
        (0..l)
            .map(|_| {
                if rng.gen_bool(0.5) {
                    mask
                } else {
                    rng.gen_usize(v) as Tok
                }
            })
            .collect()
    };
    let tokens = mk_tokens(&mut rng);
    let idx = masked_indices(&tokens, mask);
    assert!(!idx.is_empty());

    // Sparse rows match the dense evaluation (same dispatch, sparse gather).
    let dense = score.probs(&tokens, 0.5);
    let mut compact = vec![0.0; idx.len() * v];
    score.probs_masked_into(&tokens, &idx, 0.5, &mut compact);
    assert!(score.take_error().is_none(), "dispatch failed");
    for (k, &i) in idx.iter().enumerate() {
        for c in 0..v {
            let (a, b) = (compact[k * v + c], dense[i * v + c]);
            assert!((a - b).abs() < 1e-6, "row {k} pos {i} tok {c}: {a} vs {b}");
        }
    }

    // Batched evaluation (lanes packed into one dispatch) matches the
    // per-sequence sparse path.
    let tokens2 = mk_tokens(&mut rng);
    let idx2 = masked_indices(&tokens2, mask);
    let mut b1 = vec![0.0; idx.len() * v];
    let mut b2 = vec![0.0; idx2.len() * v];
    {
        let reqs: Vec<(&[Tok], &[usize])> = vec![
            (tokens.as_slice(), idx.as_slice()),
            (tokens2.as_slice(), idx2.as_slice()),
        ];
        let mut outs: Vec<&mut [f64]> = vec![&mut b1, &mut b2];
        score.probs_masked_batch(&reqs, 0.5, &mut outs);
    }
    assert!(score.take_error().is_none(), "batch dispatch failed");
    let mut want2 = vec![0.0; idx2.len() * v];
    score.probs_masked_into(&tokens2, &idx2, 0.5, &mut want2);
    for (got, want) in b1.iter().zip(&compact) {
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
    for (got, want) in b2.iter().zip(&want2) {
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }
}

#[test]
fn runtime_rejects_bad_shapes() {
    let Some(h) = handle() else { return };
    let err = h
        .execute("toy_step_tau", vec![Value::scalar_f32(1.0)])
        .unwrap_err();
    assert!(format!("{err:#}").contains("expected"), "{err:#}");
}
