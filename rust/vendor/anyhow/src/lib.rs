//! Offline stand-in for the `anyhow` crate.
//!
//! crates.io is not reachable from this build image, so this vendored shim
//! implements exactly the API subset fastdds uses: [`Error`], [`Result`],
//! the [`anyhow!`] / [`bail!`] macros, the [`Context`] extension trait, and
//! `{:#}` alternate formatting that prints the whole cause chain
//! (`outer: inner: root`).  Semantics follow the real crate: `Error` does
//! not implement `std::error::Error` itself (which is what makes the
//! blanket `From` conversion possible), context wraps become the outermost
//! message, and `{}` shows only the outermost message.

use std::error::Error as StdError;
use std::fmt;

/// Result alias with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error carrying a message and an optional cause chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Error from a plain message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Error wrapping a concrete `std::error::Error`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap `self` with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: context.to_string(),
            source: Some(Box::new(Chained(self))),
        }
    }

    /// Borrow the first error in the cause chain that is a `T` — the
    /// real crate's typed-error recovery (`downcast_ref::<JobError>()`,
    /// `downcast_ref::<RegistryError>()`, ...).  Context wraps are
    /// transparent: they chain through [`Chained`], whose `source()`
    /// exposes the wrapped error's own chain.
    pub fn downcast_ref<T: StdError + 'static>(&self) -> Option<&T> {
        let mut cur: Option<&(dyn StdError + 'static)> = self
            .source
            .as_ref()
            .map(|b| b.as_ref() as &(dyn StdError + 'static));
        while let Some(e) = cur {
            if let Some(t) = e.downcast_ref::<T>() {
                return Some(t);
            }
            cur = e.source();
        }
        None
    }

    fn write_chain(&self, f: &mut fmt::Formatter<'_>, sep: &str) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur: Option<&(dyn StdError + 'static)> = self
            .source
            .as_ref()
            .map(|b| b.as_ref() as &(dyn StdError + 'static));
        while let Some(e) = cur {
            write!(f, "{sep}{e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

/// Private adapter so an [`Error`] can sit inside another error's cause
/// chain (`Error` itself deliberately does not implement `StdError`).
struct Chained(Error);

impl fmt::Display for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)
    }
}

impl fmt::Debug for Chained {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:?}", self.0)
    }
}

impl StdError for Chained {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.0
            .source
            .as_ref()
            .map(|b| b.as_ref() as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            self.write_chain(f, ": ")
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cur: Option<&(dyn StdError + 'static)> = self
            .source
            .as_ref()
            .map(|b| b.as_ref() as &(dyn StdError + 'static));
        if cur.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = cur {
            write!(f, "\n    {e}")?;
            cur = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T, Error> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

/// Construct an [`Error`] from a format string (or a displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tt:tt)*) => {
        return Err($crate::anyhow!($($tt)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_plain_and_alternate() {
        let e = Error::msg("top");
        assert_eq!(format!("{e}"), "top");
        assert_eq!(format!("{e:#}"), "top");
    }

    #[test]
    fn context_chains_in_alternate_format() {
        let e: Error = Error::new(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        let e2 = e.context("loading registry");
        assert_eq!(
            format!("{e2:#}"),
            "loading registry: reading manifest: missing file"
        );
    }

    #[test]
    fn result_context_helpers() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("outer").unwrap_err();
        assert_eq!(format!("{e:#}"), "outer: missing file");

        let r2: Result<()> = Err(Error::msg("inner"));
        let e2 = r2.with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(format!("{e2:#}"), "step 3: inner");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let n: i32 = "17".parse()?;
            Ok(n)
        }
        assert_eq!(parse().unwrap(), 17);
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: usize) -> Result<()> {
            if x > 3 {
                bail!("too big: {x}");
            }
            Err(anyhow!("always fails with {}", x))
        }
        assert_eq!(format!("{}", f(9).unwrap_err()), "too big: 9");
        assert_eq!(format!("{}", f(1).unwrap_err()), "always fails with 1");
    }

    #[test]
    fn downcast_ref_finds_typed_errors_through_context() {
        let e: Error = Error::new(io_err());
        assert_eq!(
            e.downcast_ref::<std::io::Error>().unwrap().kind(),
            std::io::ErrorKind::NotFound
        );
        // Context wraps stay transparent to downcasting.
        let wrapped = e.context("outer").context("outermost");
        assert!(wrapped.downcast_ref::<std::io::Error>().is_some());
        // Absent types answer None, as does a message-only error.
        assert!(wrapped.downcast_ref::<std::fmt::Error>().is_none());
        assert!(Error::msg("plain").downcast_ref::<std::io::Error>().is_none());
    }

    #[test]
    fn debug_shows_cause_list() {
        let e = Error::new(io_err()).context("ctx");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("ctx"), "{dbg}");
        assert!(dbg.contains("Caused by"), "{dbg}");
        assert!(dbg.contains("missing file"), "{dbg}");
    }
}
