//! Offline stub of the `xla` (PJRT) bindings.
//!
//! The real bindings (and the PJRT CPU plugin they load) are not vendored
//! in this image.  This stub mirrors the API surface `fastdds::runtime`
//! uses so the crate builds and tests offline:
//!
//! - **Host-side literals are fully functional** (typed storage, reshape,
//!   shape queries, round-trips) — `runtime::value` and its tests work
//!   unchanged.
//! - **Device entry points fail gracefully** ([`PjRtClient::cpu`],
//!   compilation, execution): fastdds gates every dispatch behind
//!   `runtime::artifacts_available(..)` and converts a failed client
//!   construction into per-request errors, so artifact-backed paths report
//!   "unavailable" while pure-rust oracle paths are unaffected.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml` — the types and signatures below match.

use std::fmt;
use std::path::Path;

/// Error type standing in for the binding layer's status codes.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: xla/PJRT bindings are not available in this build \
         (vendored stub; see rust/vendor/xla)"
    )))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
    /// Catch-all so shape decoding can report unsupported dtypes.
    Unsupported,
}

/// Typed host buffer backing a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl LiteralData {
    fn len(&self) -> usize {
        match self {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
        }
    }

    fn ty(&self) -> ElementType {
        match self {
            LiteralData::F32(_) => ElementType::F32,
            LiteralData::I32(_) => ElementType::S32,
        }
    }
}

/// Host-native element types accepted by [`Literal`] constructors.
pub trait NativeType: Copy {
    fn wrap(data: Vec<Self>) -> LiteralData
    where
        Self: Sized;
    fn slice(data: &LiteralData) -> Option<&[Self]>
    where
        Self: Sized;
}

impl NativeType for f32 {
    fn wrap(data: Vec<f32>) -> LiteralData {
        LiteralData::F32(data)
    }
    fn slice(data: &LiteralData) -> Option<&[f32]> {
        match data {
            LiteralData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn wrap(data: Vec<i32>) -> LiteralData {
        LiteralData::I32(data)
    }
    fn slice(data: &LiteralData) -> Option<&[i32]> {
        match data {
            LiteralData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Array shape metadata (dims + element type).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Host literal: typed data plus dims ([] = scalar).  Fully functional on
/// the host; only device transfers are stubbed.
#[derive(Debug, Clone)]
pub struct Literal {
    data: LiteralData,
    dims: Vec<i64>,
}

impl Literal {
    pub fn scalar<T: NativeType>(value: T) -> Literal {
        Literal { data: T::wrap(vec![value]), dims: Vec::new() }
    }

    pub fn vec1<T: NativeType>(values: &[T]) -> Literal {
        Literal {
            data: T::wrap(values.to_vec()),
            dims: vec![values.len() as i64],
        }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n < 0 || n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into dims {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone(), ty: self.data.ty() })
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match T::slice(&self.data) {
            Some(s) => Ok(s.to_vec()),
            None => Err(Error("to_vec: element type mismatch".to_string())),
        }
    }

    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.clone())
    }
}

/// Parsed HLO module proto (stub).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// XLA computation wrapper (stub).
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device buffer returned by an executable (stub).
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Mirrors the real signature: generic over host input kind, returns
    /// per-device, per-output buffers.
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle (stub).
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literals_work_on_host() {
        let lit = Literal::vec1(&[1.5f32, -2.0, 3.0, 4.0]);
        let shaped = lit.reshape(&[2, 2]).unwrap();
        let shape = shaped.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.5, -2.0, 3.0, 4.0]);
        assert!(shaped.to_vec::<i32>().is_err());

        let s = Literal::scalar(7i32);
        assert!(s.array_shape().unwrap().dims().is_empty());
        assert_eq!(s.to_vec::<i32>().unwrap(), vec![7]);
        assert!(Literal::vec1(&[1i32, 2]).reshape(&[3]).is_err());
    }

    #[test]
    fn device_entry_points_fail_gracefully() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("nope.hlo.txt").is_err());
        let err = Literal::scalar(1.0f32).decompose_tuple().unwrap_err();
        assert!(format!("{err}").contains("not available"), "{err}");
    }
}
