//! Content-addressed artifact registry: digest-keyed schedules, oracles
//! and corpora shared across serving nodes (ROADMAP item 5).
//!
//! # Why
//!
//! Deploying the high-order solvers across a fleet means every node
//! re-fits tuned schedules and rebuilds oracles locally — there is no
//! way to *name*, *verify* or *share* an artifact.  This module is the
//! missing naming layer: a small versioned manifest over content-hashed
//! blobs, the same shape container registries use, reachable both as a
//! library (`ArtifactRegistry`) and over the serving wire
//! (`registry_put` / `registry_get` / `registry_list` / `registry_stat`,
//! see [`crate::server`]).
//!
//! # Digest format
//!
//! Every address is the lowercase-hex SHA-256 of the addressed bytes
//! (64 chars, `[0-9a-f]`; [`crate::util::sha256`]).  Blobs are addressed
//! by their content; a manifest is addressed by the SHA-256 of its
//! canonical JSON encoding ([`Manifest::to_json`] → `to_string`, sorted
//! keys, no whitespace).  Addresses are *verified on every read*: a
//! lookup re-hashes what it read and answers a typed
//! [`RegistryError::Integrity`] (`integrity_failure` on the wire) on any
//! mismatch, so a truncated or bit-flipped file on disk can fail a
//! request but can never be served as the artifact it claims to be.
//!
//! # On-disk layout & atomicity contract
//!
//! ```text
//! <root>/blobs/<sha256-hex>          raw blob bytes
//! <root>/manifests/<sha256-hex>.json canonical manifest JSON
//! ```
//!
//! All writes go to a temp file in the destination directory followed by
//! `rename`, so concurrent readers (including other processes sharing
//! the directory — the multi-node story is "point N nodes at one
//! registry root") observe either nothing or the complete file, never a
//! prefix.  Publishing order is blobs-then-manifest: a manifest is only
//! visible once every blob it references is durably in place.
//!
//! # Manifest schema
//!
//! See [`manifest`]: a versioned enum (`schema: 1` today) carrying the
//! artifact kind (`tuned_schedule` | `score_model` | `compat_corpus`),
//! the model coordinates (`family`/`vocab`/`seq_len` + `solver`/`steps`
//! for schedules), free-form `name`/`created_by` metadata, and the
//! ordered digest list of content blobs.  Future schemas upgrade at
//! parse time (the wire-v1→v2 shim pattern), never invalidating old
//! directories.
//!
//! # Consumers
//!
//! * [`crate::schedule::ScheduleCache`] in registry-backed mode pulls a
//!   tuned grid by digest instead of re-fitting ([`ArtifactRegistry::
//!   find_tuned`]) and publishes fresh fits ([`ArtifactRegistry::
//!   publish_tuned`]) so the *first* node to fit pays the pilots for the
//!   whole fleet.
//! * `serve --oracle digest:<hex>` builds an in-process Markov/HMM
//!   oracle from a `score_model` blob ([`oracle_from_score_model`]).

pub mod blob;
pub mod manifest;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::schedule::tuner::{TuneKey, TunedSchedule};
use crate::score::markov::MarkovChain;
use crate::score::ScoreSource;
use crate::util::json::Json;
use crate::util::sha256::sha256_hex;

pub use blob::BlobStore;
pub use manifest::{ArtifactKind, Manifest, ManifestV1};

/// Typed registry failures.  `code()` is the stable machine-readable
/// string a wire error frame carries (see the table in
/// [`crate::api::wire`]).
#[derive(Debug)]
pub enum RegistryError {
    /// No blob/manifest under this digest.
    NotFound(String),
    /// The bytes on disk no longer hash to the digest that names them:
    /// the artifact is corrupt and was NOT returned.
    Integrity { digest: String, actual: String },
    /// The supplied address is not a 64-char lowercase-hex digest.
    InvalidDigest(String),
    /// The manifest failed to parse or carries an unknown schema/kind.
    BadManifest(String),
    /// The server has no `--registry-dir` configured.
    Disabled,
}

impl RegistryError {
    pub fn code(&self) -> &'static str {
        match self {
            RegistryError::NotFound(_) => "not_found",
            RegistryError::Integrity { .. } => "integrity_failure",
            RegistryError::InvalidDigest(_) => "invalid_digest",
            RegistryError::BadManifest(_) => "bad_manifest",
            RegistryError::Disabled => "registry_disabled",
        }
    }
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RegistryError::NotFound(d) => write!(f, "no artifact under digest {d}"),
            RegistryError::Integrity { digest, actual } => write!(
                f,
                "integrity failure: content under {digest} hashes to {actual}; \
                 refusing to serve corrupted bytes"
            ),
            RegistryError::InvalidDigest(s) => {
                write!(f, "not a sha256 digest (64 lowercase hex chars): {s:?}")
            }
            RegistryError::BadManifest(msg) => write!(f, "bad manifest: {msg}"),
            RegistryError::Disabled => {
                write!(f, "this server has no artifact registry configured (--registry-dir)")
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// Validate an address: exactly 64 lowercase hex chars.  Doubles as the
/// path-safety gate — a digest that passes cannot contain `/`, `.` or
/// anything else that would escape the store directory.
pub fn check_digest(s: &str) -> Result<()> {
    if s.len() == 64 && s.bytes().all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b)) {
        Ok(())
    } else {
        Err(RegistryError::InvalidDigest(s.to_string()).into())
    }
}

/// Live counters + gauges, surfaced through the coordinator ledger and
/// the `stats` wire verb.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RegistryStats {
    pub puts: u64,
    pub gets: u64,
    pub integrity_failures: u64,
    /// Manifests on disk (distinct artifacts).
    pub manifests: u64,
    /// Content blobs on disk.
    pub blobs: u64,
    /// Total blob bytes on disk.
    pub blob_bytes: u64,
}

/// The registry root: a blob store plus a manifest directory plus the
/// operation counters.  Cheap to share (`Arc`) between the server's
/// wire verbs and the coordinator's schedule cache — both sides then
/// agree on one set of counters.
pub struct ArtifactRegistry {
    root: String,
    blobs: BlobStore,
    manifest_dir: String,
    puts: AtomicU64,
    gets: AtomicU64,
    integrity_failures: AtomicU64,
}

impl ArtifactRegistry {
    /// Open (creating if missing) a registry rooted at `root`.
    pub fn open(root: &str) -> Result<Arc<ArtifactRegistry>> {
        let blobs = BlobStore::open(root)?;
        let manifest_dir = format!("{root}/manifests");
        std::fs::create_dir_all(&manifest_dir)
            .with_context(|| format!("creating manifest dir {manifest_dir:?}"))?;
        Ok(Arc::new(ArtifactRegistry {
            root: root.to_string(),
            blobs,
            manifest_dir,
            puts: AtomicU64::new(0),
            gets: AtomicU64::new(0),
            integrity_failures: AtomicU64::new(0),
        }))
    }

    pub fn root(&self) -> &str {
        &self.root
    }

    fn manifest_path(&self, digest: &str) -> String {
        format!("{}/{digest}.json", self.manifest_dir)
    }

    /// Count an error against the integrity ledger when it is one.
    fn tally(&self, err: anyhow::Error) -> anyhow::Error {
        if matches!(err.downcast_ref::<RegistryError>(), Some(RegistryError::Integrity { .. })) {
            self.integrity_failures.fetch_add(1, Ordering::Relaxed);
        }
        err
    }

    /// Publish an artifact: store every blob, fill the manifest's digest
    /// list in order, store the manifest, return its digest (the
    /// artifact's address).  Blobs-then-manifest ordering means a
    /// concurrent reader never sees a manifest whose blobs are missing.
    pub fn put(&self, mut m: ManifestV1, blob_data: &[&[u8]]) -> Result<String> {
        m.blobs = blob_data
            .iter()
            .map(|data| self.blobs.put(data))
            .collect::<Result<Vec<String>>>()?;
        let manifest = Manifest::V1(m);
        let text = manifest.to_json().to_string();
        let digest = sha256_hex(text.as_bytes());
        let path = self.manifest_path(&digest);
        if std::fs::metadata(&path).is_err() {
            let tmp = format!("{}/.tmp-{}-{digest}", self.manifest_dir, std::process::id());
            std::fs::write(&tmp, &text).with_context(|| format!("writing {tmp:?}"))?;
            if let Err(e) = std::fs::rename(&tmp, &path) {
                let _ = std::fs::remove_file(&tmp);
                return Err(e).with_context(|| format!("publishing manifest {digest}"));
            }
        }
        self.puts.fetch_add(1, Ordering::Relaxed);
        Ok(digest)
    }

    /// Load and verify the manifest at `digest` (the file bytes must
    /// hash back to the address, then parse as a known schema).
    pub fn manifest(&self, digest: &str) -> Result<Manifest> {
        self.manifest_inner(digest).map_err(|e| self.tally(e))
    }

    fn manifest_inner(&self, digest: &str) -> Result<Manifest> {
        check_digest(digest)?;
        let path = self.manifest_path(digest);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(RegistryError::NotFound(digest.to_string()).into());
            }
            Err(e) => return Err(e).with_context(|| format!("reading manifest {digest}")),
        };
        let actual = sha256_hex(text.as_bytes());
        if actual != digest {
            return Err(RegistryError::Integrity {
                digest: digest.to_string(),
                actual,
            }
            .into());
        }
        Manifest::parse(&text)
    }

    /// Fetch a full artifact: the manifest plus every blob, all
    /// integrity-checked.  Nothing is returned unless *everything*
    /// verified.
    pub fn get(&self, digest: &str) -> Result<(Manifest, Vec<Vec<u8>>)> {
        let out = (|| {
            let manifest = self.manifest_inner(digest)?;
            let blobs = manifest
                .v1()
                .blobs
                .iter()
                .map(|d| self.blobs.get(d))
                .collect::<Result<Vec<Vec<u8>>>>()?;
            Ok((manifest, blobs))
        })()
        .map_err(|e| self.tally(e));
        if out.is_ok() {
            self.gets.fetch_add(1, Ordering::Relaxed);
        }
        out
    }

    /// Manifest + per-blob (digest, on-disk size if present) without
    /// fetching content.
    pub fn stat(&self, digest: &str) -> Result<(Manifest, Vec<(String, Option<u64>)>)> {
        let manifest = self.manifest(digest)?;
        let stats = manifest
            .v1()
            .blobs
            .iter()
            .map(|d| (d.clone(), self.blobs.size(d)))
            .collect();
        Ok((manifest, stats))
    }

    /// Every (digest, manifest) in the registry, optionally filtered by
    /// kind and/or family, sorted by digest for a stable listing.
    /// Unreadable or corrupt manifests are *skipped* here (a listing
    /// must not die because one entry rotted — fetching that entry by
    /// digest still fails typed).
    pub fn list(
        &self,
        kind: Option<ArtifactKind>,
        family: Option<&str>,
    ) -> Vec<(String, Manifest)> {
        let mut out: Vec<(String, Manifest)> = Vec::new();
        let Ok(entries) = std::fs::read_dir(&self.manifest_dir) else {
            return out;
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let Some(name) = name.to_str() else { continue };
            let Some(stem) = name.strip_suffix(".json") else { continue };
            let Ok(m) = self.manifest_inner(stem) else { continue };
            let v1 = m.v1();
            if kind.map(|k| v1.kind != k).unwrap_or(false) {
                continue;
            }
            if family.map(|f| v1.family != f).unwrap_or(false) {
                continue;
            }
            out.push((stem.to_string(), m));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// Counter snapshot + on-disk gauges.
    pub fn stats(&self) -> RegistryStats {
        let (blobs, blob_bytes) = self.blobs.usage();
        let manifests = std::fs::read_dir(&self.manifest_dir)
            .map(|entries| {
                entries
                    .flatten()
                    .filter(|e| {
                        e.file_name().to_str().map(|n| n.ends_with(".json")).unwrap_or(false)
                    })
                    .count() as u64
            })
            .unwrap_or(0);
        RegistryStats {
            puts: self.puts.load(Ordering::Relaxed),
            gets: self.gets.load(Ordering::Relaxed),
            integrity_failures: self.integrity_failures.load(Ordering::Relaxed),
            manifests,
            blobs,
            blob_bytes,
        }
    }

    // ---- consumers -------------------------------------------------------

    /// Publish a tuned schedule (one JSON blob + a `tuned_schedule`
    /// manifest carrying its coordinates).  Returns the artifact digest.
    pub fn publish_tuned(&self, ts: &TunedSchedule, created_by: &str) -> Result<String> {
        let blob = ts.to_json().to_string();
        let m = ManifestV1 {
            kind: ArtifactKind::TunedSchedule,
            name: format!("tuned-{}-s{}", ts.family, ts.steps()),
            family: ts.family.clone(),
            vocab: ts.vocab,
            seq_len: ts.seq_len,
            solver: ts.solver.clone(),
            steps: ts.steps(),
            created_by: created_by.to_string(),
            blobs: Vec::new(),
        };
        self.put(m, &[blob.as_bytes()])
    }

    /// Look up a tuned schedule by its coordinates and pull it by
    /// digest.  `None` when no artifact matches or the match fails
    /// verification/parsing (a poisoned registry entry must degrade to
    /// "fit locally", never to a serving error — though an *integrity*
    /// failure still lands on the ledger via [`ArtifactRegistry::get`]).
    pub fn find_tuned(&self, key: &TuneKey) -> Option<Arc<TunedSchedule>> {
        for (digest, m) in self.list(Some(ArtifactKind::TunedSchedule), Some(&key.family)) {
            let v1 = m.v1();
            if v1.vocab != key.vocab
                || v1.seq_len != key.seq_len
                || v1.solver != key.solver
                || v1.steps != key.steps
            {
                continue;
            }
            let (_, blobs) = match self.get(&digest) {
                Ok(out) => out,
                Err(e) => {
                    eprintln!("registry: artifact {digest} unusable: {e:#}");
                    continue;
                }
            };
            let Some(first) = blobs.first() else { continue };
            let parsed = String::from_utf8(first.clone())
                .map_err(anyhow::Error::from)
                .and_then(|text| TunedSchedule::from_json(&Json::parse(&text)?));
            match parsed {
                Ok(ts) if &ts.key() == key => return Some(Arc::new(ts)),
                Ok(ts) => eprintln!(
                    "registry: artifact {digest} manifest coordinates disagree \
                     with its schedule payload ({:?} vs {:?}); skipping",
                    ts.key(),
                    key
                ),
                Err(e) => eprintln!("registry: artifact {digest} blob unparsable: {e:#}"),
            }
        }
        None
    }
}

// ---- score-model blobs ---------------------------------------------------

/// Serialize an oracle description (`"markov"` or `"hmm"` over a
/// [`MarkovChain`]) as a `score_model` blob.
pub fn score_model_blob(oracle: &str, chain: &MarkovChain, seq_len: usize) -> Vec<u8> {
    let rows: Vec<Json> = (0..chain.vocab)
        .map(|r| {
            Json::Arr((0..chain.vocab).map(|c| Json::Num(chain.at(r, c))).collect())
        })
        .collect();
    Json::obj(vec![
        ("oracle", Json::from(oracle)),
        ("vocab", Json::from(chain.vocab)),
        ("seq_len", Json::from(seq_len)),
        ("transition", Json::Arr(rows)),
        ("stationary", Json::Arr(chain.pi.iter().map(|&p| Json::Num(p)).collect())),
    ])
    .to_string()
    .into_bytes()
}

/// Publish a score model, returning its artifact digest.
pub fn publish_score_model(
    reg: &ArtifactRegistry,
    oracle: &str,
    chain: &MarkovChain,
    seq_len: usize,
    name: &str,
    created_by: &str,
) -> Result<String> {
    let blob = score_model_blob(oracle, chain, seq_len);
    let m = ManifestV1 {
        kind: ArtifactKind::ScoreModel,
        name: name.to_string(),
        family: oracle.to_string(),
        vocab: chain.vocab,
        seq_len,
        solver: String::new(),
        steps: 0,
        created_by: created_by.to_string(),
        blobs: Vec::new(),
    };
    reg.put(m, &[&blob])
}

/// Rebuild the in-process oracle a `score_model` blob describes.
/// Returns (oracle, vocab, seq_len) — the serve CLI prints the shape.
pub fn oracle_from_score_model(data: &[u8]) -> Result<(Arc<dyn ScoreSource>, usize, usize)> {
    let text = std::str::from_utf8(data)
        .map_err(|e| RegistryError::BadManifest(format!("score_model blob is not utf-8: {e}")))?;
    let j = Json::parse(text)?;
    let which = j.get("oracle")?.as_str()?.to_string();
    let vocab = j.get("vocab")?.as_usize()?;
    let seq_len = j.get("seq_len")?.as_usize()?;
    let a_mat = j.get("transition")?.as_f64_mat()?;
    let pi = j.get("stationary")?.as_f64_vec()?;
    let mut a = Vec::with_capacity(vocab * vocab);
    for row in &a_mat {
        a.extend_from_slice(row);
    }
    let chain = MarkovChain::new(vocab, a, pi);
    let oracle: Arc<dyn ScoreSource> = match which.as_str() {
        "markov" => Arc::new(crate::score::markov::MarkovOracle::new(chain, seq_len)),
        "hmm" => Arc::new(crate::score::hmm::HmmUniformOracle::new(chain, seq_len)),
        other => {
            return Err(RegistryError::BadManifest(format!(
                "unknown score_model oracle {other:?} (markov|hmm)"
            ))
            .into())
        }
    };
    Ok((oracle, vocab, seq_len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::Solver;
    use crate::util::rng::Xoshiro256;

    fn temp_registry(tag: &str) -> (String, Arc<ArtifactRegistry>) {
        let root = std::env::temp_dir()
            .join(format!("fastdds_reg_{}_{tag}", std::process::id()));
        let root = root.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&root);
        let reg = ArtifactRegistry::open(&root).unwrap();
        (root, reg)
    }

    #[test]
    fn put_get_stat_list_roundtrip() {
        let (root, reg) = temp_registry("roundtrip");
        let m = ManifestV1::new(ArtifactKind::CompatCorpus, "corpus-a");
        let digest = reg.put(m, &[b"line one", b"line two"]).unwrap();
        check_digest(&digest).unwrap();

        let (manifest, blobs) = reg.get(&digest).unwrap();
        assert_eq!(manifest.v1().name, "corpus-a");
        assert_eq!(blobs, vec![b"line one".to_vec(), b"line two".to_vec()]);
        // The manifest digest is reproducible from the returned manifest.
        assert_eq!(manifest.digest(), digest);

        let (_, blob_stats) = reg.stat(&digest).unwrap();
        assert_eq!(blob_stats.len(), 2);
        assert!(blob_stats.iter().all(|(_, size)| size.is_some()));

        let listed = reg.list(Some(ArtifactKind::CompatCorpus), None);
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].0, digest);
        assert!(reg.list(Some(ArtifactKind::ScoreModel), None).is_empty());

        let s = reg.stats();
        assert_eq!(s.puts, 1);
        assert_eq!(s.gets, 1);
        assert_eq!(s.integrity_failures, 0);
        assert_eq!(s.manifests, 1);
        assert_eq!(s.blobs, 2);
        assert_eq!(s.blob_bytes, 16);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_manifest_fails_typed_and_counts() {
        let (root, reg) = temp_registry("poison");
        let digest = reg
            .put(ManifestV1::new(ArtifactKind::CompatCorpus, "x"), &[b"payload"])
            .unwrap();
        // Flip one byte of the manifest file: its digest no longer
        // matches its address.
        let path = format!("{root}/manifests/{digest}.json");
        let mut text = std::fs::read(&path).unwrap();
        let last = text.len() - 2;
        text[last] ^= 0x01;
        std::fs::write(&path, &text).unwrap();
        let err = reg.get(&digest).unwrap_err();
        assert_eq!(
            err.downcast_ref::<RegistryError>().unwrap().code(),
            "integrity_failure"
        );
        assert_eq!(reg.stats().integrity_failures, 1);
        assert_eq!(reg.stats().gets, 0, "a failed get must not count as served");
        // A rotten entry disappears from listings but other artifacts
        // stay reachable.
        let ok = reg
            .put(ManifestV1::new(ArtifactKind::CompatCorpus, "y"), &[b"fine"])
            .unwrap();
        let listed = reg.list(None, None);
        assert_eq!(listed.len(), 1);
        assert_eq!(listed[0].0, ok);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn tuned_schedule_publish_and_find() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let (root, reg) = temp_registry("tuned");
        let mut rng = Xoshiro256::seed_from_u64(11);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 6, 0.5), 12);
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let ts = crate::schedule::ScheduleTuner { pilots: 1, ..Default::default() }
            .fit_masked(&oracle, solver, 8, 1e-3, "markov");
        let key = ts.key();
        let digest = reg.publish_tuned(&ts, "test").unwrap();

        let found = reg.find_tuned(&key).expect("published schedule must be findable");
        assert_eq!(found.grid, ts.grid);

        // Wrong coordinates find nothing.
        let mut other = key.clone();
        other.steps = 9;
        assert!(reg.find_tuned(&other).is_none());

        // The stat view carries the schedule coordinates.
        let (m, _) = reg.stat(&digest).unwrap();
        assert_eq!(m.v1().kind, ArtifactKind::TunedSchedule);
        assert_eq!(m.v1().solver, "trapezoidal:0.5");
        assert_eq!(m.v1().steps, 8);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn score_model_blob_roundtrips_to_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(23);
        let chain = MarkovChain::generate(&mut rng, 5, 0.5);
        for which in ["markov", "hmm"] {
            let blob = score_model_blob(which, &chain, 10);
            let (oracle, vocab, seq_len) = oracle_from_score_model(&blob).unwrap();
            assert_eq!(vocab, 5);
            assert_eq!(seq_len, 10);
            assert_eq!(oracle.vocab(), 5);
            assert_eq!(oracle.seq_len(), 10);
        }
        let err = oracle_from_score_model(
            br#"{"oracle":"warp","vocab":2,"seq_len":2,"transition":[[0.5,0.5],[0.5,0.5]],"stationary":[0.5,0.5]}"#,
        )
        .unwrap_err();
        assert_eq!(err.downcast_ref::<RegistryError>().unwrap().code(), "bad_manifest");
    }
}
