//! Content-addressed blob store: `<root>/blobs/<sha256-hex>`.
//!
//! Two invariants, both load-bearing for multi-node sharing:
//!
//! 1. **Atomicity** — a blob is written to a temp file in the same
//!    directory and `rename`d into place, so a reader (possibly another
//!    process on a shared filesystem) never observes a half-written
//!    blob: the digest-named file either does not exist or is complete.
//! 2. **Verified reads** — every `get` re-hashes the bytes it read and
//!    compares against the requested digest.  A truncated or bit-flipped
//!    file yields a typed [`RegistryError::Integrity`] (`integrity_failure`
//!    on the wire); corrupted content is *never* returned to a caller.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{Context, Result};

use crate::util::sha256::sha256_hex;

use super::{check_digest, RegistryError};

/// Uniquifier for temp-file names: two threads (or two puts of the same
/// content racing) must never share a temp path.  Combined with the pid
/// so two *processes* on a shared registry dir cannot collide either.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

pub struct BlobStore {
    dir: String,
}

impl BlobStore {
    /// Open (creating if missing) the blob directory under `root`.
    pub fn open(root: &str) -> Result<BlobStore> {
        let dir = format!("{root}/blobs");
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating blob dir {dir:?}"))?;
        Ok(BlobStore { dir })
    }

    fn path(&self, digest: &str) -> String {
        format!("{}/{digest}", self.dir)
    }

    /// Store `data`, returning its digest.  Write-to-temp-then-rename:
    /// concurrent putters of the same content race benignly (last rename
    /// wins, contents identical by construction).
    pub fn put(&self, data: &[u8]) -> Result<String> {
        let digest = sha256_hex(data);
        let final_path = self.path(&digest);
        // Already present: content-addressing makes this a no-op (and
        // skipping the write keeps a put racing a reader harmless).
        if std::fs::metadata(&final_path).is_ok() {
            return Ok(digest);
        }
        let tmp = format!(
            "{}/.tmp-{}-{}-{}",
            self.dir,
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed),
            &digest[..16]
        );
        std::fs::write(&tmp, data).with_context(|| format!("writing {tmp:?}"))?;
        if let Err(e) = std::fs::rename(&tmp, &final_path) {
            let _ = std::fs::remove_file(&tmp);
            return Err(e).with_context(|| format!("publishing blob {digest}"));
        }
        Ok(digest)
    }

    /// Fetch and verify a blob.  Typed failures: `invalid_digest` for a
    /// malformed address, `not_found` for an absent blob,
    /// `integrity_failure` when the bytes on disk no longer hash to the
    /// digest that names them.
    pub fn get(&self, digest: &str) -> Result<Vec<u8>> {
        check_digest(digest)?;
        let path = self.path(digest);
        let data = match std::fs::read(&path) {
            Ok(d) => d,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Err(RegistryError::NotFound(digest.to_string()).into());
            }
            Err(e) => return Err(e).with_context(|| format!("reading blob {digest}")),
        };
        let actual = sha256_hex(&data);
        if actual != digest {
            return Err(RegistryError::Integrity {
                digest: digest.to_string(),
                actual,
            }
            .into());
        }
        Ok(data)
    }

    /// Presence check (no content verification — use `get` to serve).
    pub fn has(&self, digest: &str) -> bool {
        check_digest(digest).is_ok() && std::fs::metadata(self.path(digest)).is_ok()
    }

    /// On-disk size of a blob, if present.
    pub fn size(&self, digest: &str) -> Option<u64> {
        std::fs::metadata(self.path(digest)).ok().map(|m| m.len())
    }

    /// (blob count, total bytes) across the store — the stats gauges.
    /// Stray temp files (a crashed writer's leftovers) are not counted:
    /// only digest-named entries are blobs.
    pub fn usage(&self) -> (u64, u64) {
        let mut count = 0u64;
        let mut bytes = 0u64;
        if let Ok(entries) = std::fs::read_dir(&self.dir) {
            for entry in entries.flatten() {
                let name = entry.file_name();
                let Some(name) = name.to_str() else { continue };
                if check_digest(name).is_err() {
                    continue;
                }
                if let Ok(meta) = entry.metadata() {
                    count += 1;
                    bytes += meta.len();
                }
            }
        }
        (count, bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn temp_store(tag: &str) -> (String, BlobStore) {
        let root = std::env::temp_dir()
            .join(format!("fastdds_blob_{}_{tag}", std::process::id()));
        let root = root.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&root);
        let store = BlobStore::open(&root).unwrap();
        (root, store)
    }

    #[test]
    fn put_get_roundtrip_and_dedup() {
        let (root, store) = temp_store("roundtrip");
        let d1 = store.put(b"hello registry").unwrap();
        assert_eq!(d1, sha256_hex(b"hello registry"));
        assert_eq!(store.get(&d1).unwrap(), b"hello registry");
        // Idempotent put: same digest, still one blob on disk.
        let d2 = store.put(b"hello registry").unwrap();
        assert_eq!(d1, d2);
        let (count, bytes) = store.usage();
        assert_eq!(count, 1);
        assert_eq!(bytes, b"hello registry".len() as u64);
        assert!(store.has(&d1));
        assert_eq!(store.size(&d1), Some(b"hello registry".len() as u64));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupted_blob_is_never_served() {
        let (root, store) = temp_store("corrupt");
        let digest = store.put(b"precious artifact bytes").unwrap();
        // Bit-flip on disk.
        let path = format!("{root}/blobs/{digest}");
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[3] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = store.get(&digest).unwrap_err();
        let re = err.downcast_ref::<RegistryError>().unwrap();
        assert_eq!(re.code(), "integrity_failure");
        // Truncation is caught the same way.
        std::fs::write(&path, b"precious").unwrap();
        let err = store.get(&digest).unwrap_err();
        assert_eq!(err.downcast_ref::<RegistryError>().unwrap().code(), "integrity_failure");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn typed_not_found_and_invalid_digest() {
        let (root, store) = temp_store("missing");
        let absent = sha256_hex(b"never stored");
        let err = store.get(&absent).unwrap_err();
        assert_eq!(err.downcast_ref::<RegistryError>().unwrap().code(), "not_found");
        // Malformed addresses die typed before touching the filesystem —
        // in particular a path-traversal "digest" never reaches open().
        for bad in ["", "abc", "../../etc/passwd", &"Z".repeat(64)] {
            let err = store.get(bad).unwrap_err();
            assert_eq!(
                err.downcast_ref::<RegistryError>().unwrap().code(),
                "invalid_digest",
                "{bad:?}"
            );
        }
        assert!(!store.has("not-a-digest"));
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn usage_ignores_temp_files() {
        let (root, store) = temp_store("usage");
        store.put(b"counted").unwrap();
        std::fs::write(format!("{root}/blobs/.tmp-999-0-deadbeef"), b"junk").unwrap();
        let (count, _) = store.usage();
        assert_eq!(count, 1, "stray temp files must not count as blobs");
        let _ = std::fs::remove_dir_all(&root);
    }
}
