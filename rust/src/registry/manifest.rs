//! Versioned artifact manifests.
//!
//! A manifest is the small JSON document that *names* an artifact: its
//! kind, the model coordinates it is valid for, creation metadata, and
//! the ordered digest list of its content blobs.  The enum is versioned
//! the same way the wire protocol is ([`crate::api::wire`]'s v1→v2 shim)
//! and the container registries this module is modeled on: readers match
//! on the `schema` field and route historical layouts through an upgrade
//! shim, so a registry directory written by an old binary stays readable
//! forever.  Only `V1` exists today; the reserved arm documents where
//! `V2` lands.

use anyhow::Result;

use crate::util::json::Json;
use crate::util::sha256::sha256_hex;

use super::RegistryError;

/// What an artifact *is* — the consumer-facing type tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    /// A fitted non-uniform grid ([`crate::schedule::TunedSchedule`]
    /// JSON): one blob, pulled by serving nodes instead of re-fitting.
    TunedSchedule,
    /// An oracle/score-model description (Markov chain or uniform-state
    /// HMM as JSON): one blob, `serve --oracle digest:<hex>` builds the
    /// in-process oracle from it.
    ScoreModel,
    /// A compatibility corpus (e.g. the v1 wire-replay corpus): any
    /// number of blobs, reproducible by digest across machines.
    CompatCorpus,
}

impl ArtifactKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            ArtifactKind::TunedSchedule => "tuned_schedule",
            ArtifactKind::ScoreModel => "score_model",
            ArtifactKind::CompatCorpus => "compat_corpus",
        }
    }

    pub fn parse(s: &str) -> Result<ArtifactKind> {
        match s {
            "tuned_schedule" => Ok(ArtifactKind::TunedSchedule),
            "score_model" => Ok(ArtifactKind::ScoreModel),
            "compat_corpus" => Ok(ArtifactKind::CompatCorpus),
            other => Err(RegistryError::BadManifest(format!(
                "unknown artifact kind {other:?} \
                 (tuned_schedule|score_model|compat_corpus)"
            ))
            .into()),
        }
    }
}

/// Schema-1 manifest body.
#[derive(Clone, Debug, PartialEq)]
pub struct ManifestV1 {
    pub kind: ArtifactKind,
    /// Human-readable handle (not an address; the digest is the address).
    pub name: String,
    /// Model coordinates the artifact is valid for.  `family` is the
    /// score family; `solver`/`steps` only mean something for
    /// `tuned_schedule` artifacts and are empty/0 otherwise.
    pub family: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub solver: String,
    pub steps: usize,
    /// Free-form provenance note ("node-a tuner", "make corpus", ...).
    pub created_by: String,
    /// Ordered content-blob digests (64-char lowercase hex each).
    pub blobs: Vec<String>,
}

impl ManifestV1 {
    /// A minimal manifest with empty schedule coordinates; callers fill
    /// the fields that apply to their kind.
    pub fn new(kind: ArtifactKind, name: &str) -> ManifestV1 {
        ManifestV1 {
            kind,
            name: name.to_string(),
            family: String::new(),
            vocab: 0,
            seq_len: 0,
            solver: String::new(),
            steps: 0,
            created_by: String::new(),
            blobs: Vec::new(),
        }
    }

    /// Parse the `manifest` object of a `registry_put` wire request:
    /// `kind` and `name` are required, coordinates and provenance
    /// optional.  The blob digest list is deliberately NOT read — the
    /// server computes it from the uploaded content, so a client can
    /// never claim blobs it did not send.
    pub fn from_wire(j: &Json) -> Result<ManifestV1> {
        let bad = |e: anyhow::Error| RegistryError::BadManifest(format!("{e:#}"));
        let kind_s =
            j.get("kind").and_then(|v| v.as_str().map(str::to_string)).map_err(bad)?;
        let name =
            j.get("name").and_then(|v| v.as_str().map(str::to_string)).map_err(bad)?;
        let mut m = ManifestV1::new(ArtifactKind::parse(&kind_s)?, &name);
        if let Some(v) = j.opt("family") {
            m.family = v.as_str().map_err(bad)?.to_string();
        }
        if let Some(v) = j.opt("vocab") {
            m.vocab = v.as_usize().map_err(bad)?;
        }
        if let Some(v) = j.opt("seq_len") {
            m.seq_len = v.as_usize().map_err(bad)?;
        }
        if let Some(v) = j.opt("solver") {
            m.solver = v.as_str().map_err(bad)?.to_string();
        }
        if let Some(v) = j.opt("steps") {
            m.steps = v.as_usize().map_err(bad)?;
        }
        if let Some(v) = j.opt("created_by") {
            m.created_by = v.as_str().map_err(bad)?.to_string();
        }
        Ok(m)
    }
}

/// A versioned manifest.  Readers pattern-match; writers always emit the
/// newest schema.  When a schema 2 arrives, the upgrade shim lives in
/// [`Manifest::from_json`] (parse the old layout, lift it to the new
/// arm) exactly like the v1 wire shim — old registry dirs keep working.
#[derive(Clone, Debug, PartialEq)]
pub enum Manifest {
    V1(ManifestV1),
    // V2(ManifestV2) — reserved; add the arm + from_json shim together.
}

impl Manifest {
    /// The current-schema view (upgrades happen at parse time, so this
    /// is total no matter which schema the manifest arrived in).
    pub fn v1(&self) -> &ManifestV1 {
        match self {
            Manifest::V1(m) => m,
        }
    }

    /// Canonical JSON encoding.  The manifest digest is the SHA-256 of
    /// exactly this string, so the encoding must stay deterministic —
    /// [`Json::Obj`] is a BTreeMap (sorted key order) and `to_string`
    /// has no whitespace degrees of freedom.
    pub fn to_json(&self) -> Json {
        match self {
            Manifest::V1(m) => Json::obj(vec![
                ("schema", Json::from(1u64)),
                ("kind", Json::from(m.kind.as_str())),
                ("name", Json::from(m.name.as_str())),
                ("family", Json::from(m.family.as_str())),
                ("vocab", Json::from(m.vocab)),
                ("seq_len", Json::from(m.seq_len)),
                ("solver", Json::from(m.solver.as_str())),
                ("steps", Json::from(m.steps)),
                ("created_by", Json::from(m.created_by.as_str())),
                (
                    "blobs",
                    Json::Arr(m.blobs.iter().map(|d| Json::from(d.as_str())).collect()),
                ),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let schema = j
            .get("schema")
            .and_then(|s| s.as_u64())
            .map_err(|e| RegistryError::BadManifest(format!("{e:#}")))?;
        match schema {
            1 => {
                let blobs = j
                    .get("blobs")
                    .and_then(|b| b.as_arr().map(|a| a.to_vec()))
                    .map_err(|e| RegistryError::BadManifest(format!("{e:#}")))?
                    .iter()
                    .map(|d| {
                        let hex = d
                            .as_str()
                            .map_err(|e| RegistryError::BadManifest(format!("{e:#}")))?;
                        super::check_digest(hex)?;
                        Ok(hex.to_string())
                    })
                    .collect::<Result<Vec<String>>>()?;
                let field = |k: &str| -> Result<String> {
                    Ok(j.get(k)
                        .and_then(|v| v.as_str().map(str::to_string))
                        .map_err(|e| RegistryError::BadManifest(format!("{e:#}")))?)
                };
                let num = |k: &str| -> Result<usize> {
                    Ok(j.get(k)
                        .and_then(|v| v.as_usize())
                        .map_err(|e| RegistryError::BadManifest(format!("{e:#}")))?)
                };
                Ok(Manifest::V1(ManifestV1 {
                    kind: ArtifactKind::parse(&field("kind")?)?,
                    name: field("name")?,
                    family: field("family")?,
                    vocab: num("vocab")?,
                    seq_len: num("seq_len")?,
                    solver: field("solver")?,
                    steps: num("steps")?,
                    created_by: field("created_by")?,
                    blobs,
                }))
            }
            // Future schemas upgrade here (the trow-style shim): parse
            // the old arm, lift to the current one, never error on age.
            other => Err(RegistryError::BadManifest(format!(
                "unsupported manifest schema {other} (this binary reads schema 1)"
            ))
            .into()),
        }
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text)
            .map_err(|e| RegistryError::BadManifest(format!("{e:#}")))?;
        Manifest::from_json(&j)
    }

    /// The artifact's address: SHA-256 of the canonical encoding.
    pub fn digest(&self) -> String {
        sha256_hex(self.to_json().to_string().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest::V1(ManifestV1 {
            kind: ArtifactKind::TunedSchedule,
            name: "markov-trap-8".into(),
            family: "markov".into(),
            vocab: 6,
            seq_len: 12,
            solver: "trapezoidal:0.5".into(),
            steps: 8,
            created_by: "test".into(),
            blobs: vec![crate::util::sha256::sha256_hex(b"grid")],
        })
    }

    #[test]
    fn roundtrip_preserves_digest() {
        let m = sample();
        let text = m.to_json().to_string();
        let back = Manifest::parse(&text).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.digest(), m.digest());
        assert_eq!(m.digest().len(), 64);
    }

    #[test]
    fn digest_tracks_content() {
        let m = sample();
        let mut other = m.v1().clone();
        other.steps = 9;
        assert_ne!(m.digest(), Manifest::V1(other).digest());
    }

    #[test]
    fn unknown_schema_and_kind_fail_typed() {
        let err = Manifest::parse(r#"{"schema": 99}"#).unwrap_err();
        let re = err.downcast_ref::<RegistryError>().unwrap();
        assert_eq!(re.code(), "bad_manifest");
        let mut j = sample().to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("kind".into(), Json::from("warp_field"));
        }
        let err = Manifest::from_json(&j).unwrap_err();
        assert_eq!(err.downcast_ref::<RegistryError>().unwrap().code(), "bad_manifest");
        // A malformed blob digest dies at parse, not at fetch time.
        let mut j = sample().to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("blobs".into(), Json::Arr(vec![Json::from("nothex")]));
        }
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn from_wire_requires_kind_and_name_and_ignores_blobs() {
        let j = Json::parse(
            r#"{"kind":"score_model","name":"m","vocab":5,"created_by":"cli",
                "blobs":["deadbeef"]}"#,
        )
        .unwrap();
        let m = ManifestV1::from_wire(&j).unwrap();
        assert_eq!(m.kind, ArtifactKind::ScoreModel);
        assert_eq!(m.vocab, 5);
        assert_eq!(m.created_by, "cli");
        assert!(m.blobs.is_empty(), "wire blob digests must never be trusted");
        let err =
            ManifestV1::from_wire(&Json::parse(r#"{"name":"x"}"#).unwrap()).unwrap_err();
        assert_eq!(err.downcast_ref::<RegistryError>().unwrap().code(), "bad_manifest");
    }

    #[test]
    fn kind_strings_roundtrip() {
        for k in [
            ArtifactKind::TunedSchedule,
            ArtifactKind::ScoreModel,
            ArtifactKind::CompatCorpus,
        ] {
            assert_eq!(ArtifactKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(ArtifactKind::parse("nope").is_err());
    }
}
