//! Serving metrics: request/lane/dispatch counters, latency distribution,
//! NFE accounting, batch occupancy — and the failure ledger (lane panics,
//! sheds, deadline rejections/expiries, supervisor restarts) so operators
//! can see faults without log-scraping (`stats` server verb).

use crate::util::json::Json;
use crate::util::stats::Online;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub lanes: u64,
    pub dispatches: u64,
    pub nfe_total: u64,
    // Parallel-in-time (Picard) driver accounting.
    /// Total PIT sweeps executed, summed over every lane served.
    pub pit_sweeps: u64,
    /// PIT lanes that converged (bit-exactly or within tolerance).
    pub pit_converged_lanes: u64,
    /// PIT lanes that hit `sweeps_max` and returned a typed partial.
    pub pit_sweep_limit_hits: u64,
    pub latency_ms: Online,
    pub occupancy: Online,
    pub queue_wait_ms: Online,
    // Failure ledger — each counter is one typed error path.
    /// Lanes that panicked during dispatch (typed `lane_failed`).
    pub lane_failures: u64,
    /// Requests shed at intake by the queue/in-flight caps (`overloaded`).
    pub sheds: u64,
    /// Requests rejected at intake as deadline-infeasible
    /// (`deadline_infeasible`).
    pub deadline_rejects: u64,
    /// Admitted requests whose deadline expired mid-run (completed with a
    /// partial response, not an error).
    pub deadline_expiries: u64,
    /// Scheduler-loop crashes the supervisor recovered from.
    pub supervisor_restarts: u64,
    // Backend health — the breaker/watchdog/retry ledger.
    /// Dispatch retries after a timed-out or transient attempt.
    pub retries: u64,
    /// Evals the stall watchdog timed out (each abandons the worker).
    pub eval_timeouts: u64,
    /// Batches failed typed `backend_unavailable` (breaker open, or eval
    /// retries exhausted).
    pub backend_unavailable: u64,
    /// Half-open probe dispatches admitted by the breaker.
    pub breaker_probes: u64,
    // Brownout ladder — degraded admissions by the highest rung applied.
    /// Rung 1: PIT decoupling turned off.
    pub degraded_rung1: u64,
    /// Rung 2: tuned/log schedule replaced by uniform.
    pub degraded_rung2: u64,
    /// Rung 3: NFE clamped toward the floor.
    pub degraded_rung3: u64,
    // Artifact registry ([`crate::registry`]) — patched into the snapshot
    // by [`super::Coordinator::metrics`] from the shared store's own
    // counters (the wire verbs bump them off the loop thread); all zero
    // when no registry is configured.
    /// Artifacts published (`registry_put` + schedule-cache publishes).
    pub registry_puts: u64,
    /// Artifacts served fully verified (`registry_get` + cache pulls).
    pub registry_gets: u64,
    /// Reads refused because on-disk bytes no longer hash to their
    /// address (typed `integrity_failure`; corrupted content never
    /// served).
    pub registry_integrity_failures: u64,
    /// Gauge: content blobs on disk at snapshot time.
    pub registry_blobs: u64,
    /// Gauge: total blob bytes on disk at snapshot time.
    pub registry_blob_bytes: u64,
    // Point-in-time gauges, filled when the snapshot is taken.
    /// Requests registered but not yet completed.
    pub in_flight: u64,
    /// Lanes sitting in the batcher queues.
    pub queued_lanes: u64,
    /// Entries in the shared cancel registry (leak canary: must drain to
    /// the in-flight count).
    pub registry_entries: u64,
    /// Circuit-breaker state at snapshot time: `closed` / `open` /
    /// `half-open` (empty until the first snapshot patches it in).
    pub breaker_state: String,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency_ms: Online::new(),
            occupancy: Online::new(),
            queue_wait_ms: Online::new(),
            ..Default::default()
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} lanes={} dispatches={} nfe={} \
             pit_sweeps={} pit_converged_lanes={} pit_sweep_limit_hits={} \
             latency_ms[p_mean={:.2} max={:.2}] occupancy_mean={:.2} \
             queue_wait_ms_mean={:.2} lane_failures={} sheds={} \
             deadline_rejects={} deadline_expiries={} supervisor_restarts={} \
             retries={} eval_timeouts={} backend_unavailable={} \
             breaker_state={} breaker_probes={} \
             degraded_rung1={} degraded_rung2={} degraded_rung3={} \
             registry_puts={} registry_gets={} registry_integrity_failures={} \
             registry_blobs={} registry_blob_bytes={} \
             in_flight={} queued_lanes={} registry_entries={}",
            self.requests,
            self.lanes,
            self.dispatches,
            self.nfe_total,
            self.pit_sweeps,
            self.pit_converged_lanes,
            self.pit_sweep_limit_hits,
            self.latency_ms.mean(),
            if self.latency_ms.n > 0 { self.latency_ms.max } else { 0.0 },
            self.occupancy.mean(),
            self.queue_wait_ms.mean(),
            self.lane_failures,
            self.sheds,
            self.deadline_rejects,
            self.deadline_expiries,
            self.supervisor_restarts,
            self.retries,
            self.eval_timeouts,
            self.backend_unavailable,
            if self.breaker_state.is_empty() { "closed" } else { &self.breaker_state },
            self.breaker_probes,
            self.degraded_rung1,
            self.degraded_rung2,
            self.degraded_rung3,
            self.registry_puts,
            self.registry_gets,
            self.registry_integrity_failures,
            self.registry_blobs,
            self.registry_blob_bytes,
            self.in_flight,
            self.queued_lanes,
            self.registry_entries,
        )
    }

    /// Samples per second over a wall-clock window.
    pub fn throughput(&self, window_secs: f64) -> f64 {
        if window_secs <= 0.0 {
            return 0.0;
        }
        self.lanes as f64 / window_secs
    }

    /// The `stats` server verb's payload: every counter and gauge, flat.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::from(self.requests)),
            ("lanes", Json::from(self.lanes)),
            ("dispatches", Json::from(self.dispatches)),
            ("nfe_total", Json::from(self.nfe_total)),
            ("pit_sweeps", Json::from(self.pit_sweeps)),
            ("pit_converged_lanes", Json::from(self.pit_converged_lanes)),
            ("pit_sweep_limit_hits", Json::from(self.pit_sweep_limit_hits)),
            ("latency_ms_mean", Json::Num(self.latency_ms.mean())),
            ("occupancy_mean", Json::Num(self.occupancy.mean())),
            ("queue_wait_ms_mean", Json::Num(self.queue_wait_ms.mean())),
            ("lane_failures", Json::from(self.lane_failures)),
            ("sheds", Json::from(self.sheds)),
            ("deadline_rejects", Json::from(self.deadline_rejects)),
            ("deadline_expiries", Json::from(self.deadline_expiries)),
            ("supervisor_restarts", Json::from(self.supervisor_restarts)),
            ("retries", Json::from(self.retries)),
            ("eval_timeouts", Json::from(self.eval_timeouts)),
            ("backend_unavailable", Json::from(self.backend_unavailable)),
            (
                "breaker_state",
                Json::Str(if self.breaker_state.is_empty() {
                    "closed".to_string()
                } else {
                    self.breaker_state.clone()
                }),
            ),
            ("breaker_probes", Json::from(self.breaker_probes)),
            ("degraded_rung1", Json::from(self.degraded_rung1)),
            ("degraded_rung2", Json::from(self.degraded_rung2)),
            ("degraded_rung3", Json::from(self.degraded_rung3)),
            ("registry_puts", Json::from(self.registry_puts)),
            ("registry_gets", Json::from(self.registry_gets)),
            (
                "registry_integrity_failures",
                Json::from(self.registry_integrity_failures),
            ),
            ("registry_blobs", Json::from(self.registry_blobs)),
            ("registry_blob_bytes", Json::from(self.registry_blob_bytes)),
            ("in_flight", Json::from(self.in_flight)),
            ("queued_lanes", Json::from(self.queued_lanes)),
            ("registry_entries", Json::from(self.registry_entries)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = Metrics::new();
        m.requests += 1;
        m.lanes += 4;
        m.latency_ms.push(10.0);
        m.latency_ms.push(20.0);
        m.occupancy.push(0.5);
        assert_eq!(m.requests, 1);
        assert!((m.latency_ms.mean() - 15.0).abs() < 1e-12);
        assert!(m.report().contains("lanes=4"));
        assert!((m.throughput(2.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_report_safe() {
        let m = Metrics::new();
        assert!(m.report().contains("requests=0"));
        assert_eq!(m.throughput(0.0), 0.0);
    }

    #[test]
    fn failure_ledger_in_report_and_json() {
        let mut m = Metrics::new();
        m.lane_failures = 2;
        m.sheds = 3;
        m.deadline_rejects = 4;
        m.deadline_expiries = 5;
        m.supervisor_restarts = 1;
        m.in_flight = 7;
        m.pit_sweeps = 11;
        m.pit_converged_lanes = 6;
        m.pit_sweep_limit_hits = 1;
        m.retries = 8;
        m.eval_timeouts = 2;
        m.backend_unavailable = 9;
        m.breaker_probes = 1;
        m.breaker_state = "half-open".to_string();
        m.degraded_rung1 = 10;
        m.degraded_rung3 = 12;
        m.registry_puts = 13;
        m.registry_gets = 14;
        m.registry_integrity_failures = 15;
        m.registry_blobs = 16;
        m.registry_blob_bytes = 1024;
        let r = m.report();
        for needle in [
            "pit_sweeps=11",
            "pit_converged_lanes=6",
            "pit_sweep_limit_hits=1",
            "lane_failures=2",
            "sheds=3",
            "deadline_rejects=4",
            "deadline_expiries=5",
            "supervisor_restarts=1",
            "retries=8",
            "eval_timeouts=2",
            "backend_unavailable=9",
            "breaker_state=half-open",
            "breaker_probes=1",
            "degraded_rung1=10",
            "degraded_rung2=0",
            "degraded_rung3=12",
            "registry_puts=13",
            "registry_gets=14",
            "registry_integrity_failures=15",
            "registry_blobs=16",
            "registry_blob_bytes=1024",
            "in_flight=7",
        ] {
            assert!(r.contains(needle), "{needle} missing from {r}");
        }
        let j = m.to_json();
        assert_eq!(j.get("lane_failures").unwrap().as_u64().unwrap(), 2);
        assert_eq!(j.get("pit_sweeps").unwrap().as_u64().unwrap(), 11);
        assert_eq!(j.get("pit_converged_lanes").unwrap().as_u64().unwrap(), 6);
        assert_eq!(j.get("pit_sweep_limit_hits").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("supervisor_restarts").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("registry_entries").unwrap().as_u64().unwrap(), 0);
        assert_eq!(j.get("retries").unwrap().as_u64().unwrap(), 8);
        assert_eq!(j.get("eval_timeouts").unwrap().as_u64().unwrap(), 2);
        assert_eq!(j.get("backend_unavailable").unwrap().as_u64().unwrap(), 9);
        assert_eq!(j.get("breaker_probes").unwrap().as_u64().unwrap(), 1);
        assert_eq!(j.get("breaker_state").unwrap().as_str().unwrap(), "half-open");
        assert_eq!(j.get("degraded_rung1").unwrap().as_u64().unwrap(), 10);
        assert_eq!(j.get("degraded_rung3").unwrap().as_u64().unwrap(), 12);
        assert_eq!(j.get("registry_puts").unwrap().as_u64().unwrap(), 13);
        assert_eq!(j.get("registry_gets").unwrap().as_u64().unwrap(), 14);
        assert_eq!(
            j.get("registry_integrity_failures").unwrap().as_u64().unwrap(),
            15
        );
        assert_eq!(j.get("registry_blobs").unwrap().as_u64().unwrap(), 16);
        assert_eq!(j.get("registry_blob_bytes").unwrap().as_u64().unwrap(), 1024);
        // A snapshot nobody patched reads as closed, not as "".
        let fresh = Metrics::new();
        assert!(fresh.report().contains("breaker_state=closed"));
        assert_eq!(
            fresh.to_json().get("breaker_state").unwrap().as_str().unwrap(),
            "closed"
        );
    }
}
