//! Serving metrics: request/lane/dispatch counters, latency distribution,
//! NFE accounting and batch occupancy.

use crate::util::stats::Online;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    pub requests: u64,
    pub lanes: u64,
    pub dispatches: u64,
    pub nfe_total: u64,
    pub latency_ms: Online,
    pub occupancy: Online,
    pub queue_wait_ms: Online,
}

impl Metrics {
    pub fn new() -> Self {
        Self {
            latency_ms: Online::new(),
            occupancy: Online::new(),
            queue_wait_ms: Online::new(),
            ..Default::default()
        }
    }

    pub fn report(&self) -> String {
        format!(
            "requests={} lanes={} dispatches={} nfe={} \
             latency_ms[p_mean={:.2} max={:.2}] occupancy_mean={:.2} \
             queue_wait_ms_mean={:.2}",
            self.requests,
            self.lanes,
            self.dispatches,
            self.nfe_total,
            self.latency_ms.mean(),
            if self.latency_ms.n > 0 { self.latency_ms.max } else { 0.0 },
            self.occupancy.mean(),
            self.queue_wait_ms.mean(),
        )
    }

    /// Samples per second over a wall-clock window.
    pub fn throughput(&self, window_secs: f64) -> f64 {
        if window_secs <= 0.0 {
            return 0.0;
        }
        self.lanes as f64 / window_secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut m = Metrics::new();
        m.requests += 1;
        m.lanes += 4;
        m.latency_ms.push(10.0);
        m.latency_ms.push(20.0);
        m.occupancy.push(0.5);
        assert_eq!(m.requests, 1);
        assert!((m.latency_ms.mean() - 15.0).abs() < 1e-12);
        assert!(m.report().contains("lanes=4"));
        assert!((m.throughput(2.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_report_safe() {
        let m = Metrics::new();
        assert!(m.report().contains("requests=0"));
        assert_eq!(m.throughput(0.0), 0.0);
    }
}
