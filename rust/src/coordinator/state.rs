//! In-flight request state: lanes complete out of order (different batches,
//! splits across dispatches); the assembler reunites them into responses.

use std::collections::BTreeMap;

use crate::coordinator::request::GenerateResponse;
use crate::score::Tok;

struct Pending {
    sequences: Vec<Option<Vec<Tok>>>,
    remaining: usize,
    nfe_used: usize,
    started_ms: f64,
    any_partial: bool,
}

/// Collects per-lane results; yields a response when a request completes.
#[derive(Default)]
pub struct ResponseAssembler {
    pending: BTreeMap<u64, Pending>,
}

impl ResponseAssembler {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, request_id: u64, n_samples: usize, started_ms: f64) {
        self.pending.insert(
            request_id,
            Pending {
                sequences: (0..n_samples).map(|_| None).collect(),
                remaining: n_samples,
                nfe_used: 0,
                started_ms,
                any_partial: false,
            },
        );
    }

    /// Record one completed lane; returns the response if that finished the
    /// request.  `now_ms` stamps latency; `partial` marks an interrupted
    /// lane (the response is partial if ANY lane was).
    pub fn complete_lane(
        &mut self,
        request_id: u64,
        sample_idx: usize,
        tokens: Vec<Tok>,
        nfe: usize,
        partial: bool,
        now_ms: f64,
    ) -> Option<GenerateResponse> {
        let p = self
            .pending
            .get_mut(&request_id)
            .unwrap_or_else(|| panic!("lane for unknown request {request_id}"));
        assert!(
            p.sequences[sample_idx].is_none(),
            "duplicate lane {request_id}/{sample_idx}"
        );
        p.sequences[sample_idx] = Some(tokens);
        p.remaining -= 1;
        p.nfe_used = p.nfe_used.max(nfe);
        p.any_partial |= partial;
        if p.remaining > 0 {
            return None;
        }
        let p = self.pending.remove(&request_id).unwrap();
        Some(GenerateResponse {
            id: request_id,
            sequences: p.sequences.into_iter().map(Option::unwrap).collect(),
            nfe_used: p.nfe_used,
            latency_ms: now_ms - p.started_ms,
            partial: p.any_partial,
            // The brownout echo lives on the request's sink, not the
            // per-lane state; the coordinator patches it in before the
            // response leaves the loop.
            degraded: None,
        })
    }

    /// Discard a request's pending state (batch failure / abort): later
    /// lanes must no longer exist for it — the caller purges them from the
    /// batcher — so the unknown-request panic in [`Self::complete_lane`]
    /// keeps guarding against genuine routing bugs.  Returns whether the
    /// request was pending.
    pub fn abort(&mut self, request_id: u64) -> bool {
        self.pending.remove(&request_id).is_some()
    }

    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    /// Whether the request is pending with NO lane completed yet.  Dispatch
    /// runs synchronously on the loop thread, so an untouched request's
    /// lanes all still sit in the batcher — it can be shed without wasting
    /// completed work or leaving orphaned lanes (priority load shedding).
    pub fn untouched(&self, request_id: u64) -> bool {
        self.pending
            .get(&request_id)
            .map(|p| p.remaining == p.sequences.len())
            .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assembles_out_of_order() {
        let mut a = ResponseAssembler::new();
        a.register(1, 3, 0.0);
        assert!(a.complete_lane(1, 2, vec![2], 16, false, 5.0).is_none());
        assert!(a.complete_lane(1, 0, vec![0], 16, false, 6.0).is_none());
        let r = a.complete_lane(1, 1, vec![1], 17, false, 7.5).unwrap();
        assert_eq!(r.sequences, vec![vec![0], vec![1], vec![2]]);
        assert_eq!(r.nfe_used, 17);
        assert!(!r.partial);
        assert!((r.latency_ms - 7.5).abs() < 1e-12);
        assert_eq!(a.in_flight(), 0);
    }

    #[test]
    fn one_partial_lane_marks_the_response() {
        let mut a = ResponseAssembler::new();
        a.register(1, 2, 0.0);
        assert!(a.complete_lane(1, 0, vec![1], 4, true, 1.0).is_none());
        let r = a.complete_lane(1, 1, vec![2], 4, false, 2.0).unwrap();
        assert!(r.partial, "any partial lane must mark the response partial");
    }

    #[test]
    fn untouched_tracks_first_lane() {
        let mut a = ResponseAssembler::new();
        a.register(1, 2, 0.0);
        assert!(a.untouched(1));
        a.complete_lane(1, 0, vec![1], 4, false, 1.0);
        assert!(!a.untouched(1), "a completed lane disqualifies shedding");
        assert!(!a.untouched(99), "unknown requests are not shed candidates");
    }

    #[test]
    fn abort_discards_pending_state() {
        let mut a = ResponseAssembler::new();
        a.register(1, 3, 0.0);
        a.complete_lane(1, 0, vec![1], 4, false, 1.0);
        assert!(a.abort(1), "request 1 was pending");
        assert_eq!(a.in_flight(), 0, "aborted state must not leak");
        assert!(!a.abort(1), "already gone");
    }

    #[test]
    fn multiple_requests_interleaved() {
        let mut a = ResponseAssembler::new();
        a.register(1, 1, 0.0);
        a.register(2, 2, 0.0);
        assert!(a.complete_lane(2, 0, vec![9], 8, false, 1.0).is_none());
        assert!(a.complete_lane(1, 0, vec![7], 8, false, 1.0).is_some());
        assert!(a.complete_lane(2, 1, vec![9], 8, false, 2.0).is_some());
    }

    #[test]
    #[should_panic(expected = "duplicate lane")]
    fn duplicate_lane_panics() {
        let mut a = ResponseAssembler::new();
        a.register(1, 2, 0.0);
        a.complete_lane(1, 0, vec![1], 4, false, 1.0);
        a.complete_lane(1, 0, vec![1], 4, false, 1.0);
    }
}
