//! Request/response types of the serving layer.

use crate::score::Tok;
use crate::solvers::Solver;
use crate::util::json::Json;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub id: u64,
    /// Artifact family: "markov" (oracle score) or "transformer".
    pub family: String,
    pub solver: Solver,
    /// Total score-evaluation budget per sample (the paper's NFE axis).
    pub nfe: usize,
    pub n_samples: usize,
    pub seed: u64,
}

impl GenerateRequest {
    pub fn from_json(j: &Json, id: u64) -> Result<GenerateRequest> {
        let solver = Solver::parse(j.get("solver")?.as_str()?)?;
        Ok(GenerateRequest {
            id,
            family: j
                .opt("family")
                .map(|f| f.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "markov".to_string()),
            solver,
            nfe: j.get("nfe")?.as_usize()?,
            n_samples: j.opt("n_samples").map(|v| v.as_usize()).transpose()?.unwrap_or(1),
            seed: j.opt("seed").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0) as u64,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("family", Json::from(self.family.as_str())),
            ("solver", Json::from(solver_string(self.solver).as_str())),
            ("nfe", Json::from(self.nfe)),
            ("n_samples", Json::from(self.n_samples)),
            ("seed", Json::from(self.seed as f64)),
        ])
    }
}

pub fn solver_string(s: Solver) -> String {
    match s {
        Solver::Euler => "euler".into(),
        Solver::TauLeaping => "tau".into(),
        Solver::Tweedie => "tweedie".into(),
        Solver::Trapezoidal { theta } => format!("trapezoidal:{theta}"),
        Solver::Rk2 { theta } => format!("rk2:{theta}"),
        Solver::ParallelDecoding => "parallel".into(),
    }
}

#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub sequences: Vec<Vec<Tok>>,
    /// Score evaluations actually spent per sample.
    pub nfe_used: usize,
    pub latency_ms: f64,
}

impl GenerateResponse {
    pub fn to_json(&self) -> Json {
        let seqs: Vec<Json> = self
            .sequences
            .iter()
            .map(|s| Json::Arr(s.iter().map(|&t| Json::Num(t as f64)).collect()))
            .collect();
        Json::obj(vec![
            ("id", Json::from(self.id as f64)),
            ("sequences", Json::Arr(seqs)),
            ("nfe_used", Json::from(self.nfe_used)),
            ("latency_ms", Json::from(self.latency_ms)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GenerateResponse> {
        let sequences = j
            .get("sequences")?
            .as_arr()?
            .iter()
            .map(|s| {
                s.as_arr()?
                    .iter()
                    .map(|t| Ok(t.as_f64()? as Tok))
                    .collect::<Result<Vec<Tok>>>()
            })
            .collect::<Result<_>>()?;
        Ok(GenerateResponse {
            id: j.get("id")?.as_f64()? as u64,
            sequences,
            nfe_used: j.get("nfe_used")?.as_usize()?,
            latency_ms: j.get("latency_ms")?.as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = GenerateRequest {
            id: 7,
            family: "markov".into(),
            solver: Solver::Trapezoidal { theta: 0.5 },
            nfe: 64,
            n_samples: 3,
            seed: 42,
        };
        let j = r.to_json();
        let back = GenerateRequest::from_json(&j, 7).unwrap();
        assert_eq!(back.solver, r.solver);
        assert_eq!(back.nfe, 64);
        assert_eq!(back.n_samples, 3);
        assert_eq!(back.seed, 42);
    }

    #[test]
    fn response_json_roundtrip() {
        let r = GenerateResponse {
            id: 3,
            sequences: vec![vec![1, 2, 3], vec![4, 5, 6]],
            nfe_used: 32,
            latency_ms: 12.5,
        };
        let back = GenerateResponse::from_json(&r.to_json()).unwrap();
        assert_eq!(back.sequences, r.sequences);
        assert_eq!(back.nfe_used, 32);
    }

    #[test]
    fn request_defaults() {
        let j = Json::parse(r#"{"solver": "tau", "nfe": 16}"#).unwrap();
        let r = GenerateRequest::from_json(&j, 1).unwrap();
        assert_eq!(r.family, "markov");
        assert_eq!(r.n_samples, 1);
        assert_eq!(r.solver, Solver::TauLeaping);
    }

    #[test]
    fn solver_string_roundtrip() {
        for s in [
            Solver::Euler,
            Solver::Trapezoidal { theta: 0.3 },
            Solver::Rk2 { theta: 0.25 },
        ] {
            assert_eq!(Solver::parse(&solver_string(s)).unwrap(), s);
        }
    }
}
