//! Request/response types of the serving layer.

use crate::ctmc::uniformization::ExactCfg;
use crate::schedule::ScheduleSpec;
use crate::score::Tok;
use crate::solvers::Solver;
use crate::util::json::Json;
use anyhow::Result;

#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub id: u64,
    /// Artifact family: "markov" (oracle score) or "transformer".
    pub family: String,
    pub solver: Solver,
    /// Total score-evaluation budget per sample (the paper's NFE axis).
    /// For fixed schedules it sets the step count; for adaptive schedules
    /// it only seeds the initial step size.
    pub nfe: usize,
    pub n_samples: usize,
    pub seed: u64,
    /// Time-discretisation policy (`"schedule"` field; default uniform).
    pub schedule: ScheduleSpec,
    /// Optional HARD per-sample NFE cap (`"nfe_budget"` field): the run —
    /// including the terminal denoise — never spends more.  Requires
    /// `nfe_budget >= nfe_per_step + 1`.
    pub nfe_budget: Option<usize>,
    /// Exact-path knob (`"window_ratio"` field, [`Solver::Exact`] only):
    /// geometric window ratio of the windowed uniformization, in (0, 1).
    pub window_ratio: Option<f64>,
    /// Exact-path knob (`"slack"` field, [`Solver::Exact`] only): thinning
    /// safety factor >= 1 applied to evaluated window bounds.
    pub slack: Option<f64>,
}

impl Default for GenerateRequest {
    fn default() -> Self {
        GenerateRequest {
            id: 0,
            family: "markov".into(),
            solver: Solver::Tweedie,
            nfe: 16,
            n_samples: 1,
            seed: 0,
            schedule: ScheduleSpec::Uniform,
            nfe_budget: None,
            window_ratio: None,
            slack: None,
        }
    }
}

impl GenerateRequest {
    pub fn from_json(j: &Json, id: u64) -> Result<GenerateRequest> {
        let solver = Solver::parse(j.get("solver")?.as_str()?)?;
        let schedule = j
            .opt("schedule")
            .map(|s| -> Result<ScheduleSpec> { ScheduleSpec::parse(s.as_str()?) })
            .transpose()?
            .unwrap_or_default();
        Ok(GenerateRequest {
            id,
            family: j
                .opt("family")
                .map(|f| f.as_str().map(str::to_string))
                .transpose()?
                .unwrap_or_else(|| "markov".to_string()),
            solver,
            nfe: j.get("nfe")?.as_usize()?,
            n_samples: j.opt("n_samples").map(|v| v.as_usize()).transpose()?.unwrap_or(1),
            seed: j.opt("seed").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0) as u64,
            schedule,
            nfe_budget: j.opt("nfe_budget").map(|v| v.as_usize()).transpose()?,
            window_ratio: j.opt("window_ratio").map(|v| v.as_f64()).transpose()?,
            slack: j.opt("slack").map(|v| v.as_f64()).transpose()?,
        })
    }

    /// Effective exact-path knobs: request values where given, the library
    /// defaults otherwise.  Also the batch-key identity for exact lanes.
    pub fn exact_cfg(&self) -> ExactCfg {
        let d = ExactCfg::default();
        ExactCfg {
            window_ratio: self.window_ratio.unwrap_or(d.window_ratio),
            slack: self.slack.unwrap_or(d.slack),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("family", Json::from(self.family.as_str())),
            ("solver", Json::from(solver_string(self.solver).as_str())),
            ("nfe", Json::from(self.nfe)),
            ("n_samples", Json::from(self.n_samples)),
            ("seed", Json::from(self.seed as f64)),
            ("schedule", Json::from(self.schedule.to_string_spec().as_str())),
        ];
        if let Some(b) = self.nfe_budget {
            fields.push(("nfe_budget", Json::from(b)));
        }
        if let Some(w) = self.window_ratio {
            fields.push(("window_ratio", Json::Num(w)));
        }
        if let Some(s) = self.slack {
            fields.push(("slack", Json::Num(s)));
        }
        Json::obj(fields)
    }
}

pub fn solver_string(s: Solver) -> String {
    s.spec_string()
}

#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub sequences: Vec<Vec<Tok>>,
    /// Score evaluations actually spent per sample.
    pub nfe_used: usize,
    pub latency_ms: f64,
}

impl GenerateResponse {
    pub fn to_json(&self) -> Json {
        let seqs: Vec<Json> = self
            .sequences
            .iter()
            .map(|s| Json::Arr(s.iter().map(|&t| Json::Num(t as f64)).collect()))
            .collect();
        Json::obj(vec![
            ("id", Json::from(self.id as f64)),
            ("sequences", Json::Arr(seqs)),
            ("nfe_used", Json::from(self.nfe_used)),
            ("latency_ms", Json::from(self.latency_ms)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<GenerateResponse> {
        let sequences = j
            .get("sequences")?
            .as_arr()?
            .iter()
            .map(|s| {
                s.as_arr()?
                    .iter()
                    .map(|t| Ok(t.as_f64()? as Tok))
                    .collect::<Result<Vec<Tok>>>()
            })
            .collect::<Result<_>>()?;
        Ok(GenerateResponse {
            id: j.get("id")?.as_f64()? as u64,
            sequences,
            nfe_used: j.get("nfe_used")?.as_usize()?,
            latency_ms: j.get("latency_ms")?.as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_json_roundtrip() {
        let r = GenerateRequest {
            id: 7,
            family: "markov".into(),
            solver: Solver::Trapezoidal { theta: 0.5 },
            nfe: 64,
            n_samples: 3,
            seed: 42,
            schedule: ScheduleSpec::Adaptive { tol: 1e-3 },
            nfe_budget: Some(48),
            window_ratio: None,
            slack: None,
        };
        let j = r.to_json();
        let back = GenerateRequest::from_json(&j, 7).unwrap();
        assert_eq!(back.solver, r.solver);
        assert_eq!(back.nfe, 64);
        assert_eq!(back.n_samples, 3);
        assert_eq!(back.seed, 42);
        assert_eq!(back.schedule, ScheduleSpec::Adaptive { tol: 1e-3 });
        assert_eq!(back.nfe_budget, Some(48));
        assert_eq!(back.window_ratio, None);
        assert_eq!(back.slack, None);
    }

    #[test]
    fn exact_knobs_roundtrip_and_default() {
        let j = Json::parse(
            r#"{"solver": "exact", "nfe": 16, "window_ratio": 0.8, "slack": 2.5}"#,
        )
        .unwrap();
        let r = GenerateRequest::from_json(&j, 1).unwrap();
        assert_eq!(r.window_ratio, Some(0.8));
        assert_eq!(r.slack, Some(2.5));
        let back = GenerateRequest::from_json(&r.to_json(), 1).unwrap();
        assert_eq!(back.window_ratio, Some(0.8));
        assert_eq!(back.slack, Some(2.5));
        assert_eq!(r.exact_cfg(), ExactCfg { window_ratio: 0.8, slack: 2.5 });

        // Absent knobs resolve to the library defaults.
        let j = Json::parse(r#"{"solver": "exact", "nfe": 16}"#).unwrap();
        let r = GenerateRequest::from_json(&j, 2).unwrap();
        assert_eq!(r.window_ratio, None);
        assert_eq!(r.exact_cfg(), ExactCfg::default());
    }

    #[test]
    fn request_schedule_defaults_and_tuned_roundtrip() {
        let j = Json::parse(r#"{"solver": "trapezoidal:0.5", "nfe": 32}"#).unwrap();
        let r = GenerateRequest::from_json(&j, 1).unwrap();
        assert_eq!(r.schedule, ScheduleSpec::Uniform);
        assert_eq!(r.nfe_budget, None);
        let j = Json::parse(
            r#"{"solver": "trapezoidal:0.5", "nfe": 32,
                "schedule": "tuned:steps=12", "nfe_budget": 24}"#,
        )
        .unwrap();
        let r = GenerateRequest::from_json(&j, 2).unwrap();
        assert_eq!(r.schedule, ScheduleSpec::Tuned { steps: 12 });
        assert_eq!(r.nfe_budget, Some(24));
        let back = GenerateRequest::from_json(&r.to_json(), 2).unwrap();
        assert_eq!(back.schedule, r.schedule);
        assert_eq!(back.nfe_budget, r.nfe_budget);
        assert!(GenerateRequest::from_json(
            &Json::parse(r#"{"solver": "tau", "nfe": 8, "schedule": "bogus"}"#).unwrap(),
            3
        )
        .is_err());
    }

    #[test]
    fn response_json_roundtrip() {
        let r = GenerateResponse {
            id: 3,
            sequences: vec![vec![1, 2, 3], vec![4, 5, 6]],
            nfe_used: 32,
            latency_ms: 12.5,
        };
        let back = GenerateResponse::from_json(&r.to_json()).unwrap();
        assert_eq!(back.sequences, r.sequences);
        assert_eq!(back.nfe_used, 32);
    }

    #[test]
    fn request_defaults() {
        let j = Json::parse(r#"{"solver": "tau", "nfe": 16}"#).unwrap();
        let r = GenerateRequest::from_json(&j, 1).unwrap();
        assert_eq!(r.family, "markov");
        assert_eq!(r.n_samples, 1);
        assert_eq!(r.solver, Solver::TauLeaping);
    }

    #[test]
    fn solver_string_roundtrip() {
        for s in [
            Solver::Euler,
            Solver::Trapezoidal { theta: 0.3 },
            Solver::Rk2 { theta: 0.25 },
        ] {
            assert_eq!(Solver::parse(&solver_string(s)).unwrap(), s);
        }
    }
}
