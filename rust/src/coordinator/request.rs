//! Request/response types of the serving layer.
//!
//! A request is just a serving id plus a typed [`SamplingSpec`] — the spec
//! is valid by construction (see [`crate::api`]), so nothing downstream of
//! this type re-validates anything.  The flat v1 JSON form and the
//! structured v2 form both parse through [`crate::api::wire`].

use crate::api::wire;
use crate::api::SamplingSpec;
use crate::score::Tok;
use crate::solvers::Solver;
use crate::util::json::Json;
use anyhow::Result;

/// One generation request in flight: the coordinator-assigned id plus the
/// validated spec.
#[derive(Clone, Debug)]
pub struct GenerateRequest {
    pub id: u64,
    pub spec: SamplingSpec,
}

impl GenerateRequest {
    pub fn new(id: u64, spec: SamplingSpec) -> GenerateRequest {
        GenerateRequest { id, spec }
    }

    /// Parse either wire form (flat v1 or `{"v":2,"spec":...}`) and attach
    /// the id.  Kept for tests and embedding users; the server parses via
    /// [`wire::request_from_json`] directly so it can keep the v1 echo.
    pub fn from_json(j: &Json, id: u64) -> Result<GenerateRequest> {
        let parsed = wire::request_from_json(j)?;
        Ok(GenerateRequest { id, spec: parsed.spec })
    }

    /// Serialize as a v2 envelope (the canonical wire form going forward).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("v", Json::from(wire::PROTOCOL_VERSION)),
            ("spec", wire::spec_to_json(&self.spec)),
        ])
    }
}

pub fn solver_string(s: Solver) -> String {
    s.spec_string()
}

#[derive(Clone, Debug)]
pub struct GenerateResponse {
    pub id: u64,
    pub sequences: Vec<Vec<Tok>>,
    /// Score evaluations actually spent per sample.
    pub nfe_used: usize,
    pub latency_ms: f64,
    /// Set when the run was interrupted (cancel verb or `max_events`): the
    /// sequences are whatever the solver had produced at the stop point —
    /// still-masked positions keep the mask id (= vocab).
    pub partial: bool,
    /// Brownout echo: the degradation-ladder rung applied at admission
    /// (1..=3, see `SamplingSpec::degrade`), `None` for undegraded
    /// requests.  Wire-emitted only when set, so undegraded responses
    /// keep the exact pre-brownout shape.
    pub degraded: Option<u8>,
}

impl GenerateResponse {
    pub fn to_json(&self) -> Json {
        let seqs: Vec<Json> = self
            .sequences
            .iter()
            .map(|s| Json::Arr(s.iter().map(|&t| Json::Num(t as f64)).collect()))
            .collect();
        let mut fields = vec![
            ("id", Json::from(self.id)),
            ("sequences", Json::Arr(seqs)),
            ("nfe_used", Json::from(self.nfe_used)),
            ("latency_ms", Json::from(self.latency_ms)),
        ];
        // Only present when set: complete responses keep the exact legacy
        // shape (bit-compatibility of the v1 protocol).
        if self.partial {
            fields.push(("partial", Json::Bool(true)));
        }
        if let Some(rung) = self.degraded {
            fields.push(("degraded", Json::from(rung as u64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<GenerateResponse> {
        let sequences = j
            .get("sequences")?
            .as_arr()?
            .iter()
            .map(|s| {
                s.as_arr()?
                    .iter()
                    .map(|t| Ok(t.as_f64()? as Tok))
                    .collect::<Result<Vec<Tok>>>()
            })
            .collect::<Result<_>>()?;
        Ok(GenerateResponse {
            id: j.get("id")?.as_u64()?,
            sequences,
            nfe_used: j.get("nfe_used")?.as_usize()?,
            latency_ms: j.get("latency_ms")?.as_f64()?,
            partial: j
                .opt("partial")
                .map(|p| p.as_bool())
                .transpose()?
                .unwrap_or(false),
            degraded: j
                .opt("degraded")
                .map(|d| d.as_u64())
                .transpose()?
                .map(|r| r as u8),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ScheduleSpec;

    #[test]
    fn request_round_trips_through_v2_envelope() {
        let spec = SamplingSpec::builder()
            .solver(Solver::Trapezoidal { theta: 0.5 })
            .nfe(64)
            .n_samples(3)
            .seed(42)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .nfe_budget(Some(48))
            .build()
            .unwrap();
        let r = GenerateRequest::new(7, spec);
        let back = GenerateRequest::from_json(&r.to_json(), 7).unwrap();
        assert_eq!(back.spec, r.spec);
        assert_eq!(back.id, 7);
    }

    #[test]
    fn v1_flat_requests_still_parse() {
        let j = Json::parse(
            r#"{"cmd": "generate", "solver": "trapezoidal:0.5", "nfe": 32,
                "schedule": "tuned:steps=12", "nfe_budget": 24, "seed": 9}"#,
        )
        .unwrap();
        let r = GenerateRequest::from_json(&j, 2).unwrap();
        assert_eq!(r.spec.solver(), Solver::Trapezoidal { theta: 0.5 });
        assert_eq!(r.spec.schedule(), ScheduleSpec::Tuned { steps: 12 });
        assert_eq!(r.spec.nfe_budget(), Some(24));
        assert_eq!(r.spec.seed(), 9);
        assert!(GenerateRequest::from_json(
            &Json::parse(r#"{"solver": "tau", "nfe": 8, "schedule": "bogus"}"#).unwrap(),
            3
        )
        .is_err());
    }

    #[test]
    fn response_json_roundtrip() {
        let r = GenerateResponse {
            id: u64::MAX - 3,
            sequences: vec![vec![1, 2, 3], vec![4, 5, 6]],
            nfe_used: 32,
            latency_ms: 12.5,
            partial: false,
            degraded: None,
        };
        let back = GenerateResponse::from_json(&Json::parse(&r.to_json().to_string()).unwrap())
            .unwrap();
        assert_eq!(back.sequences, r.sequences);
        assert_eq!(back.nfe_used, 32);
        // u64 ids survive the wire losslessly (the old f64 path corrupted
        // anything above 2^53).
        assert_eq!(back.id, u64::MAX - 3);
        assert!(!back.partial);
        // Partial responses carry the marker; complete ones omit it so the
        // legacy v1 shape is byte-identical.
        assert!(!r.to_json().to_string().contains("partial"));
        let p = GenerateResponse { partial: true, ..r.clone() };
        let t = p.to_json().to_string();
        assert!(t.contains("\"partial\":true"), "{t}");
        assert!(GenerateResponse::from_json(&Json::parse(&t).unwrap()).unwrap().partial);
        // Same only-when-set rule for the brownout echo.
        assert!(!r.to_json().to_string().contains("degraded"));
        let d = GenerateResponse { degraded: Some(3), ..r };
        let t = d.to_json().to_string();
        assert!(t.contains("\"degraded\":3"), "{t}");
        let back = GenerateResponse::from_json(&Json::parse(&t).unwrap()).unwrap();
        assert_eq!(back.degraded, Some(3));
    }

    #[test]
    fn solver_string_roundtrip() {
        for s in [
            Solver::Euler,
            Solver::Trapezoidal { theta: 0.3 },
            Solver::Rk2 { theta: 0.25 },
        ] {
            assert_eq!(Solver::parse(&solver_string(s)).unwrap(), s);
        }
    }
}
