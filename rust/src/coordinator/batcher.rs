//! Dynamic batcher: packs sample lanes from compatible requests into
//! fixed-shape artifact batches.
//!
//! Compatibility is decided by [`BatchKey::of`] over the request's typed
//! spec — the key hashes the *resolved execution plan*
//! ([`crate::api::ExecPlan`]), so lanes co-batch exactly when they would
//! execute identically (same family, kernel, discretisation / exact-path
//! configuration).  Two policies (ablated in `exp::ablations`):
//!   - `Greedy`: dispatch as soon as any lane is available (min latency);
//!   - `Timeout(ms)`: hold partially full batches up to a deadline to
//!     improve occupancy (min cost per sample).

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::api::SamplingSpec;
use crate::coordinator::request::GenerateRequest;
use crate::util::cancel::CancelToken;

pub use crate::api::BatchKey;

/// One sample lane of a request.
#[derive(Clone, Debug)]
pub struct Lane {
    pub request_id: u64,
    pub sample_idx: usize,
    pub seed: u64,
    pub enqueued: Instant,
    /// The request's cancel token (a never-token for non-cancellable
    /// submissions): exact lanes poll it individually; lock-step scheme
    /// batches poll it when the whole batch shares one token.
    pub cancel: CancelToken,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchPolicy {
    Greedy,
    Timeout(Duration),
}

pub struct DynamicBatcher {
    pub policy: BatchPolicy,
    /// Artifact batch size (lanes per dispatch).
    pub max_lanes: usize,
    queues: BTreeMap<BatchKey, VecDeque<(Lane, SamplingSpec)>>,
    pub enqueued_lanes: usize,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy, max_lanes: usize) -> Self {
        assert!(max_lanes >= 1);
        Self { policy, max_lanes, queues: BTreeMap::new(), enqueued_lanes: 0 }
    }

    /// Split a request into lanes and enqueue them.  `cancel` is the
    /// request's token (pass [`CancelToken::never`] for non-cancellable
    /// submissions).
    pub fn enqueue(&mut self, req: GenerateRequest, cancel: CancelToken) {
        let key = BatchKey::of(&req.spec);
        let q = self.queues.entry(key).or_default();
        for sample_idx in 0..req.spec.n_samples() {
            let lane = Lane {
                request_id: req.id,
                sample_idx,
                // Per-lane stream: request seed + lane index spread
                // (the spec owns the stride — part of the wire contract).
                seed: req.spec.lane_seed(sample_idx),
                enqueued: Instant::now(),
                cancel: cancel.clone(),
            };
            q.push_back((lane, req.spec.clone()));
            self.enqueued_lanes += 1;
        }
    }

    /// Pop the next dispatchable batch under the policy, if any.  The
    /// returned spec is the prototype every lane of the batch shares — by
    /// key construction, all co-batched specs have identical execution
    /// plans, so any of them serves.
    pub fn next_batch(&mut self, now: Instant) -> Option<(BatchKey, SamplingSpec, Vec<Lane>)> {
        let key = self.queues.iter().find_map(|(key, q)| {
            // Empty queues (front() is None) are skipped, not dispatchable.
            let front = q.front()?;
            let full = q.len() >= self.max_lanes;
            let due = match self.policy {
                BatchPolicy::Greedy => true,
                BatchPolicy::Timeout(d) => full || now.duration_since(front.0.enqueued) >= d,
            };
            if due {
                Some(*key)
            } else {
                None
            }
        })?;
        let q = self.queues.get_mut(&key)?;
        let take = q.len().min(self.max_lanes);
        let mut lanes = Vec::with_capacity(take);
        let mut proto = None;
        while lanes.len() < take {
            let Some((lane, spec)) = q.pop_front() else { break };
            proto.get_or_insert(spec);
            lanes.push(lane);
            self.enqueued_lanes -= 1;
        }
        proto.map(|p| (key, p, lanes))
    }

    pub fn pending(&self) -> usize {
        self.enqueued_lanes
    }

    /// Drop every still-queued lane of a request (the request failed or
    /// was aborted — executing its remaining lanes would be wasted work
    /// landing in an assembler entry that no longer exists).  Returns the
    /// number of lanes removed.
    pub fn purge_request(&mut self, request_id: u64) -> usize {
        let mut removed = 0usize;
        for q in self.queues.values_mut() {
            let before = q.len();
            q.retain(|(lane, _)| lane.request_id != request_id);
            removed += before - q.len();
        }
        self.enqueued_lanes -= removed;
        removed
    }

    /// Mean occupancy a dispatch would get right now (metrics).
    pub fn occupancy_if_dispatched(&self) -> f64 {
        let ready: Vec<usize> = self
            .queues
            .values()
            .filter(|q| !q.is_empty())
            .map(|q| q.len().min(self.max_lanes))
            .collect();
        if ready.is_empty() {
            return 0.0;
        }
        ready.iter().sum::<usize>() as f64 / (ready.len() * self.max_lanes) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::ScheduleSpec;
    use crate::solvers::Solver;

    fn req(id: u64, solver: Solver, nfe: usize, n: usize) -> GenerateRequest {
        GenerateRequest::new(
            id,
            SamplingSpec::builder()
                .solver(solver)
                .nfe(nfe)
                .n_samples(n)
                .seed(id * 100)
                .build()
                .unwrap(),
        )
    }

    fn enq(b: &mut DynamicBatcher, r: GenerateRequest) {
        b.enqueue(r, CancelToken::never());
    }

    #[test]
    fn schedule_and_budget_split_keys() {
        let base = req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 1);
        let adaptive = GenerateRequest::new(
            2,
            SamplingSpec::builder()
                .solver(Solver::Trapezoidal { theta: 0.5 })
                .nfe(32)
                .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
                .build()
                .unwrap(),
        );
        let budgeted = GenerateRequest::new(
            3,
            SamplingSpec::builder()
                .solver(Solver::Trapezoidal { theta: 0.5 })
                .nfe(32)
                .nfe_budget(Some(17))
                .build()
                .unwrap(),
        );
        assert_ne!(BatchKey::of(&base.spec), BatchKey::of(&adaptive.spec));
        assert_ne!(BatchKey::of(&base.spec), BatchKey::of(&budgeted.spec));
        assert_eq!(BatchKey::of(&base.spec), BatchKey::of(&base.spec.clone()));
    }

    #[test]
    fn greedy_dispatches_immediately() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        enq(&mut b, req(1, Solver::TauLeaping, 32, 3));
        let (_, proto, lanes) = b.next_batch(Instant::now()).unwrap();
        assert_eq!(lanes.len(), 3);
        assert_eq!(proto.n_samples(), 3);
        assert!(b.next_batch(Instant::now()).is_none());
    }

    #[test]
    fn batches_group_by_key_only() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        enq(&mut b, req(1, Solver::TauLeaping, 32, 2));
        enq(&mut b, req(2, Solver::TauLeaping, 32, 2));
        enq(&mut b, req(3, Solver::Euler, 32, 2));
        // Two batches total (key order is unspecified): tau lanes from
        // requests 1 and 2 co-batch; euler stays separate.
        let mut batches = Vec::new();
        while let Some((_, proto, lanes)) = b.next_batch(Instant::now()) {
            batches.push((proto.solver(), lanes));
        }
        assert_eq!(batches.len(), 2);
        let tau = batches
            .iter()
            .find(|(s, _)| *s == Solver::TauLeaping)
            .unwrap();
        assert_eq!(tau.1.len(), 4);
        let ids: Vec<u64> = tau.1.iter().map(|l| l.request_id).collect();
        assert!(ids.contains(&1) && ids.contains(&2) && !ids.contains(&3));
        let euler = batches.iter().find(|(s, _)| *s == Solver::Euler).unwrap();
        assert_eq!(euler.1.len(), 2);
    }

    #[test]
    fn resolved_grids_co_batch_across_raw_nfe() {
        // nfe=64 and nfe=65 resolve to the same 32-step uniform grid for a
        // two-stage scheme: their lanes must share one batch (the
        // pre-redesign raw-NFE key split them for no execution reason).
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        enq(&mut b, req(1, Solver::Trapezoidal { theta: 0.5 }, 64, 2));
        enq(&mut b, req(2, Solver::Trapezoidal { theta: 0.5 }, 65, 2));
        let (_, _, lanes) = b.next_batch(Instant::now()).unwrap();
        assert_eq!(lanes.len(), 4, "equal resolved plans must co-batch");
        assert!(b.next_batch(Instant::now()).is_none());
    }

    #[test]
    fn exact_knobs_split_keys_with_resolution() {
        use crate::ctmc::uniformization::{DEFAULT_SLACK, DEFAULT_WINDOW_RATIO};
        let base = req(1, Solver::Exact, 16, 1);
        let tuned = GenerateRequest::new(
            2,
            SamplingSpec::builder()
                .solver(Solver::Exact)
                .slack(Some(8.0))
                .build()
                .unwrap(),
        );
        assert_ne!(BatchKey::of(&base.spec), BatchKey::of(&tuned.spec));
        // Explicit defaults co-batch with knob-free exact requests: the
        // builder resolves them to the identical spec.
        let explicit = SamplingSpec::builder()
            .solver(Solver::Exact)
            .nfe(16)
            .seed(100)
            .window_ratio(Some(DEFAULT_WINDOW_RATIO))
            .slack(Some(DEFAULT_SLACK))
            .build()
            .unwrap();
        assert_eq!(BatchKey::of(&base.spec), BatchKey::of(&explicit));
    }

    #[test]
    fn theta_distinguishes_keys() {
        let a = BatchKey::of(&req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 1).spec);
        let b = BatchKey::of(&req(2, Solver::Trapezoidal { theta: 0.3 }, 32, 1).spec);
        let c = BatchKey::of(&req(3, Solver::Trapezoidal { theta: 0.5 }, 32, 1).spec);
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn max_lanes_splits_large_requests() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 4);
        enq(&mut b, req(1, Solver::TauLeaping, 16, 10));
        let (_, _, l1) = b.next_batch(Instant::now()).unwrap();
        let (_, _, l2) = b.next_batch(Instant::now()).unwrap();
        let (_, _, l3) = b.next_batch(Instant::now()).unwrap();
        assert_eq!((l1.len(), l2.len(), l3.len()), (4, 4, 2));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_policy_waits_then_fires() {
        let mut b = DynamicBatcher::new(
            BatchPolicy::Timeout(Duration::from_millis(50)),
            8,
        );
        enq(&mut b, req(1, Solver::TauLeaping, 16, 2));
        let now = Instant::now();
        assert!(b.next_batch(now).is_none(), "should hold under-full batch");
        let later = now + Duration::from_millis(60);
        let got = b.next_batch(later);
        assert!(got.is_some(), "deadline passed, must dispatch");
    }

    #[test]
    fn timeout_policy_fires_immediately_when_full() {
        let mut b = DynamicBatcher::new(
            BatchPolicy::Timeout(Duration::from_secs(100)),
            4,
        );
        enq(&mut b, req(1, Solver::TauLeaping, 16, 4));
        assert!(b.next_batch(Instant::now()).is_some());
    }

    #[test]
    fn purge_request_drops_only_that_requests_lanes() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        enq(&mut b, req(1, Solver::TauLeaping, 16, 3));
        enq(&mut b, req(2, Solver::TauLeaping, 16, 2));
        assert_eq!(b.purge_request(1), 3);
        assert_eq!(b.pending(), 2);
        let (_, _, lanes) = b.next_batch(Instant::now()).unwrap();
        assert!(lanes.iter().all(|l| l.request_id == 2));
        assert_eq!(b.purge_request(99), 0);
    }

    #[test]
    fn lane_seeds_distinct_and_tokens_shared() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        let token = CancelToken::new();
        b.enqueue(req(1, Solver::TauLeaping, 16, 5), token.clone());
        let (_, _, lanes) = b.next_batch(Instant::now()).unwrap();
        let mut seeds: Vec<u64> = lanes.iter().map(|l| l.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 5);
        // Every lane of the request shares the request's token.
        assert!(lanes.iter().all(|l| CancelToken::same(&l.cancel, &token)));
    }
}
