//! Dynamic batcher: packs sample lanes from compatible requests into
//! fixed-shape artifact batches.
//!
//! Compatibility key = (family, solver, NFE, schedule, NFE budget): every
//! lane of a batch must run the same step graph over the same time grid —
//! for adaptive schedules, lanes of one batch vote on a single shared dt,
//! so the controller parameters must also match.  Two policies (ablated in
//! `exp::ablations`):
//!   - `Greedy`: dispatch as soon as any lane is available (min latency);
//!   - `Timeout(ms)`: hold partially full batches up to a deadline to
//!     improve occupancy (min cost per sample).

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::coordinator::request::GenerateRequest;
use crate::solvers::Solver;

/// One sample lane of a request.
#[derive(Clone, Debug)]
pub struct Lane {
    pub request_id: u64,
    pub sample_idx: usize,
    pub seed: u64,
    pub enqueued: Instant,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct BatchKey {
    pub family_hash: u64,
    pub solver_kind: u8,
    /// theta bits (exact f64) for the two-stage solvers, 0 otherwise.
    pub theta_bits: u64,
    pub nfe: usize,
    /// Schedule identity ([`crate::schedule::ScheduleSpec::key_bits`]).
    pub schedule_kind: u8,
    pub schedule_bits: u64,
    /// Hard NFE budget + 1 (0 = unbudgeted).
    pub budget_plus1: u64,
    /// Exact-path knob identity (effective-value bits for exact lanes,
    /// 0 otherwise): lanes of one exact batch must share the knobs the
    /// scheduler threads through to the simulator.
    pub exact_wr_bits: u64,
    pub exact_slack_bits: u64,
}

impl BatchKey {
    pub fn of(req: &GenerateRequest) -> BatchKey {
        let (kind, theta) = match req.solver {
            Solver::Euler => (0u8, 0.0),
            Solver::TauLeaping => (1, 0.0),
            Solver::Tweedie => (2, 0.0),
            Solver::Trapezoidal { theta } => (3, theta),
            Solver::Rk2 { theta } => (4, theta),
            Solver::ParallelDecoding => (5, 0.0),
            Solver::Exact => (6, 0.0),
        };
        let (schedule_kind, schedule_bits) = req.schedule.key_bits();
        // Key on the EFFECTIVE knob values (request or default) so an
        // explicit request for the defaults co-batches with a knob-free one.
        let (exact_wr_bits, exact_slack_bits) = match req.solver {
            Solver::Exact => {
                let cfg = req.exact_cfg();
                (cfg.window_ratio.to_bits(), cfg.slack.to_bits())
            }
            _ => (0, 0),
        };
        BatchKey {
            family_hash: crate::testkit::fnv1a(&req.family),
            solver_kind: kind,
            theta_bits: theta.to_bits(),
            nfe: req.nfe,
            schedule_kind,
            schedule_bits,
            budget_plus1: req.nfe_budget.map(|b| b as u64 + 1).unwrap_or(0),
            exact_wr_bits,
            exact_slack_bits,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BatchPolicy {
    Greedy,
    Timeout(Duration),
}

pub struct DynamicBatcher {
    pub policy: BatchPolicy,
    /// Artifact batch size (lanes per dispatch).
    pub max_lanes: usize,
    queues: BTreeMap<BatchKey, VecDeque<(Lane, GenerateRequest)>>,
    pub enqueued_lanes: usize,
}

impl DynamicBatcher {
    pub fn new(policy: BatchPolicy, max_lanes: usize) -> Self {
        assert!(max_lanes >= 1);
        Self { policy, max_lanes, queues: BTreeMap::new(), enqueued_lanes: 0 }
    }

    /// Split a request into lanes and enqueue them.
    pub fn enqueue(&mut self, req: GenerateRequest) {
        let key = BatchKey::of(&req);
        let q = self.queues.entry(key).or_default();
        for sample_idx in 0..req.n_samples {
            let lane = Lane {
                request_id: req.id,
                sample_idx,
                // Per-lane stream: request seed + lane index spread.
                seed: req
                    .seed
                    .wrapping_add((sample_idx as u64).wrapping_mul(0x9E3779B97F4A7C15)),
                enqueued: Instant::now(),
            };
            q.push_back((lane, req.clone()));
            self.enqueued_lanes += 1;
        }
    }

    /// Pop the next dispatchable batch under the policy, if any.
    pub fn next_batch(&mut self, now: Instant) -> Option<(BatchKey, GenerateRequest, Vec<Lane>)> {
        let key = {
            let mut chosen: Option<BatchKey> = None;
            for (key, q) in self.queues.iter() {
                if q.is_empty() {
                    continue;
                }
                let full = q.len() >= self.max_lanes;
                let due = match self.policy {
                    BatchPolicy::Greedy => true,
                    BatchPolicy::Timeout(d) => {
                        full || now.duration_since(q.front().unwrap().0.enqueued) >= d
                    }
                };
                if due {
                    chosen = Some(*key);
                    break;
                }
            }
            chosen?
        };
        let q = self.queues.get_mut(&key).unwrap();
        let take = q.len().min(self.max_lanes);
        let mut lanes = Vec::with_capacity(take);
        let mut proto = None;
        for _ in 0..take {
            let (lane, req) = q.pop_front().unwrap();
            proto.get_or_insert(req);
            lanes.push(lane);
            self.enqueued_lanes -= 1;
        }
        Some((key, proto.unwrap(), lanes))
    }

    pub fn pending(&self) -> usize {
        self.enqueued_lanes
    }

    /// Mean occupancy a dispatch would get right now (metrics).
    pub fn occupancy_if_dispatched(&self) -> f64 {
        let ready: Vec<usize> = self
            .queues
            .values()
            .filter(|q| !q.is_empty())
            .map(|q| q.len().min(self.max_lanes))
            .collect();
        if ready.is_empty() {
            return 0.0;
        }
        ready.iter().sum::<usize>() as f64 / (ready.len() * self.max_lanes) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, solver: Solver, nfe: usize, n: usize) -> GenerateRequest {
        GenerateRequest {
            id,
            family: "markov".into(),
            solver,
            nfe,
            n_samples: n,
            seed: id * 100,
            ..Default::default()
        }
    }

    #[test]
    fn schedule_and_budget_split_keys() {
        use crate::schedule::ScheduleSpec;
        let base = req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 1);
        let mut adaptive = base.clone();
        adaptive.schedule = ScheduleSpec::Adaptive { tol: 1e-3 };
        let mut budgeted = base.clone();
        budgeted.nfe_budget = Some(32);
        assert_ne!(BatchKey::of(&base), BatchKey::of(&adaptive));
        assert_ne!(BatchKey::of(&base), BatchKey::of(&budgeted));
        assert_eq!(BatchKey::of(&base), BatchKey::of(&base.clone()));
        let mut adaptive2 = adaptive.clone();
        adaptive2.schedule = ScheduleSpec::Adaptive { tol: 2e-3 };
        assert_ne!(BatchKey::of(&adaptive), BatchKey::of(&adaptive2));
    }

    #[test]
    fn greedy_dispatches_immediately() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        b.enqueue(req(1, Solver::TauLeaping, 32, 3));
        let (_, proto, lanes) = b.next_batch(Instant::now()).unwrap();
        assert_eq!(lanes.len(), 3);
        assert_eq!(proto.id, 1);
        assert!(b.next_batch(Instant::now()).is_none());
    }

    #[test]
    fn batches_group_by_key_only() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        b.enqueue(req(1, Solver::TauLeaping, 32, 2));
        b.enqueue(req(2, Solver::TauLeaping, 32, 2));
        b.enqueue(req(3, Solver::Euler, 32, 2));
        // Two batches total (key order is unspecified): tau lanes from
        // requests 1 and 2 co-batch; euler stays separate.
        let mut batches = Vec::new();
        while let Some((_, proto, lanes)) = b.next_batch(Instant::now()) {
            batches.push((proto.solver, lanes));
        }
        assert_eq!(batches.len(), 2);
        let tau = batches
            .iter()
            .find(|(s, _)| *s == Solver::TauLeaping)
            .unwrap();
        assert_eq!(tau.1.len(), 4);
        let ids: Vec<u64> = tau.1.iter().map(|l| l.request_id).collect();
        assert!(ids.contains(&1) && ids.contains(&2) && !ids.contains(&3));
        let euler = batches.iter().find(|(s, _)| *s == Solver::Euler).unwrap();
        assert_eq!(euler.1.len(), 2);
    }

    #[test]
    fn exact_knobs_split_keys_only_for_exact() {
        use crate::ctmc::uniformization::{DEFAULT_SLACK, DEFAULT_WINDOW_RATIO};
        let base = req(1, Solver::Exact, 16, 1);
        let mut tuned = base.clone();
        tuned.slack = Some(2.0);
        assert_ne!(BatchKey::of(&base), BatchKey::of(&tuned));
        let mut ratio = base.clone();
        ratio.window_ratio = Some(0.9);
        assert_ne!(BatchKey::of(&base), BatchKey::of(&ratio));
        // Explicit defaults co-batch with knob-free exact requests.
        let mut explicit = base.clone();
        explicit.window_ratio = Some(DEFAULT_WINDOW_RATIO);
        explicit.slack = Some(DEFAULT_SLACK);
        assert_eq!(BatchKey::of(&base), BatchKey::of(&explicit));
        // Knobs are inert (zeroed) in non-exact keys.
        let mut tau = req(2, Solver::TauLeaping, 16, 1);
        let k1 = BatchKey::of(&tau);
        tau.slack = Some(9.0);
        assert_eq!(k1, BatchKey::of(&tau));
    }

    #[test]
    fn theta_distinguishes_keys() {
        let a = BatchKey::of(&req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 1));
        let b = BatchKey::of(&req(2, Solver::Trapezoidal { theta: 0.3 }, 32, 1));
        let c = BatchKey::of(&req(3, Solver::Trapezoidal { theta: 0.5 }, 32, 1));
        assert_ne!(a, b);
        assert_eq!(a, c);
    }

    #[test]
    fn max_lanes_splits_large_requests() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 4);
        b.enqueue(req(1, Solver::TauLeaping, 16, 10));
        let (_, _, l1) = b.next_batch(Instant::now()).unwrap();
        let (_, _, l2) = b.next_batch(Instant::now()).unwrap();
        let (_, _, l3) = b.next_batch(Instant::now()).unwrap();
        assert_eq!((l1.len(), l2.len(), l3.len()), (4, 4, 2));
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn timeout_policy_waits_then_fires() {
        let mut b = DynamicBatcher::new(
            BatchPolicy::Timeout(Duration::from_millis(50)),
            8,
        );
        b.enqueue(req(1, Solver::TauLeaping, 16, 2));
        let now = Instant::now();
        assert!(b.next_batch(now).is_none(), "should hold under-full batch");
        let later = now + Duration::from_millis(60);
        let got = b.next_batch(later);
        assert!(got.is_some(), "deadline passed, must dispatch");
    }

    #[test]
    fn timeout_policy_fires_immediately_when_full() {
        let mut b = DynamicBatcher::new(
            BatchPolicy::Timeout(Duration::from_secs(100)),
            4,
        );
        b.enqueue(req(1, Solver::TauLeaping, 16, 4));
        assert!(b.next_batch(Instant::now()).is_some());
    }

    #[test]
    fn lane_seeds_distinct() {
        let mut b = DynamicBatcher::new(BatchPolicy::Greedy, 8);
        b.enqueue(req(1, Solver::TauLeaping, 16, 5));
        let (_, _, lanes) = b.next_batch(Instant::now()).unwrap();
        let mut seeds: Vec<u64> = lanes.iter().map(|l| l.seed).collect();
        seeds.sort_unstable();
        seeds.dedup();
        assert_eq!(seeds.len(), 5);
    }
}
