//! Supervision primitives for the coordinator loop: capped exponential
//! restart backoff and panic-payload extraction.
//!
//! The coordinator thread runs its scheduler loop under `catch_unwind`; if
//! the loop itself panics (a bug — per-dispatch panics are already
//! contained one level down), the supervisor fails all in-flight jobs with
//! a typed `coordinator_restarted` error, waits out the backoff, and
//! re-enters the loop with fresh batching state.  The backoff is reset
//! after a healthy stretch so an isolated crash costs one restart, while a
//! hot crash loop decays to the cap instead of spinning.

use std::any::Any;
use std::time::Duration;

/// Capped exponential backoff between supervisor restarts.
#[derive(Clone, Debug)]
pub struct Backoff {
    initial: Duration,
    cap: Duration,
    current: Duration,
}

impl Backoff {
    pub fn new(initial: Duration, cap: Duration) -> Backoff {
        assert!(initial > Duration::ZERO && cap >= initial);
        Backoff { initial, cap, current: initial }
    }

    /// The delay to wait before the next restart; doubles (up to the cap)
    /// for each consecutive crash.
    pub fn next_delay(&mut self) -> Duration {
        let d = self.current;
        self.current = (self.current * 2).min(self.cap);
        d
    }

    /// Call after a healthy stretch (e.g. a dispatch completed without the
    /// loop crashing): the next crash starts from the initial delay again.
    pub fn reset(&mut self) {
        self.current = self.initial;
    }

    pub fn current(&self) -> Duration {
        self.current
    }
}

impl Default for Backoff {
    /// 10ms → 1s: fast enough that a single crash is invisible to clients,
    /// capped so a crash loop cannot busy-spin the thread.
    fn default() -> Backoff {
        Backoff::new(Duration::from_millis(10), Duration::from_secs(1))
    }
}

/// Best-effort human-readable message from a `catch_unwind` payload.
pub fn panic_message(payload: &(dyn Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic payload of unknown type".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_doubles_to_cap_and_resets() {
        let mut b = Backoff::new(Duration::from_millis(10), Duration::from_millis(100));
        assert_eq!(b.next_delay(), Duration::from_millis(10));
        assert_eq!(b.next_delay(), Duration::from_millis(20));
        assert_eq!(b.next_delay(), Duration::from_millis(40));
        assert_eq!(b.next_delay(), Duration::from_millis(80));
        // Capped, then stays capped.
        assert_eq!(b.next_delay(), Duration::from_millis(100));
        assert_eq!(b.next_delay(), Duration::from_millis(100));
        b.reset();
        assert_eq!(b.next_delay(), Duration::from_millis(10));
    }

    #[test]
    fn panic_messages_extracted() {
        let p = std::panic::catch_unwind(|| panic!("static message")).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "static message");
        let p = std::panic::catch_unwind(|| panic!("formatted {}", 7)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "formatted 7");
        let p = std::panic::catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert_eq!(panic_message(p.as_ref()), "panic payload of unknown type");
    }
}
