//! The serving coordinator — L3's contribution: request router, dynamic
//! batcher, step scheduler and metrics over the PJRT runtime.
//!
//! Architecture (all std threads + channels; tokio is not vendored):
//!
//! ```text
//!   submit() ──channel──▶ coordinator thread
//!                           │  DynamicBatcher (group lanes by key)
//!                           │  StepPlan + run_batch  ──▶ RuntimeHandle ──▶ PJRT
//!                           │  ResponseAssembler (reunite lanes)
//!                           └──▶ per-request reply channels
//! ```

pub mod request;
pub mod batcher;
pub mod scheduler;
pub mod state;
pub mod metrics;

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::{Duration, Instant};

use anyhow::Result;

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{GenerateRequest, GenerateResponse};

use crate::runtime::{Registry, RuntimeHandle};
use state::ResponseAssembler;

enum Msg {
    Submit(GenerateRequest, Sender<Result<GenerateResponse>>),
    Metrics(Sender<Metrics>),
    Shutdown,
}

/// Handle to the coordinator thread.
#[derive(Clone)]
pub struct Coordinator {
    tx: Sender<Msg>,
}

impl Coordinator {
    pub fn start(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
    ) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || coordinator_loop(runtime, registry, policy, rx))
            .expect("spawning coordinator");
        Coordinator { tx }
    }

    /// Submit a request; returns a receiver for the (single) response.
    pub fn submit(&self, req: GenerateRequest) -> Receiver<Result<GenerateResponse>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Submit(req, reply))
            .expect("coordinator thread is gone");
        rx
    }

    /// Submit and wait.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped reply"))?
    }

    pub fn metrics(&self) -> Metrics {
        let (reply, rx) = channel();
        if self.tx.send(Msg::Metrics(reply)).is_err() {
            return Metrics::new();
        }
        rx.recv().unwrap_or_else(|_| Metrics::new())
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

fn coordinator_loop(
    runtime: RuntimeHandle,
    registry: Registry,
    policy: BatchPolicy,
    rx: Receiver<Msg>,
) {
    // Batch capacity = the max artifact batch across families (lanes are
    // split per-key anyway; run_batch asserts against the plan's batch).
    let max_lanes = registry
        .by_family("markov")
        .iter()
        .filter_map(|a| a.batch().ok())
        .max()
        .unwrap_or(8);
    let mut batcher = DynamicBatcher::new(policy, max_lanes);
    let mut assembler = ResponseAssembler::new();
    let mut replies: BTreeMap<u64, Sender<Result<GenerateResponse>>> = BTreeMap::new();
    let mut metrics = Metrics::new();
    let started = Instant::now();
    let now_ms = |s: Instant| s.elapsed().as_secs_f64() * 1e3;

    let mut open = true;
    while open || batcher.pending() > 0 {
        // Drain inbound messages (block briefly when idle).
        let deadline = match policy {
            BatchPolicy::Greedy => Duration::from_millis(1),
            BatchPolicy::Timeout(d) => d.min(Duration::from_millis(5)),
        };
        loop {
            match rx.recv_timeout(if batcher.pending() > 0 {
                Duration::from_micros(100)
            } else {
                deadline
            }) {
                Ok(Msg::Submit(req, reply)) => {
                    metrics.requests += 1;
                    metrics.lanes += req.n_samples as u64;
                    assembler.register(req.id, req.n_samples, now_ms(started));
                    replies.insert(req.id, reply);
                    batcher.enqueue(req);
                }
                Ok(Msg::Metrics(reply)) => {
                    let _ = reply.send(metrics.clone());
                }
                Ok(Msg::Shutdown) => {
                    open = false;
                    break;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // Dispatch due batches.
        while let Some((_key, proto, lanes)) = batcher.next_batch(Instant::now()) {
            metrics.dispatches += 1;
            metrics
                .occupancy
                .push(lanes.len() as f64 / batcher.max_lanes as f64);
            for lane in &lanes {
                metrics
                    .queue_wait_ms
                    .push(lane.enqueued.elapsed().as_secs_f64() * 1e3);
            }
            let outcome = scheduler::StepPlan::build(&registry, &proto)
                .and_then(|plan| {
                    scheduler::run_batch(&runtime, &plan, proto.solver, &lanes)
                });
            match outcome {
                Ok(result) => {
                    metrics.nfe_total +=
                        (result.nfe_per_lane * lanes.len()) as u64;
                    for (lane, toks) in lanes.iter().zip(result.tokens) {
                        if let Some(resp) = assembler.complete_lane(
                            lane.request_id,
                            lane.sample_idx,
                            toks,
                            result.nfe_per_lane,
                            now_ms(started),
                        ) {
                            metrics.latency_ms.push(resp.latency_ms);
                            if let Some(tx) = replies.remove(&resp.id) {
                                let _ = tx.send(Ok(resp));
                            }
                        }
                    }
                }
                Err(err) => {
                    // Fail every request touched by this batch.
                    let mut failed: Vec<u64> =
                        lanes.iter().map(|l| l.request_id).collect();
                    failed.sort_unstable();
                    failed.dedup();
                    for id in failed {
                        if let Some(tx) = replies.remove(&id) {
                            let _ = tx.send(Err(anyhow::anyhow!(
                                "batch execution failed: {err:#}"
                            )));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solvers::Solver;

    fn coordinator(policy: BatchPolicy) -> Option<Coordinator> {
        if !crate::runtime::artifacts_available("artifacts") {
            return None;
        }
        let runtime = RuntimeHandle::spawn("artifacts").unwrap();
        let registry = Registry::load("artifacts").unwrap();
        Some(Coordinator::start(runtime, registry, policy))
    }

    fn req(id: u64, solver: Solver, nfe: usize, n: usize, seed: u64) -> GenerateRequest {
        GenerateRequest { id, family: "markov".into(), solver, nfe, n_samples: n, seed }
    }

    #[test]
    fn end_to_end_generation() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        let resp = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 3, 7))
            .unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 32);
            assert!(s.iter().all(|&t| t < 16), "masks left: {s:?}");
        }
        assert!(resp.nfe_used >= 32 && resp.nfe_used <= 34);
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 3);
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_batched_and_reproducible() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        // Same seed/solver twice -> identical sequences even when batched
        // with different partners.
        let rx1 = c.submit(req(1, Solver::TauLeaping, 16, 2, 99));
        let rx2 = c.submit(req(2, Solver::TauLeaping, 16, 4, 55));
        let rx3 = c.submit(req(3, Solver::Euler, 16, 1, 1));
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        let r3 = rx3.recv().unwrap().unwrap();
        assert_eq!(r1.sequences.len(), 2);
        assert_eq!(r2.sequences.len(), 4);
        assert_eq!(r3.sequences.len(), 1);

        let r1b = c.generate(req(9, Solver::TauLeaping, 16, 2, 99)).unwrap();
        assert_eq!(r1.sequences, r1b.sequences, "seeded lanes must be batch-invariant");
        c.shutdown();
    }

    #[test]
    fn rejects_absurd_budget() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        let err = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 1, 1, 0))
            .unwrap_err();
        assert!(format!("{err:#}").contains("below one step"), "{err:#}");
        c.shutdown();
    }

    #[test]
    fn timeout_policy_improves_occupancy() {
        let Some(c) = coordinator(BatchPolicy::Timeout(Duration::from_millis(30)))
        else {
            return;
        };
        let rxs: Vec<_> = (0..4)
            .map(|i| c.submit(req(i, Solver::TauLeaping, 16, 2, i)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = c.metrics();
        // 8 lanes with batch size 8: with the hold-for-timeout policy these
        // should need very few dispatches (the exact count depends on
        // arrival timing, so just check it beats one-lane-per-dispatch).
        assert!(m.dispatches <= 4, "dispatches={}", m.dispatches);
        assert!(m.occupancy.mean() > 0.25, "occupancy={}", m.occupancy.mean());
        c.shutdown();
    }
}
