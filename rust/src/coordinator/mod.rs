//! The serving coordinator — request router, dynamic batcher, step
//! scheduler and metrics over the PJRT runtime.
//!
//! Architecture (all std threads + channels; tokio is not vendored):
//!
//! ```text
//!   submit_spec()/submit() ──channel──▶ coordinator thread
//!        │ (JobHandle: id,                │  DynamicBatcher (group lanes
//!        │  event stream,                 │    by BatchKey::of(spec))
//!        │  cancel token)                 │  run_batch_scored ──▶ generate_batch
//!        │                               │    (score artifact over PJRT, or
//!   cancel(id) ──shared registry──▶      │     local oracle; legacy fused
//!     fires the job's CancelToken        │     step graphs as fallback)
//!     (polled inside the solver loops)   │  ResponseAssembler (reunite lanes)
//!                                        └──▶ per-job event channels
//!                                             (Lane chunks → Done/Failed)
//! ```
//!
//! Every submission is a **job**: [`Coordinator::submit_spec`] returns a
//! [`JobHandle`] carrying the id, an event receiver and a cancel token.
//! Blocking `generate` is just `submit + wait`; the streaming server verb
//! subscribes to the per-lane [`JobEvent::Lane`] chunks (emitted as each
//! lane completes a dispatch, so a large request split across batches
//! streams progressively); `cancel(id)` fires the token from any thread —
//! the solver loops poll it per window, so even a long exact-simulation
//! run winds down within one window and completes its job with a
//! partial-result response.
//!
//! Validation happens **before** submission, at spec construction
//! ([`crate::api::SpecBuilder`]): a coordinator never sees an invalid
//! request, and the batch key is derived from the same resolved plan the
//! scheduler executes, so intake re-validation (the pre-redesign
//! workaround for under-encoding keys) is gone.
//!
//! Batching pays off *below* the request layer: every batch the
//! `DynamicBatcher` emits is executed by `solvers::masked::generate_batch`,
//! which makes one masked-sparse score call per solver stage for all lanes
//! together.  With artifacts present that call is a single PJRT dispatch of
//! the `{family}_score` graph; with a local oracle it fans across the
//! threadpool.  The legacy per-step fused graphs remain as a fallback for
//! families that ship step artifacts but no score artifact.

pub mod request;
pub mod batcher;
pub mod scheduler;
pub mod state;
pub mod metrics;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

pub use batcher::{BatchKey, BatchPolicy, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{GenerateRequest, GenerateResponse};

pub use crate::api::{CancelToken, SamplingSpec};

use crate::runtime::{ArtifactScore, Registry, RuntimeHandle};
use crate::schedule::{ScheduleCache, ScheduleSpec};
use crate::score::{ScoreSource, Tok};
use state::ResponseAssembler;

/// One progress/completion event of a job.
#[derive(Debug)]
pub enum JobEvent {
    /// A lane finished a dispatch (streamed jobs only): its sample index,
    /// its tokens, the NFE it spent, and whether it was interrupted.
    Lane { sample_idx: usize, tokens: Vec<Tok>, nfe: usize, partial: bool },
    /// All lanes done — the assembled response (also carries `partial`).
    Done(GenerateResponse),
    /// The batch executing this job failed.
    Failed(String),
}

/// Handle to a submitted job: the serving id (the `cancel` verb's key), a
/// receiver of [`JobEvent`]s, and the job's cancel token.
pub struct JobHandle {
    pub id: u64,
    events: Receiver<JobEvent>,
    cancel: CancelToken,
}

impl JobHandle {
    /// Fire the job's cancel token (cooperative: the run winds down at the
    /// next solver window and completes with a partial response).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Next event (blocking).
    pub fn recv(&self) -> Result<JobEvent> {
        self.events
            .recv()
            .map_err(|_| anyhow!("coordinator dropped the job channel"))
    }

    /// Drain events until completion and return the response.
    pub fn wait(self) -> Result<GenerateResponse> {
        loop {
            match self.recv()? {
                JobEvent::Lane { .. } => continue,
                JobEvent::Done(resp) => return Ok(resp),
                JobEvent::Failed(err) => bail!("{err}"),
            }
        }
    }
}

struct Job {
    id: u64,
    spec: SamplingSpec,
    events: Sender<JobEvent>,
    stream: bool,
    cancel: CancelToken,
}

enum Msg {
    Submit(Job),
    Metrics(Sender<Metrics>),
    Shutdown,
}

/// State shared between coordinator handles and the loop thread: the id
/// allocator and the cancel-token registry (`cancel` must work while the
/// loop thread is busy executing a batch, so it bypasses the channel).
struct Shared {
    next_id: AtomicU64,
    cancels: Mutex<BTreeMap<u64, CancelToken>>,
}

fn lock_cancels(shared: &Shared) -> std::sync::MutexGuard<'_, BTreeMap<u64, CancelToken>> {
    shared.cancels.lock().unwrap_or_else(|e| e.into_inner())
}

/// Where batches execute.
enum Backend {
    /// PJRT runtime: prefer the `{family}_score` artifact through
    /// `generate_batch`; fall back to the legacy fused step graphs.
    Pjrt {
        runtime: RuntimeHandle,
        registry: Registry,
        /// Lazily built, cached per family.
        scores: BTreeMap<String, Arc<ArtifactScore>>,
        /// Tuned grids, memoised per (family, vocab, seq_len, solver, steps).
        schedules: ScheduleCache,
    },
    /// A local in-process score source (analytic oracle): no artifacts
    /// needed, everything runs through `generate_batch`.
    Local {
        score: Arc<dyn ScoreSource>,
        schedules: ScheduleCache,
    },
}

/// Handle to the coordinator thread.
#[derive(Clone)]
pub struct Coordinator {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
}

impl Coordinator {
    pub fn start(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
    ) -> Coordinator {
        Coordinator::start_with_schedule_dir(runtime, registry, policy, None)
    }

    /// As [`Coordinator::start`], with tuned schedules persisted under
    /// `schedule_dir`: fits flush to disk on insert and reload on start, so
    /// a restart never re-pays the pilot runs ([`ScheduleCache`]).
    pub fn start_with_schedule_dir(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
        schedule_dir: Option<&str>,
    ) -> Coordinator {
        // Batch capacity = the max artifact batch across families.
        let max_lanes = registry
            .by_family("markov")
            .iter()
            .filter_map(|a| a.batch().ok())
            .max()
            .unwrap_or(8);
        let backend = Backend::Pjrt {
            runtime,
            registry,
            scores: BTreeMap::new(),
            schedules: ScheduleCache::with_dir(schedule_dir),
        };
        Coordinator::spawn(backend, policy, max_lanes)
    }

    /// Serve straight from an in-process score source (no artifacts, no
    /// PJRT): the dynamic batcher still groups lanes and every batch runs
    /// through `generate_batch`.
    pub fn start_local(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
    ) -> Coordinator {
        Coordinator::start_local_with_schedule_dir(score, policy, max_lanes, None)
    }

    /// As [`Coordinator::start_local`], with tuned schedules persisted
    /// under `schedule_dir` across restarts.
    pub fn start_local_with_schedule_dir(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
        schedule_dir: Option<&str>,
    ) -> Coordinator {
        Coordinator::spawn(
            Backend::Local { score, schedules: ScheduleCache::with_dir(schedule_dir) },
            policy,
            max_lanes.max(1),
        )
    }

    fn spawn(backend: Backend, policy: BatchPolicy, max_lanes: usize) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let shared = Arc::new(Shared {
            next_id: AtomicU64::new(1),
            cancels: Mutex::new(BTreeMap::new()),
        });
        let loop_shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || coordinator_loop(backend, policy, max_lanes, rx, loop_shared))
            .expect("spawning coordinator");
        Coordinator { tx, shared }
    }

    fn submit_internal(&self, id: u64, spec: SamplingSpec, stream: bool) -> JobHandle {
        let cancel = CancelToken::new();
        lock_cancels(&self.shared).insert(id, cancel.clone());
        let (events_tx, events_rx) = channel();
        self.tx
            .send(Msg::Submit(Job {
                id,
                spec,
                events: events_tx,
                stream,
                cancel: cancel.clone(),
            }))
            .expect("coordinator thread is gone");
        JobHandle { id, events: events_rx, cancel }
    }

    /// Submit a spec as a blocking-style job (no per-lane events) with a
    /// coordinator-assigned id.
    pub fn submit_spec(&self, spec: SamplingSpec) -> JobHandle {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_internal(id, spec, false)
    }

    /// Submit a spec as a streaming job: the handle receives a
    /// [`JobEvent::Lane`] chunk for every completed lane, then `Done`.
    pub fn submit_stream(&self, spec: SamplingSpec) -> JobHandle {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_internal(id, spec, true)
    }

    /// Submit with a caller-chosen id (embedding users and tests; ids also
    /// key the cancel registry, so keep them unique).
    pub fn submit(&self, req: GenerateRequest) -> JobHandle {
        self.submit_internal(req.id, req.spec, false)
    }

    /// Submit and wait.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        self.submit(req).wait()
    }

    /// Submit a spec and wait.
    pub fn generate_spec(&self, spec: SamplingSpec) -> Result<GenerateResponse> {
        self.submit_spec(spec).wait()
    }

    /// Fire the cancel token of an in-flight job.  Returns whether the id
    /// was found (false = unknown id or already completed).  Cooperative:
    /// the job still completes through its event channel, with `partial`
    /// set on whatever the solver had produced.
    pub fn cancel(&self, id: u64) -> bool {
        match lock_cancels(&self.shared).get(&id) {
            Some(token) => {
                token.cancel();
                true
            }
            None => false,
        }
    }

    pub fn metrics(&self) -> Metrics {
        let (reply, rx) = channel();
        if self.tx.send(Msg::Metrics(reply)).is_err() {
            return Metrics::new();
        }
        rx.recv().unwrap_or_else(|_| Metrics::new())
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Execute one packed batch on the backend.
fn execute_batch(
    backend: &mut Backend,
    proto: &SamplingSpec,
    lanes: &[batcher::Lane],
) -> Result<scheduler::BatchResult> {
    match backend {
        Backend::Local { score, schedules } => {
            scheduler::run_batch_scored(score.as_ref(), proto, lanes, schedules)
        }
        Backend::Pjrt { runtime, registry, scores, schedules } => {
            let score_name = format!("{}_score", proto.family());
            if registry.get(&score_name).is_ok() {
                let score = match scores.get(proto.family()) {
                    Some(s) => Arc::clone(s),
                    None => {
                        let s = Arc::new(ArtifactScore::new(
                            runtime.clone(),
                            registry,
                            proto.family(),
                        )?);
                        scores.insert(proto.family().to_string(), Arc::clone(&s));
                        s
                    }
                };
                let result =
                    scheduler::run_batch_scored(score.as_ref(), proto, lanes, schedules)?;
                // Score dispatch failures poison the source instead of
                // surfacing through the trait; convert them to a batch error.
                if let Some(err) = score.take_error() {
                    return Err(anyhow!("score artifact dispatch failed: {err}"));
                }
                Ok(result)
            } else {
                // Legacy path: fused per-step graphs over the uniform grid
                // only (non-uniform schedules need the score-artifact or
                // local backend).
                if proto.schedule() != ScheduleSpec::Uniform || proto.nfe_budget().is_some() {
                    return Err(anyhow!(
                        "schedule {:?} requires a score artifact or local backend \
                         (family {:?} ships only fused step graphs)",
                        proto.schedule().to_string_spec(),
                        proto.family()
                    ));
                }
                let plan = scheduler::StepPlan::build(registry, proto)?;
                scheduler::run_batch(runtime, &plan, proto.solver(), lanes)
            }
        }
    }
}

/// Per-job sink state the loop thread keeps.
struct Sink {
    events: Sender<JobEvent>,
    stream: bool,
}

fn finish_job(
    jobs: &mut BTreeMap<u64, Sink>,
    shared: &Shared,
    id: u64,
    event: JobEvent,
) {
    lock_cancels(shared).remove(&id);
    if let Some(sink) = jobs.remove(&id) {
        let _ = sink.events.send(event);
    }
}

fn coordinator_loop(
    mut backend: Backend,
    policy: BatchPolicy,
    max_lanes: usize,
    rx: Receiver<Msg>,
    shared: Arc<Shared>,
) {
    let mut batcher = DynamicBatcher::new(policy, max_lanes);
    let mut assembler = ResponseAssembler::new();
    let mut jobs: BTreeMap<u64, Sink> = BTreeMap::new();
    let mut metrics = Metrics::new();
    let started = Instant::now();
    let now_ms = |s: Instant| s.elapsed().as_secs_f64() * 1e3;

    let mut open = true;
    while open || batcher.pending() > 0 {
        // Drain inbound messages (block briefly when idle).
        let deadline = match policy {
            BatchPolicy::Greedy => Duration::from_millis(1),
            BatchPolicy::Timeout(d) => d.min(Duration::from_millis(5)),
        };
        loop {
            match rx.recv_timeout(if batcher.pending() > 0 {
                Duration::from_micros(100)
            } else {
                deadline
            }) {
                Ok(Msg::Submit(job)) => {
                    // The spec is valid by construction (builder-only), so
                    // intake is pure bookkeeping.
                    metrics.requests += 1;
                    metrics.lanes += job.spec.n_samples() as u64;
                    assembler.register(job.id, job.spec.n_samples(), now_ms(started));
                    jobs.insert(job.id, Sink { events: job.events, stream: job.stream });
                    batcher.enqueue(GenerateRequest::new(job.id, job.spec), job.cancel);
                }
                Ok(Msg::Metrics(reply)) => {
                    let _ = reply.send(metrics.clone());
                }
                Ok(Msg::Shutdown) => {
                    open = false;
                    break;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // Dispatch due batches.
        while let Some((_key, proto, lanes)) = batcher.next_batch(Instant::now()) {
            metrics.dispatches += 1;
            metrics
                .occupancy
                .push(lanes.len() as f64 / batcher.max_lanes as f64);
            for lane in &lanes {
                metrics
                    .queue_wait_ms
                    .push(lane.enqueued.elapsed().as_secs_f64() * 1e3);
            }
            // Jobs cancelled while still queued are NOT special-cased:
            // the solver loops poll the token before the first window, so
            // a pre-cancelled lane costs only its (all-masked) init and
            // comes back with the correct sequence shape — still-masked
            // positions carrying the mask id, exactly the partial-result
            // contract.  Fabricating empty sequences here would break it.
            let outcome = execute_batch(&mut backend, &proto, &lanes);
            match outcome {
                Ok(result) => {
                    metrics.nfe_total += result.nfe.iter().sum::<usize>() as u64;
                    let scheduler::BatchResult { tokens, nfe, partial } = result;
                    for (idx, (lane, toks)) in
                        lanes.iter().zip(tokens.into_iter()).enumerate()
                    {
                        let lane_nfe = nfe[idx];
                        let lane_partial = partial[idx];
                        if let Some(sink) = jobs.get(&lane.request_id) {
                            if sink.stream {
                                let _ = sink.events.send(JobEvent::Lane {
                                    sample_idx: lane.sample_idx,
                                    tokens: toks.clone(),
                                    nfe: lane_nfe,
                                    partial: lane_partial,
                                });
                            }
                        }
                        if let Some(resp) = assembler.complete_lane(
                            lane.request_id,
                            lane.sample_idx,
                            toks,
                            lane_nfe,
                            lane_partial,
                            now_ms(started),
                        ) {
                            metrics.latency_ms.push(resp.latency_ms);
                            finish_job(&mut jobs, &shared, resp.id, JobEvent::Done(resp));
                        }
                    }
                }
                Err(err) => {
                    // Fail every request touched by this batch — and clean
                    // it up fully: discard its assembler state (a leaked
                    // Pending entry would grow the long-lived coordinator
                    // on every failing request) and purge its still-queued
                    // lanes (they would execute into a request that no
                    // longer exists).
                    let mut failed: Vec<u64> =
                        lanes.iter().map(|l| l.request_id).collect();
                    failed.sort_unstable();
                    failed.dedup();
                    for id in failed {
                        assembler.abort(id);
                        batcher.purge_request(id);
                        finish_job(
                            &mut jobs,
                            &shared,
                            id,
                            JobEvent::Failed(format!("batch execution failed: {err:#}")),
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::hmm::HmmUniformOracle;
    use crate::score::markov::{MarkovChain, MarkovOracle};
    use crate::solvers::{grid, masked, Solver};
    use crate::util::rng::Xoshiro256;

    fn coordinator(policy: BatchPolicy) -> Option<Coordinator> {
        if !crate::runtime::artifacts_available("artifacts") {
            return None;
        }
        let runtime = RuntimeHandle::spawn("artifacts").unwrap();
        let registry = Registry::load("artifacts").unwrap();
        Some(Coordinator::start(runtime, registry, policy))
    }

    fn local_oracle(vocab: usize, seq_len: usize) -> Arc<MarkovOracle> {
        let mut rng = Xoshiro256::seed_from_u64(23);
        Arc::new(MarkovOracle::new(
            MarkovChain::generate(&mut rng, vocab, 0.5),
            seq_len,
        ))
    }

    fn req(id: u64, solver: Solver, nfe: usize, n: usize, seed: u64) -> GenerateRequest {
        GenerateRequest::new(
            id,
            SamplingSpec::builder()
                .solver(solver)
                .nfe(nfe)
                .n_samples(n)
                .seed(seed)
                .build()
                .unwrap(),
        )
    }

    #[test]
    fn local_backend_serves_adaptive_and_tuned_schedules() {
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let solver = Solver::Trapezoidal { theta: 0.5 };

        // Adaptive with a hard budget: all lanes finish, nobody overdraws.
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(64)
            .n_samples(3)
            .seed(7)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .nfe_budget(Some(24))
            .build()
            .unwrap();
        let resp = c.generate_spec(spec).unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        assert!(resp.nfe_used <= 24, "budget exceeded: {}", resp.nfe_used);
        assert!(!resp.partial);

        // Tuned: fit-on-first-use, then cache hit; deterministic replay.
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .n_samples(2)
            .seed(9)
            .schedule(ScheduleSpec::Tuned { steps: 8 })
            .build()
            .unwrap();
        let a = c.generate_spec(spec.clone()).unwrap();
        let b = c.generate_spec(spec).unwrap();
        assert_eq!(a.sequences, b.sequences, "tuned grid must be cached + reused");

        // Log schedule still serves.
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .seed(1)
            .schedule(ScheduleSpec::Log)
            .build()
            .unwrap();
        let resp = c.generate_spec(spec).unwrap();
        assert!(resp.sequences[0].iter().all(|&t| t < 6));
        c.shutdown();
    }

    #[test]
    fn local_backend_serves_exact_solver() {
        // Solver::Exact dispatches through batcher -> scheduler like any
        // approximate scheme; nfe_used echoes the realized jump count.
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let resp = c.generate(req(1, Solver::Exact, 16, 3, 11)).unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 20);
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        // Realized NFE: <= one eval per dim + one finalize, independent of
        // the requested planning budget.
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 21, "nfe={}", resp.nfe_used);

        // Same seed -> identical samples (per-lane seeded fhs streams).
        let again = c.generate(req(2, Solver::Exact, 16, 3, 11)).unwrap();
        assert_eq!(again.sequences, resp.sequences);
        c.shutdown();
    }

    #[test]
    fn local_backend_persists_tuned_schedules_across_restart() {
        let dir = std::env::temp_dir().join(format!(
            "fastdds_coord_sched_{}",
            std::process::id()
        ));
        let dir = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);
        let solver = Solver::Trapezoidal { theta: 0.5 };

        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .n_samples(2)
            .seed(9)
            .schedule(ScheduleSpec::Tuned { steps: 8 })
            .build()
            .unwrap();
        let first = {
            let oracle = local_oracle(6, 20);
            let c = Coordinator::start_local_with_schedule_dir(
                oracle,
                BatchPolicy::Greedy,
                8,
                Some(&dir),
            );
            let resp = c.generate_spec(spec.clone()).unwrap();
            c.shutdown();
            resp.sequences
        };
        // The fit must have been flushed to disk.
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().flatten().collect();
        assert!(!files.is_empty(), "tuned schedule not flushed to {dir:?}");

        // Restarted coordinator (same oracle construction): the reloaded
        // grid reproduces the samples exactly.
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local_with_schedule_dir(
            oracle,
            BatchPolicy::Greedy,
            8,
            Some(&dir),
        );
        let resp = c.generate_spec(spec).unwrap();
        assert_eq!(resp.sequences, first, "reloaded tuned grid must replay");
        c.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn end_to_end_generation() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        let resp = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 3, 7))
            .unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 32);
            assert!(s.iter().all(|&t| t < 16), "masks left: {s:?}");
        }
        // Sparse skipping lets a lane finish under budget; finalize adds at
        // most one evaluation on top.
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 34, "nfe={}", resp.nfe_used);
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 3);
        c.shutdown();
    }

    #[test]
    fn local_backend_serves_without_artifacts() {
        let oracle = local_oracle(6, 24);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let resp = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 3, 7))
            .unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 24);
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 33);
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 3);
        assert_eq!(m.dispatches, 1, "3 lanes must co-batch in one dispatch");
        c.shutdown();
    }

    #[test]
    fn local_backend_batches_are_lane_reproducible() {
        // The whole stack — batcher lane seeding, run_batch_scored,
        // generate_batch — must produce exactly what a single-lane
        // masked::generate with the derived lane seed produces.
        let oracle = local_oracle(5, 16);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let solver = Solver::TauLeaping;
        let (nfe, n, seed) = (16usize, 4usize, 99u64);
        let resp = c.generate(req(1, solver, nfe, n, seed)).unwrap();
        assert_eq!(resp.sequences.len(), n);
        let grid_ts = grid::masked_uniform(solver.steps_for_nfe(nfe), scheduler::DELTA);
        for (idx, seq) in resp.sequences.iter().enumerate() {
            let lane_seed =
                seed.wrapping_add((idx as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut rng = Xoshiro256::seed_from_u64(lane_seed);
            let (want, _) = masked::generate(oracle.as_ref(), solver, &grid_ts, &mut rng);
            assert_eq!(seq, &want, "lane {idx}");
        }
        // Same request again: identical samples even with different
        // co-batching partners in flight.
        let again = c.generate(req(2, solver, nfe, n, seed)).unwrap();
        assert_eq!(again.sequences, resp.sequences);
        c.shutdown();
    }

    #[test]
    fn streaming_job_chunks_concatenate_to_blocking_response() {
        // n_samples > max_lanes forces multiple dispatches: the streamed
        // per-lane chunks, placed by sample index, must equal the blocking
        // response for the same spec + seed bit for bit.
        let oracle = local_oracle(5, 12);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 2);
        let spec = SamplingSpec::builder()
            .solver(Solver::TauLeaping)
            .nfe(16)
            .n_samples(5)
            .seed(42)
            .build()
            .unwrap();
        let blocking = c.generate_spec(spec.clone()).unwrap();

        let job = c.submit_stream(spec);
        let mut chunks: Vec<Option<Vec<Tok>>> = vec![None; 5];
        let mut n_chunks = 0usize;
        let done = loop {
            match job.recv().unwrap() {
                JobEvent::Lane { sample_idx, tokens, partial, .. } => {
                    assert!(!partial);
                    assert!(chunks[sample_idx].replace(tokens).is_none(), "dup lane");
                    n_chunks += 1;
                }
                JobEvent::Done(resp) => break resp,
                JobEvent::Failed(e) => panic!("{e}"),
            }
        };
        assert_eq!(n_chunks, 5, "every lane must stream exactly once");
        let assembled: Vec<Vec<Tok>> = chunks.into_iter().map(Option::unwrap).collect();
        assert_eq!(assembled, blocking.sequences, "chunks must concatenate bitwise");
        assert_eq!(done.sequences, blocking.sequences);
        assert_eq!(done.nfe_used, blocking.nfe_used);
        c.shutdown();
    }

    #[test]
    fn cancel_interrupts_long_exact_job_with_partial_result() {
        // A large HMM exact job is the unbounded workload cancellation is
        // for: fire the token mid-run and require a prompt partial Done.
        let mut rng = Xoshiro256::seed_from_u64(29);
        let chain = MarkovChain::generate(&mut rng, 6, 0.6);
        let oracle = Arc::new(HmmUniformOracle::new(chain, 48));
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 4);
        let spec = SamplingSpec::builder()
            .solver(Solver::Exact)
            .n_samples(2)
            .seed(3)
            .build()
            .unwrap();
        let job = c.submit_stream(spec);
        let id = job.id;
        // Cancel from "another thread" (the handle's token IS the registry
        // entry, but go through the coordinator API like the server does).
        assert!(c.cancel(id), "in-flight job must be found");
        let resp = job.wait().unwrap();
        assert!(resp.partial, "cancelled run must be partial");
        assert_eq!(resp.sequences.len(), 2);
        // Completed job: the registry entry is gone.
        assert!(!c.cancel(id), "completed job must be unknown to cancel");
        c.shutdown();
    }

    #[test]
    fn max_events_caps_exact_runs() {
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let spec = SamplingSpec::builder()
            .solver(Solver::Exact)
            .n_samples(2)
            .seed(5)
            .max_events(Some(4))
            .build()
            .unwrap();
        let resp = c.generate_spec(spec).unwrap();
        assert!(resp.partial, "20 dims cannot finish in 4 events");
        for s in &resp.sequences {
            let masked = s.iter().filter(|&&t| t == oracle.mask_id()).count();
            assert!(masked >= 16, "at most 4 positions may reveal, {masked} masks");
        }
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_batched_and_reproducible() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        // Same seed/solver twice -> identical sequences even when batched
        // with different partners.
        let h1 = c.submit(req(1, Solver::TauLeaping, 16, 2, 99));
        let h2 = c.submit(req(2, Solver::TauLeaping, 16, 4, 55));
        let h3 = c.submit(req(3, Solver::Euler, 16, 1, 1));
        let r1 = h1.wait().unwrap();
        let r2 = h2.wait().unwrap();
        let r3 = h3.wait().unwrap();
        assert_eq!(r1.sequences.len(), 2);
        assert_eq!(r2.sequences.len(), 4);
        assert_eq!(r3.sequences.len(), 1);

        let r1b = c.generate(req(9, Solver::TauLeaping, 16, 2, 99)).unwrap();
        assert_eq!(r1.sequences, r1b.sequences, "seeded lanes must be batch-invariant");
        c.shutdown();
    }

    #[test]
    fn timeout_policy_improves_occupancy() {
        let Some(c) = coordinator(BatchPolicy::Timeout(Duration::from_millis(30)))
        else {
            return;
        };
        let handles: Vec<_> = (0..4)
            .map(|i| c.submit(req(i, Solver::TauLeaping, 16, 2, i)))
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let m = c.metrics();
        // 8 lanes with batch size 8: with the hold-for-timeout policy these
        // should need very few dispatches (the exact count depends on
        // arrival timing, so just check it beats one-lane-per-dispatch).
        assert!(m.dispatches <= 4, "dispatches={}", m.dispatches);
        assert!(m.occupancy.mean() > 0.25, "occupancy={}", m.occupancy.mean());
        c.shutdown();
    }
}
