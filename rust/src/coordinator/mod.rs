//! The serving coordinator — L3's contribution: request router, dynamic
//! batcher, step scheduler and metrics over the PJRT runtime.
//!
//! Architecture (all std threads + channels; tokio is not vendored):
//!
//! ```text
//!   submit() ──channel──▶ coordinator thread
//!                           │  DynamicBatcher (group lanes by key)
//!                           │  run_batch_scored ──▶ generate_batch ──▶ ScoreSource
//!                           │    (score artifact over PJRT, or local oracle;
//!                           │     legacy fused step graphs as fallback)
//!                           │  ResponseAssembler (reunite lanes)
//!                           └──▶ per-request reply channels
//! ```
//!
//! Batching pays off *below* the request layer: every batch the
//! `DynamicBatcher` emits is executed by `solvers::masked::generate_batch`,
//! which makes one masked-sparse score call per solver stage for all lanes
//! together.  With artifacts present that call is a single PJRT dispatch of
//! the `{family}_score` graph; with a local oracle it fans across the
//! threadpool.  The legacy per-step fused graphs remain as a fallback for
//! families that ship step artifacts but no score artifact.

pub mod request;
pub mod batcher;
pub mod scheduler;
pub mod state;
pub mod metrics;

use std::collections::BTreeMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

pub use batcher::{BatchPolicy, DynamicBatcher};
pub use metrics::Metrics;
pub use request::{GenerateRequest, GenerateResponse};

use crate::runtime::{ArtifactScore, Registry, RuntimeHandle};
use crate::schedule::{ScheduleCache, ScheduleSpec};
use crate::score::ScoreSource;
use state::ResponseAssembler;

enum Msg {
    Submit(GenerateRequest, Sender<Result<GenerateResponse>>),
    Metrics(Sender<Metrics>),
    Shutdown,
}

/// Where batches execute.
enum Backend {
    /// PJRT runtime: prefer the `{family}_score` artifact through
    /// `generate_batch`; fall back to the legacy fused step graphs.
    Pjrt {
        runtime: RuntimeHandle,
        registry: Registry,
        /// Lazily built, cached per family.
        scores: BTreeMap<String, Arc<ArtifactScore>>,
        /// Tuned grids, memoised per (family, vocab, seq_len, solver, steps).
        schedules: ScheduleCache,
    },
    /// A local in-process score source (analytic oracle): no artifacts
    /// needed, everything runs through `generate_batch`.
    Local {
        score: Arc<dyn ScoreSource>,
        schedules: ScheduleCache,
    },
}

/// Handle to the coordinator thread.
#[derive(Clone)]
pub struct Coordinator {
    tx: Sender<Msg>,
}

impl Coordinator {
    pub fn start(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
    ) -> Coordinator {
        Coordinator::start_with_schedule_dir(runtime, registry, policy, None)
    }

    /// As [`Coordinator::start`], with tuned schedules persisted under
    /// `schedule_dir`: fits flush to disk on insert and reload on start, so
    /// a restart never re-pays the pilot runs ([`ScheduleCache`]).
    pub fn start_with_schedule_dir(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
        schedule_dir: Option<&str>,
    ) -> Coordinator {
        // Batch capacity = the max artifact batch across families.
        let max_lanes = registry
            .by_family("markov")
            .iter()
            .filter_map(|a| a.batch().ok())
            .max()
            .unwrap_or(8);
        let backend = Backend::Pjrt {
            runtime,
            registry,
            scores: BTreeMap::new(),
            schedules: ScheduleCache::with_dir(schedule_dir),
        };
        Coordinator::spawn(backend, policy, max_lanes)
    }

    /// Serve straight from an in-process score source (no artifacts, no
    /// PJRT): the dynamic batcher still groups lanes and every batch runs
    /// through `generate_batch`.
    pub fn start_local(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
    ) -> Coordinator {
        Coordinator::start_local_with_schedule_dir(score, policy, max_lanes, None)
    }

    /// As [`Coordinator::start_local`], with tuned schedules persisted
    /// under `schedule_dir` across restarts.
    pub fn start_local_with_schedule_dir(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
        schedule_dir: Option<&str>,
    ) -> Coordinator {
        Coordinator::spawn(
            Backend::Local { score, schedules: ScheduleCache::with_dir(schedule_dir) },
            policy,
            max_lanes.max(1),
        )
    }

    fn spawn(backend: Backend, policy: BatchPolicy, max_lanes: usize) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || coordinator_loop(backend, policy, max_lanes, rx))
            .expect("spawning coordinator");
        Coordinator { tx }
    }

    /// Submit a request; returns a receiver for the (single) response.
    pub fn submit(&self, req: GenerateRequest) -> Receiver<Result<GenerateResponse>> {
        let (reply, rx) = channel();
        self.tx
            .send(Msg::Submit(req, reply))
            .expect("coordinator thread is gone");
        rx
    }

    /// Submit and wait.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        self.submit(req)
            .recv()
            .map_err(|_| anyhow::anyhow!("coordinator dropped reply"))?
    }

    pub fn metrics(&self) -> Metrics {
        let (reply, rx) = channel();
        if self.tx.send(Msg::Metrics(reply)).is_err() {
            return Metrics::new();
        }
        rx.recv().unwrap_or_else(|_| Metrics::new())
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }
}

/// Execute one packed batch on the backend.
fn execute_batch(
    backend: &mut Backend,
    proto: &GenerateRequest,
    lanes: &[batcher::Lane],
) -> Result<scheduler::BatchResult> {
    match backend {
        Backend::Local { score, schedules } => {
            scheduler::run_batch_scored(score.as_ref(), proto, lanes, schedules)
        }
        Backend::Pjrt { runtime, registry, scores, schedules } => {
            let score_name = format!("{}_score", proto.family);
            if registry.get(&score_name).is_ok() {
                let score = match scores.get(&proto.family) {
                    Some(s) => Arc::clone(s),
                    None => {
                        let s = Arc::new(ArtifactScore::new(
                            runtime.clone(),
                            registry,
                            &proto.family,
                        )?);
                        scores.insert(proto.family.clone(), Arc::clone(&s));
                        s
                    }
                };
                let result =
                    scheduler::run_batch_scored(score.as_ref(), proto, lanes, schedules)?;
                // Score dispatch failures poison the source instead of
                // surfacing through the trait; convert them to a batch error.
                if let Some(err) = score.take_error() {
                    return Err(anyhow!("score artifact dispatch failed: {err}"));
                }
                Ok(result)
            } else {
                // Legacy path: fused per-step graphs over the uniform grid
                // only (non-uniform schedules need the score-artifact or
                // local backend).
                if proto.schedule != ScheduleSpec::Uniform || proto.nfe_budget.is_some() {
                    return Err(anyhow!(
                        "schedule {:?} requires a score artifact or local backend \
                         (family {:?} ships only fused step graphs)",
                        proto.schedule.to_string_spec(),
                        proto.family
                    ));
                }
                let plan = scheduler::StepPlan::build(registry, proto)?;
                scheduler::run_batch(runtime, &plan, proto.solver, lanes)
            }
        }
    }
}

fn coordinator_loop(
    mut backend: Backend,
    policy: BatchPolicy,
    max_lanes: usize,
    rx: Receiver<Msg>,
) {
    let mut batcher = DynamicBatcher::new(policy, max_lanes);
    let mut assembler = ResponseAssembler::new();
    let mut replies: BTreeMap<u64, Sender<Result<GenerateResponse>>> = BTreeMap::new();
    let mut metrics = Metrics::new();
    let started = Instant::now();
    let now_ms = |s: Instant| s.elapsed().as_secs_f64() * 1e3;

    let mut open = true;
    while open || batcher.pending() > 0 {
        // Drain inbound messages (block briefly when idle).
        let deadline = match policy {
            BatchPolicy::Greedy => Duration::from_millis(1),
            BatchPolicy::Timeout(d) => d.min(Duration::from_millis(5)),
        };
        loop {
            match rx.recv_timeout(if batcher.pending() > 0 {
                Duration::from_micros(100)
            } else {
                deadline
            }) {
                Ok(Msg::Submit(req, reply)) => {
                    // Validate at intake, before batching: a batch must
                    // never mix valid and invalid requests — the batch key
                    // does not encode every validated field, so per-batch
                    // validation of the proto request could reject a valid
                    // co-batched neighbour or let an invalid request ride
                    // a valid proto.
                    if let Err(err) = scheduler::validate_request(&req) {
                        let _ = reply.send(Err(err));
                        continue;
                    }
                    metrics.requests += 1;
                    metrics.lanes += req.n_samples as u64;
                    assembler.register(req.id, req.n_samples, now_ms(started));
                    replies.insert(req.id, reply);
                    batcher.enqueue(req);
                }
                Ok(Msg::Metrics(reply)) => {
                    let _ = reply.send(metrics.clone());
                }
                Ok(Msg::Shutdown) => {
                    open = false;
                    break;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    open = false;
                    break;
                }
            }
        }

        // Dispatch due batches.
        while let Some((_key, proto, lanes)) = batcher.next_batch(Instant::now()) {
            metrics.dispatches += 1;
            metrics
                .occupancy
                .push(lanes.len() as f64 / batcher.max_lanes as f64);
            for lane in &lanes {
                metrics
                    .queue_wait_ms
                    .push(lane.enqueued.elapsed().as_secs_f64() * 1e3);
            }
            let outcome = execute_batch(&mut backend, &proto, &lanes);
            match outcome {
                Ok(result) => {
                    metrics.nfe_total += result.nfe.iter().sum::<usize>() as u64;
                    for ((lane, toks), &nfe) in
                        lanes.iter().zip(result.tokens).zip(&result.nfe)
                    {
                        if let Some(resp) = assembler.complete_lane(
                            lane.request_id,
                            lane.sample_idx,
                            toks,
                            nfe,
                            now_ms(started),
                        ) {
                            metrics.latency_ms.push(resp.latency_ms);
                            if let Some(tx) = replies.remove(&resp.id) {
                                let _ = tx.send(Ok(resp));
                            }
                        }
                    }
                }
                Err(err) => {
                    // Fail every request touched by this batch.
                    let mut failed: Vec<u64> =
                        lanes.iter().map(|l| l.request_id).collect();
                    failed.sort_unstable();
                    failed.dedup();
                    for id in failed {
                        if let Some(tx) = replies.remove(&id) {
                            let _ = tx.send(Err(anyhow::anyhow!(
                                "batch execution failed: {err:#}"
                            )));
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::markov::{MarkovChain, MarkovOracle};
    use crate::solvers::{grid, masked, Solver};
    use crate::util::rng::Xoshiro256;

    fn coordinator(policy: BatchPolicy) -> Option<Coordinator> {
        if !crate::runtime::artifacts_available("artifacts") {
            return None;
        }
        let runtime = RuntimeHandle::spawn("artifacts").unwrap();
        let registry = Registry::load("artifacts").unwrap();
        Some(Coordinator::start(runtime, registry, policy))
    }

    fn local_oracle(vocab: usize, seq_len: usize) -> Arc<MarkovOracle> {
        let mut rng = Xoshiro256::seed_from_u64(23);
        Arc::new(MarkovOracle::new(
            MarkovChain::generate(&mut rng, vocab, 0.5),
            seq_len,
        ))
    }

    fn req(id: u64, solver: Solver, nfe: usize, n: usize, seed: u64) -> GenerateRequest {
        GenerateRequest {
            id,
            family: "markov".into(),
            solver,
            nfe,
            n_samples: n,
            seed,
            ..Default::default()
        }
    }

    #[test]
    fn local_backend_serves_adaptive_and_tuned_schedules() {
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let solver = Solver::Trapezoidal { theta: 0.5 };

        // Adaptive with a hard budget: all lanes finish, nobody overdraws.
        let mut r = req(1, solver, 64, 3, 7);
        r.schedule = ScheduleSpec::Adaptive { tol: 1e-3 };
        r.nfe_budget = Some(24);
        let resp = c.generate(r).unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        assert!(resp.nfe_used <= 24, "budget exceeded: {}", resp.nfe_used);

        // Tuned: fit-on-first-use, then cache hit; deterministic replay.
        let mut r = req(2, solver, 16, 2, 9);
        r.schedule = ScheduleSpec::Tuned { steps: 8 };
        let a = c.generate(r.clone()).unwrap();
        r.id = 3;
        let b = c.generate(r).unwrap();
        assert_eq!(a.sequences, b.sequences, "tuned grid must be cached + reused");

        // Adaptive with a one-stage solver is a clean error, not a panic.
        let mut r = req(4, Solver::TauLeaping, 16, 1, 0);
        r.schedule = ScheduleSpec::Adaptive { tol: 1e-3 };
        assert!(c.generate(r).is_err());
        // ... and the coordinator thread survived it.
        let mut r = req(5, solver, 16, 1, 1);
        r.schedule = ScheduleSpec::Log;
        let resp = c.generate(r).unwrap();
        assert!(resp.sequences[0].iter().all(|&t| t < 6));
        c.shutdown();
    }

    #[test]
    fn local_backend_serves_exact_solver() {
        // Solver::Exact dispatches through batcher -> scheduler like any
        // approximate scheme; nfe_used echoes the realized jump count.
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let resp = c.generate(req(1, Solver::Exact, 16, 3, 11)).unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 20);
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        // Realized NFE: <= one eval per dim + one finalize, independent of
        // the requested planning budget.
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 21, "nfe={}", resp.nfe_used);

        // Same seed -> identical samples (per-lane seeded fhs streams).
        let again = c.generate(req(2, Solver::Exact, 16, 3, 11)).unwrap();
        assert_eq!(again.sequences, resp.sequences);

        // Exact + hard budget is a clean error and the thread survives.
        let mut r = req(3, Solver::Exact, 16, 1, 0);
        r.nfe_budget = Some(8);
        assert!(c.generate(r).is_err());
        let ok = c.generate(req(4, Solver::Exact, 16, 1, 5)).unwrap();
        assert_eq!(ok.sequences.len(), 1);
        c.shutdown();
    }

    #[test]
    fn local_backend_persists_tuned_schedules_across_restart() {
        let dir = std::env::temp_dir().join(format!(
            "fastdds_coord_sched_{}",
            std::process::id()
        ));
        let dir = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);
        let solver = Solver::Trapezoidal { theta: 0.5 };

        let mut r = req(1, solver, 16, 2, 9);
        r.schedule = ScheduleSpec::Tuned { steps: 8 };
        let first = {
            let oracle = local_oracle(6, 20);
            let c = Coordinator::start_local_with_schedule_dir(
                oracle,
                BatchPolicy::Greedy,
                8,
                Some(&dir),
            );
            let resp = c.generate(r.clone()).unwrap();
            c.shutdown();
            resp.sequences
        };
        // The fit must have been flushed to disk.
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().flatten().collect();
        assert!(!files.is_empty(), "tuned schedule not flushed to {dir:?}");

        // Restarted coordinator (same oracle construction): the reloaded
        // grid reproduces the samples exactly.
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local_with_schedule_dir(
            oracle,
            BatchPolicy::Greedy,
            8,
            Some(&dir),
        );
        r.id = 2;
        let resp = c.generate(r).unwrap();
        assert_eq!(resp.sequences, first, "reloaded tuned grid must replay");
        c.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn end_to_end_generation() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        let resp = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 3, 7))
            .unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 32);
            assert!(s.iter().all(|&t| t < 16), "masks left: {s:?}");
        }
        // Sparse skipping lets a lane finish under budget; finalize adds at
        // most one evaluation on top.
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 34, "nfe={}", resp.nfe_used);
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 3);
        c.shutdown();
    }

    #[test]
    fn local_backend_serves_without_artifacts() {
        let oracle = local_oracle(6, 24);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let resp = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 3, 7))
            .unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 24);
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 33);
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 3);
        assert_eq!(m.dispatches, 1, "3 lanes must co-batch in one dispatch");
        c.shutdown();
    }

    #[test]
    fn local_backend_batches_are_lane_reproducible() {
        // The whole stack — batcher lane seeding, run_batch_scored,
        // generate_batch — must produce exactly what a single-lane
        // masked::generate with the derived lane seed produces.
        let oracle = local_oracle(5, 16);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let solver = Solver::TauLeaping;
        let (nfe, n, seed) = (16usize, 4usize, 99u64);
        let resp = c.generate(req(1, solver, nfe, n, seed)).unwrap();
        assert_eq!(resp.sequences.len(), n);
        let grid_ts = grid::masked_uniform(solver.steps_for_nfe(nfe), scheduler::DELTA);
        for (idx, seq) in resp.sequences.iter().enumerate() {
            let lane_seed =
                seed.wrapping_add((idx as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut rng = Xoshiro256::seed_from_u64(lane_seed);
            let (want, _) = masked::generate(oracle.as_ref(), solver, &grid_ts, &mut rng);
            assert_eq!(seq, &want, "lane {idx}");
        }
        // Same request again: identical samples even with different
        // co-batching partners in flight.
        let again = c.generate(req(2, solver, nfe, n, seed)).unwrap();
        assert_eq!(again.sequences, resp.sequences);
        c.shutdown();
    }

    #[test]
    fn invalid_request_rejected_at_intake_without_poisoning_batch() {
        // Knobs on a non-exact solver are invalid, but their bits are
        // zeroed out of non-exact batch keys — so an invalid request and a
        // valid one land in the SAME queue.  Intake validation must reject
        // the invalid one and leave its co-batched neighbour unharmed.
        let oracle = local_oracle(5, 12);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let mut bad = req(1, Solver::TauLeaping, 16, 2, 3);
        bad.slack = Some(2.0);
        let rx_bad = c.submit(bad);
        let rx_good = c.submit(req(2, Solver::TauLeaping, 16, 2, 3));
        let err = rx_bad.recv().unwrap().unwrap_err();
        assert!(format!("{err:#}").contains("exact"), "{err:#}");
        let good = rx_good.recv().unwrap().unwrap();
        assert_eq!(good.sequences.len(), 2);
        assert!(good.sequences.iter().all(|s| s.iter().all(|&t| t < 5)));
        c.shutdown();
    }

    #[test]
    fn local_backend_rejects_absurd_budget() {
        let oracle = local_oracle(4, 8);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 4);
        let err = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 1, 1, 0))
            .unwrap_err();
        assert!(format!("{err:#}").contains("below one step"), "{err:#}");
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_batched_and_reproducible() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        // Same seed/solver twice -> identical sequences even when batched
        // with different partners.
        let rx1 = c.submit(req(1, Solver::TauLeaping, 16, 2, 99));
        let rx2 = c.submit(req(2, Solver::TauLeaping, 16, 4, 55));
        let rx3 = c.submit(req(3, Solver::Euler, 16, 1, 1));
        let r1 = rx1.recv().unwrap().unwrap();
        let r2 = rx2.recv().unwrap().unwrap();
        let r3 = rx3.recv().unwrap().unwrap();
        assert_eq!(r1.sequences.len(), 2);
        assert_eq!(r2.sequences.len(), 4);
        assert_eq!(r3.sequences.len(), 1);

        let r1b = c.generate(req(9, Solver::TauLeaping, 16, 2, 99)).unwrap();
        assert_eq!(r1.sequences, r1b.sequences, "seeded lanes must be batch-invariant");
        c.shutdown();
    }

    #[test]
    fn rejects_absurd_budget() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        let err = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 1, 1, 0))
            .unwrap_err();
        assert!(format!("{err:#}").contains("below one step"), "{err:#}");
        c.shutdown();
    }

    #[test]
    fn timeout_policy_improves_occupancy() {
        let Some(c) = coordinator(BatchPolicy::Timeout(Duration::from_millis(30)))
        else {
            return;
        };
        let rxs: Vec<_> = (0..4)
            .map(|i| c.submit(req(i, Solver::TauLeaping, 16, 2, i)))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let m = c.metrics();
        // 8 lanes with batch size 8: with the hold-for-timeout policy these
        // should need very few dispatches (the exact count depends on
        // arrival timing, so just check it beats one-lane-per-dispatch).
        assert!(m.dispatches <= 4, "dispatches={}", m.dispatches);
        assert!(m.occupancy.mean() > 0.25, "occupancy={}", m.occupancy.mean());
        c.shutdown();
    }
}
