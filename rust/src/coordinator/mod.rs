//! The serving coordinator — request router, dynamic batcher, step
//! scheduler and metrics over the PJRT runtime.
//!
//! Architecture (all std threads + channels; tokio is not vendored):
//!
//! ```text
//!   submit_spec()/submit() ──channel──▶ coordinator thread
//!        │ (JobHandle: id,                │  DynamicBatcher (group lanes
//!        │  event stream,                 │    by BatchKey::of(spec))
//!        │  cancel token)                 │  run_batch_scored ──▶ generate_batch
//!        │                               │    (score artifact over PJRT, or
//!   cancel(id) ──shared registry──▶      │     local oracle; legacy fused
//!     fires the job's CancelToken        │     step graphs as fallback)
//!     (polled inside the solver loops)   │  ResponseAssembler (reunite lanes)
//!                                        └──▶ per-job event channels
//!                                             (Lane chunks → Done/Failed)
//! ```
//!
//! Every submission is a **job**: [`Coordinator::submit_spec`] returns a
//! [`JobHandle`] carrying the id, an event receiver and a cancel token.
//! Blocking `generate` is just `submit + wait`; the streaming server verb
//! subscribes to the per-lane [`JobEvent::Lane`] chunks (emitted as each
//! lane completes a dispatch, so a large request split across batches
//! streams progressively); `cancel(id)` fires the token from any thread —
//! the solver loops poll it per window, so even a long exact-simulation
//! run winds down within one window and completes its job with a
//! partial-result response.
//!
//! Validation happens **before** submission, at spec construction
//! ([`crate::api::SpecBuilder`]): a coordinator never sees an invalid
//! request, and the batch key is derived from the same resolved plan the
//! scheduler executes, so intake re-validation (the pre-redesign
//! workaround for under-encoding keys) is gone.
//!
//! Batching pays off *below* the request layer: every batch the
//! `DynamicBatcher` emits is executed by `solvers::masked::generate_batch`,
//! which makes one masked-sparse score call per solver stage for all lanes
//! together.  With artifacts present that call is a single PJRT dispatch of
//! the `{family}_score` graph; with a local oracle it fans across the
//! threadpool.  The legacy per-step fused graphs remain as a fallback for
//! families that ship step artifacts but no score artifact.
//!
//! # Failure taxonomy
//!
//! Every failure path ends in a typed [`JobEvent::Failed`] with a stable
//! code from [`codes`] (surfaced on the wire — see the table in
//! [`crate::api::wire`]); nothing hangs a client, and nothing leaks a
//! registry entry:
//!
//! - **A lane panics during dispatch** (`lane_failed`): the batch runs
//!   under `catch_unwind`.  On a panic, each lane is re-executed alone
//!   (also caught); the panicking lane's request fails typed, sibling
//!   lanes complete — bit-identical to an uninjected run for fixed-grid
//!   and exact plans (per-lane seeded streams; PR 1's batch-invariance).
//!   Adaptive siblings re-run under a solo dt vote, the documented
//!   trade-off of shared online control.
//! - **The backend reports an execution error** (`batch_failed`): every
//!   request with a lane in the batch fails typed; its assembler state is
//!   discarded and its queued lanes purged.
//! - **A duplicate idempotency key** (`duplicate_request`): a submission
//!   carrying a `request_key` already claimed by an in-flight job fails
//!   typed at submission (before the registry or queue see it), echoing
//!   the original job id; the key is released — and instantly reusable —
//!   the moment its job completes, fails, or is rejected.
//! - **Admission rejects a request** (`deadline_infeasible` /
//!   `overloaded`): intake compares the resolved plan's NFE (the
//!   [`SamplingSpec::planned_nfe`] cost model) against a learned ms/NFE
//!   rate for deadline feasibility, and enforces queue-depth + in-flight
//!   caps with priority-aware shedding — an arriving higher-priority
//!   request may displace a strictly lower-priority request that has no
//!   completed lanes yet (the displaced job fails `overloaded`).
//! - **A deadline expires mid-run**: not an error — the driver polls the
//!   deadline on the same per-window hook as the cancel token, and the job
//!   completes with a partial response (counted as `deadline_expiries`).
//! - **The score backend is sick** (`backend_unavailable`): score
//!   dispatches run on a watchdogged worker thread with an eval timeout
//!   derived from the learned ms/NFE cost model; a timed-out or
//!   `[transient]`-marked eval is retried under capped backoff within a
//!   per-dispatch budget ([`health::HealthCfg`]).  Evals are pure (each
//!   lane re-seeds per attempt), so a retried-then-succeeded request is
//!   bit-identical to a never-faulted run.  Exhausted retries fail typed
//!   and feed the circuit breaker ([`health::HealthTracker`]); while it
//!   is open, new batches fail fast with the same code instead of
//!   queueing behind the sick backend, until a half-open probe succeeds.
//!   A stalled eval blocks only the abandoned worker — never the loop —
//!   so it cannot delay unrelated queued requests past the watchdog
//!   bound.
//! - **Sustained overload (brownout)**: before the capacity loop sheds,
//!   intake walks degradable specs down a pre-declared ladder
//!   ([`SamplingSpec::degrade`]: PIT off → uniform schedule → NFE floor)
//!   keyed to queue/in-flight utilization — and straight to the last rung
//!   while the breaker is non-closed.  Every degraded plan is still a
//!   valid typed spec (built through the same constructors), the response
//!   echoes `degraded` + rung, and specs that set `no_degrade` are never
//!   touched (they shed typed `overloaded` instead).  Undegraded requests
//!   are bit-identical to a coordinator without brownout.
//! - **The scheduler loop itself crashes** (`coordinator_restarted`): the
//!   supervisor catches the panic, fails all in-flight jobs typed, clears
//!   the registry, rebuilds batching state (metrics survive), and
//!   re-enters the loop under capped exponential backoff
//!   ([`supervise::Backoff`], reset after a healthy dispatch).
//! - **Shutdown with work still registered** (`shutdown`): drained jobs
//!   complete normally; anything left at exit fails typed.

pub mod request;
pub mod batcher;
pub mod scheduler;
pub mod state;
pub mod metrics;
pub mod supervise;
pub mod health;

use std::collections::{BTreeMap, BTreeSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

pub use batcher::{BatchKey, BatchPolicy, DynamicBatcher};
pub use health::HealthCfg;
pub use metrics::Metrics;
pub use request::{GenerateRequest, GenerateResponse};
pub use supervise::Backoff;

use health::{DispatchWorker, Gate, HealthTracker, WorkerReply};

pub use crate::api::{CancelToken, SamplingSpec};

use crate::registry::ArtifactRegistry;
use crate::runtime::{ArtifactScore, Registry, RuntimeHandle};
use crate::schedule::{ScheduleCache, ScheduleSpec};
use crate::score::{ScoreSource, Tok};
use state::ResponseAssembler;

/// Stable machine-readable codes of the runtime failure paths (the
/// spec-validation codes live on [`crate::api::SpecError::code`]).  The
/// full wire-level table is documented in [`crate::api::wire`].
pub mod codes {
    /// A panic inside this request's own lane(s) during dispatch.
    pub const LANE_FAILED: &str = "lane_failed";
    /// The backend reported a batch-level execution error.
    pub const BATCH_FAILED: &str = "batch_failed";
    /// Shed at intake: queue/in-flight caps (or displaced by priority).
    pub const OVERLOADED: &str = "overloaded";
    /// Rejected at intake: the plan's NFE cannot fit the deadline.
    pub const DEADLINE_INFEASIBLE: &str = "deadline_infeasible";
    /// In flight when the supervisor restarted the scheduler loop.
    pub const COORDINATOR_RESTARTED: &str = "coordinator_restarted";
    /// In flight at coordinator shutdown.
    pub const SHUTDOWN: &str = "shutdown";
    /// A request carried a `request_key` already claimed by an in-flight
    /// job (idempotency dedupe); the message echoes the original job id.
    pub const DUPLICATE_REQUEST: &str = "duplicate_request";
    /// The score backend's circuit breaker is open, or a stalled /
    /// transiently-failing eval exhausted its retry budget.
    pub const BACKEND_UNAVAILABLE: &str = "backend_unavailable";
}

/// Typed job failure: a stable [`codes`] code plus a human-readable
/// message.  [`JobHandle::wait`] returns it inside the `anyhow` chain, so
/// callers (the server) recover the code with `downcast_ref::<JobError>()`.
#[derive(Clone, Debug)]
pub struct JobError {
    pub code: &'static str,
    pub message: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for JobError {}

/// One progress/completion event of a job.
#[derive(Debug)]
pub enum JobEvent {
    /// A lane finished a dispatch (streamed jobs only): its sample index,
    /// its tokens, the NFE it spent, and whether it was interrupted.
    Lane { sample_idx: usize, tokens: Vec<Tok>, nfe: usize, partial: bool },
    /// Driver heartbeat (streamed jobs that set [`SamplingSpec::progress`]
    /// only): `done`/`total` in `phase` units — solver windows for the
    /// sequential drivers (`"window"`), Picard sweeps for PIT (`"sweep"`).
    /// Emitted from the same per-window hook that polls cancellation, so a
    /// stalled stream and a stalled cancel poll are the same symptom.
    Progress { done: usize, total: usize, phase: &'static str },
    /// All lanes done — the assembled response (also carries `partial`).
    Done(GenerateResponse),
    /// The job failed: a stable [`codes`] code plus the failure message.
    Failed { code: &'static str, message: String },
}

/// Handle to a submitted job: the serving id (the `cancel` verb's key), a
/// receiver of [`JobEvent`]s, and the job's cancel token.
pub struct JobHandle {
    pub id: u64,
    events: Receiver<JobEvent>,
    cancel: CancelToken,
}

impl JobHandle {
    /// Fire the job's cancel token (cooperative: the run winds down at the
    /// next solver window and completes with a partial response).
    pub fn cancel(&self) {
        self.cancel.cancel();
    }

    /// Next event (blocking).  A dropped channel means the coordinator
    /// went away without completing the job — surfaced as a typed
    /// `shutdown` [`JobError`], never a hang.
    pub fn recv(&self) -> Result<JobEvent> {
        self.events.recv().map_err(|_| {
            JobError {
                code: codes::SHUTDOWN,
                message: "coordinator dropped the job channel".to_string(),
            }
            .into()
        })
    }

    /// Drain events until completion and return the response.  Failures
    /// carry a typed [`JobError`] in the chain (downcast for the code).
    pub fn wait(self) -> Result<GenerateResponse> {
        loop {
            match self.recv()? {
                JobEvent::Lane { .. } | JobEvent::Progress { .. } => continue,
                JobEvent::Done(resp) => return Ok(resp),
                JobEvent::Failed { code, message } => {
                    return Err(JobError { code, message }.into());
                }
            }
        }
    }
}

struct Job {
    id: u64,
    spec: SamplingSpec,
    events: Sender<JobEvent>,
    stream: bool,
    cancel: CancelToken,
    /// Claimed idempotency key (already inserted in [`Shared::keys`];
    /// every exit path of the job must release it).
    key: Option<String>,
}

enum Msg {
    Submit(Job),
    Metrics(Sender<Metrics>),
    /// Test hook: panic the scheduler loop deterministically so the
    /// supervisor's restart path is exercisable without a real bug.
    Crash(String),
    Shutdown,
}

/// Admission-control limits.  `None` = unbounded (the historical
/// behavior); the serve CLI maps `--max-inflight` / `--queue-cap` here.
#[derive(Clone, Copy, Debug, Default)]
pub struct CoordinatorCfg {
    /// Max requests registered (accepted, not yet completed) at once.
    pub max_inflight: Option<usize>,
    /// Max lanes sitting in the batcher queues.
    pub queue_cap: Option<usize>,
    /// Robustness knobs: circuit breaker, stall watchdog + retry budget,
    /// brownout ladder ([`health::HealthCfg`]).  Defaults keep everything
    /// on with production-shaped constants.
    pub health: HealthCfg,
}

/// State shared between coordinator handles and the loop thread: the id
/// allocator and the cancel-token registry (`cancel` must work while the
/// loop thread is busy executing a batch, so it bypasses the channel).
struct Shared {
    next_id: AtomicU64,
    cancels: Mutex<BTreeMap<u64, CancelToken>>,
    /// In-flight idempotency keys → the job id that claimed each.  Claimed
    /// at submission (before the loop thread sees the job, so two racing
    /// duplicates cannot both pass) and released when the job completes,
    /// fails, or is rejected — a finished key is immediately reusable.
    keys: Mutex<BTreeMap<String, u64>>,
}

fn lock_cancels(shared: &Shared) -> std::sync::MutexGuard<'_, BTreeMap<u64, CancelToken>> {
    shared.cancels.lock().unwrap_or_else(|e| e.into_inner())
}

fn lock_keys(shared: &Shared) -> std::sync::MutexGuard<'_, BTreeMap<String, u64>> {
    shared.keys.lock().unwrap_or_else(|e| e.into_inner())
}

/// Release a claimed idempotency key (no-op for keyless jobs).
fn release_key(shared: &Shared, key: &Option<String>) {
    if let Some(k) = key {
        lock_keys(shared).remove(k);
    }
}

/// Where batches execute.
enum Backend {
    /// PJRT runtime: prefer the `{family}_score` artifact through
    /// `generate_batch`; fall back to the legacy fused step graphs.
    Pjrt {
        runtime: RuntimeHandle,
        registry: Registry,
        /// Lazily built, cached per family.
        scores: BTreeMap<String, Arc<ArtifactScore>>,
        /// Tuned grids, memoised per (family, vocab, seq_len, solver,
        /// steps).  Shared with the watchdog's dispatch worker, hence the
        /// mutex (locked only for the tuned-arm lookup, never across an
        /// evaluation).
        schedules: Arc<Mutex<ScheduleCache>>,
    },
    /// A local in-process score source (analytic oracle): no artifacts
    /// needed, everything runs through `generate_batch`.
    Local {
        score: Arc<dyn ScoreSource>,
        schedules: Arc<Mutex<ScheduleCache>>,
    },
}

/// Handle to the coordinator thread.
#[derive(Clone)]
pub struct Coordinator {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
    /// Shared artifact registry ([`crate::registry`]): the schedule cache
    /// pulls/publishes tuned grids through it, the server's `registry_*`
    /// wire verbs read it via [`Coordinator::artifact_registry`], and
    /// [`Coordinator::metrics`] patches its counters into every snapshot.
    /// `None` = no `--registry-dir` configured.
    artifacts: Option<Arc<ArtifactRegistry>>,
}

impl Coordinator {
    pub fn start(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
    ) -> Coordinator {
        Coordinator::start_with_schedule_dir(runtime, registry, policy, None)
    }

    /// As [`Coordinator::start`], with tuned schedules persisted under
    /// `schedule_dir`: fits flush to disk on insert and reload on start, so
    /// a restart never re-pays the pilot runs ([`ScheduleCache`]).
    pub fn start_with_schedule_dir(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
        schedule_dir: Option<&str>,
    ) -> Coordinator {
        Coordinator::start_with_cfg(
            runtime,
            registry,
            policy,
            schedule_dir,
            CoordinatorCfg::default(),
        )
    }

    /// As [`Coordinator::start_with_schedule_dir`], with admission-control
    /// limits ([`CoordinatorCfg`]).
    pub fn start_with_cfg(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
        schedule_dir: Option<&str>,
        cfg: CoordinatorCfg,
    ) -> Coordinator {
        Coordinator::start_with_registry(runtime, registry, policy, schedule_dir, cfg, None)
    }

    /// As [`Coordinator::start_with_cfg`], sharing a content-addressed
    /// artifact registry: tuned schedules are pulled by digest before
    /// fitting and published after, and the `registry_*` wire verbs go
    /// live on any server holding this coordinator.
    pub fn start_with_registry(
        runtime: RuntimeHandle,
        registry: Registry,
        policy: BatchPolicy,
        schedule_dir: Option<&str>,
        cfg: CoordinatorCfg,
        artifacts: Option<Arc<ArtifactRegistry>>,
    ) -> Coordinator {
        // Batch capacity = the max artifact batch across families.
        let max_lanes = registry
            .by_family("markov")
            .iter()
            .filter_map(|a| a.batch().ok())
            .max()
            .unwrap_or(8);
        let backend = Backend::Pjrt {
            runtime,
            registry,
            scores: BTreeMap::new(),
            schedules: Arc::new(Mutex::new(ScheduleCache::with_store(
                schedule_dir,
                artifacts.clone(),
            ))),
        };
        Coordinator::spawn(backend, policy, max_lanes, cfg, artifacts)
    }

    /// Serve straight from an in-process score source (no artifacts, no
    /// PJRT): the dynamic batcher still groups lanes and every batch runs
    /// through `generate_batch`.
    pub fn start_local(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
    ) -> Coordinator {
        Coordinator::start_local_with_schedule_dir(score, policy, max_lanes, None)
    }

    /// As [`Coordinator::start_local`], with tuned schedules persisted
    /// under `schedule_dir` across restarts.
    pub fn start_local_with_schedule_dir(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
        schedule_dir: Option<&str>,
    ) -> Coordinator {
        Coordinator::start_local_with_cfg(
            score,
            policy,
            max_lanes,
            schedule_dir,
            CoordinatorCfg::default(),
        )
    }

    /// As [`Coordinator::start_local_with_schedule_dir`], with
    /// admission-control limits ([`CoordinatorCfg`]).
    pub fn start_local_with_cfg(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
        schedule_dir: Option<&str>,
        cfg: CoordinatorCfg,
    ) -> Coordinator {
        Coordinator::start_local_with_registry(score, policy, max_lanes, schedule_dir, cfg, None)
    }

    /// As [`Coordinator::start_local_with_cfg`], sharing a
    /// content-addressed artifact registry (see
    /// [`Coordinator::start_with_registry`]).
    pub fn start_local_with_registry(
        score: Arc<dyn ScoreSource>,
        policy: BatchPolicy,
        max_lanes: usize,
        schedule_dir: Option<&str>,
        cfg: CoordinatorCfg,
        artifacts: Option<Arc<ArtifactRegistry>>,
    ) -> Coordinator {
        Coordinator::spawn(
            Backend::Local {
                score,
                schedules: Arc::new(Mutex::new(ScheduleCache::with_store(
                    schedule_dir,
                    artifacts.clone(),
                ))),
            },
            policy,
            max_lanes.max(1),
            cfg,
            artifacts,
        )
    }

    fn spawn(
        backend: Backend,
        policy: BatchPolicy,
        max_lanes: usize,
        cfg: CoordinatorCfg,
        artifacts: Option<Arc<ArtifactRegistry>>,
    ) -> Coordinator {
        let (tx, rx) = channel::<Msg>();
        let shared = Arc::new(Shared {
            next_id: AtomicU64::new(1),
            cancels: Mutex::new(BTreeMap::new()),
            keys: Mutex::new(BTreeMap::new()),
        });
        let loop_shared = Arc::clone(&shared);
        std::thread::Builder::new()
            .name("coordinator".into())
            .spawn(move || supervised_loop(backend, policy, max_lanes, cfg, rx, loop_shared))
            .expect("spawning coordinator");
        Coordinator { tx, shared, artifacts }
    }

    fn submit_internal(
        &self,
        id: u64,
        spec: SamplingSpec,
        stream: bool,
        key: Option<String>,
    ) -> JobHandle {
        // Idempotency: claim the key before the loop thread can see the
        // job, so two racing duplicates cannot both pass.  A claimed key
        // fails the *newer* submission typed, echoing the original id so
        // the client can attach to (or cancel) the in-flight job.
        if let Some(k) = &key {
            let mut keys = lock_keys(&self.shared);
            if let Some(&original) = keys.get(k) {
                drop(keys);
                let (events_tx, events_rx) = channel();
                let _ = events_tx.send(JobEvent::Failed {
                    code: codes::DUPLICATE_REQUEST,
                    message: format!(
                        "request_key {k:?} is already claimed by in-flight job {original}"
                    ),
                });
                return JobHandle { id, events: events_rx, cancel: CancelToken::never() };
            }
            keys.insert(k.clone(), id);
        }
        // A deadline arms the job's cancel token: the solver loops already
        // poll it per window, so expiry winds the run down into a partial
        // response with no extra plumbing (and no RNG consumed — parity
        // with un-deadlined runs is pinned by the golden tests).
        let cancel = CancelToken::with_deadline(
            spec.deadline_ms().map(|ms| Instant::now() + Duration::from_millis(ms)),
        );
        lock_cancels(&self.shared).insert(id, cancel.clone());
        let (events_tx, events_rx) = channel();
        let sent = self.tx.send(Msg::Submit(Job {
            id,
            spec,
            events: events_tx.clone(),
            stream,
            cancel: cancel.clone(),
            key: key.clone(),
        }));
        if sent.is_err() {
            // Shut-down coordinator: fail typed instead of panicking the
            // submitting thread.
            lock_cancels(&self.shared).remove(&id);
            release_key(&self.shared, &key);
            let _ = events_tx.send(JobEvent::Failed {
                code: codes::SHUTDOWN,
                message: "coordinator is shut down".to_string(),
            });
        }
        JobHandle { id, events: events_rx, cancel }
    }

    /// Submit a spec as a blocking-style job (no per-lane events) with a
    /// coordinator-assigned id.
    pub fn submit_spec(&self, spec: SamplingSpec) -> JobHandle {
        self.submit_spec_keyed(spec, None)
    }

    /// As [`Coordinator::submit_spec`], with an optional idempotency key:
    /// if `request_key` is already claimed by an in-flight job, the new
    /// submission fails typed [`codes::DUPLICATE_REQUEST`] (the message
    /// echoes the original job id) and nothing is enqueued.
    pub fn submit_spec_keyed(
        &self,
        spec: SamplingSpec,
        request_key: Option<String>,
    ) -> JobHandle {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_internal(id, spec, false, request_key)
    }

    /// Submit a spec as a streaming job: the handle receives a
    /// [`JobEvent::Lane`] chunk for every completed lane, then `Done`.
    pub fn submit_stream(&self, spec: SamplingSpec) -> JobHandle {
        self.submit_stream_keyed(spec, None)
    }

    /// As [`Coordinator::submit_stream`], with an optional idempotency key
    /// (same dedupe contract as [`Coordinator::submit_spec_keyed`]).
    pub fn submit_stream_keyed(
        &self,
        spec: SamplingSpec,
        request_key: Option<String>,
    ) -> JobHandle {
        let id = self.shared.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_internal(id, spec, true, request_key)
    }

    /// Submit with a caller-chosen id (embedding users and tests; ids also
    /// key the cancel registry, so keep them unique).
    pub fn submit(&self, req: GenerateRequest) -> JobHandle {
        self.submit_internal(req.id, req.spec, false, None)
    }

    /// Submit and wait.
    pub fn generate(&self, req: GenerateRequest) -> Result<GenerateResponse> {
        self.submit(req).wait()
    }

    /// Submit a spec and wait.
    pub fn generate_spec(&self, spec: SamplingSpec) -> Result<GenerateResponse> {
        self.submit_spec(spec).wait()
    }

    /// Fire the cancel token of an in-flight job.  Returns whether the id
    /// was found (false = unknown id or already completed).  Cooperative:
    /// the job still completes through its event channel, with `partial`
    /// set on whatever the solver had produced.
    pub fn cancel(&self, id: u64) -> bool {
        match lock_cancels(&self.shared).get(&id) {
            Some(token) => {
                token.cancel();
                true
            }
            None => false,
        }
    }

    /// The shared artifact registry this coordinator was started with
    /// (`None` when no `--registry-dir` is configured).  The server's
    /// `registry_*` wire verbs resolve their store through this accessor,
    /// so adding the registry never changed the server's surface.
    pub fn artifact_registry(&self) -> Option<Arc<ArtifactRegistry>> {
        self.artifacts.clone()
    }

    pub fn metrics(&self) -> Metrics {
        let (reply, rx) = channel();
        let mut m = if self.tx.send(Msg::Metrics(reply)).is_err() {
            Metrics::new()
        } else {
            rx.recv().unwrap_or_else(|_| Metrics::new())
        };
        // Registry counters live on the shared `ArtifactRegistry` (the
        // server's wire verbs bump them without going through the loop
        // thread), so they are patched into the snapshot here rather than
        // accumulated by the scheduler.
        if let Some(reg) = &self.artifacts {
            let s = reg.stats();
            m.registry_puts = s.puts;
            m.registry_gets = s.gets;
            m.registry_integrity_failures = s.integrity_failures;
            m.registry_blobs = s.blobs;
            m.registry_blob_bytes = s.blob_bytes;
        }
        m
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }

    /// Test hook: crash the scheduler loop deterministically so the
    /// supervisor's restart path is exercisable without a real bug.
    #[doc(hidden)]
    pub fn inject_loop_panic(&self, reason: &str) {
        let _ = self.tx.send(Msg::Crash(reason.to_string()));
    }
}

/// Execute one packed batch on the backend.  `obs` (when jobs in the
/// batch asked for progress) receives the driver's per-window/per-sweep
/// heartbeat; the legacy fused-graph fallback has no such hook and stays
/// silent.
fn execute_batch(
    backend: &mut Backend,
    proto: &SamplingSpec,
    lanes: &[batcher::Lane],
    obs: Option<&mut dyn FnMut(crate::solvers::driver::Progress)>,
) -> Result<scheduler::BatchResult> {
    match backend {
        Backend::Local { score, schedules } => {
            scheduler::run_batch_scored_obs(score.as_ref(), proto, lanes, schedules, obs)
        }
        Backend::Pjrt { runtime, registry, scores, schedules } => {
            let score_name = format!("{}_score", proto.family());
            if registry.get(&score_name).is_ok() {
                let score = match scores.get(proto.family()) {
                    Some(s) => Arc::clone(s),
                    None => {
                        let s = Arc::new(ArtifactScore::new(
                            runtime.clone(),
                            registry,
                            proto.family(),
                        )?);
                        scores.insert(proto.family().to_string(), Arc::clone(&s));
                        s
                    }
                };
                let result = scheduler::run_batch_scored_obs(
                    score.as_ref(),
                    proto,
                    lanes,
                    schedules,
                    obs,
                )?;
                // Score dispatch failures poison the source instead of
                // surfacing through the trait; convert them to a batch error.
                if let Some(err) = score.take_error() {
                    return Err(anyhow!("score artifact dispatch failed: {err}"));
                }
                Ok(result)
            } else {
                // Legacy path: fused per-step graphs over the uniform grid
                // only (non-uniform schedules need the score-artifact or
                // local backend).
                if proto.schedule() != ScheduleSpec::Uniform || proto.nfe_budget().is_some() {
                    return Err(anyhow!(
                        "schedule {:?} requires a score artifact or local backend \
                         (family {:?} ships only fused step graphs)",
                        proto.schedule().to_string_spec(),
                        proto.family()
                    ));
                }
                let plan = scheduler::StepPlan::build(registry, proto)?;
                scheduler::run_batch(runtime, &plan, proto.solver(), lanes)
            }
        }
    }
}

/// The pieces of one *watchable* dispatch — cheap clones the watchdog's
/// worker thread can own.  `None` from [`scored_job`] means the batch can
/// only run on the legacy fused-step-graph path, which needs `&mut
/// Backend` and therefore stays inline on the loop thread (unwatched, the
/// historical behavior — documented trade-off of the fallback).
struct ScoredJob {
    score: Arc<dyn ScoreSource>,
    schedules: Arc<Mutex<ScheduleCache>>,
    /// Present for artifact-backed scores: polled for poisoned dispatch
    /// errors after the run (the trait cannot surface them).
    artifact: Option<Arc<ArtifactScore>>,
}

/// Extract the watchable pieces of one dispatch from the backend (lazily
/// building the family's score artifact, exactly as [`execute_batch`]
/// would).
fn scored_job(backend: &mut Backend, proto: &SamplingSpec) -> Result<Option<ScoredJob>> {
    match backend {
        Backend::Local { score, schedules } => Ok(Some(ScoredJob {
            score: Arc::clone(score),
            schedules: Arc::clone(schedules),
            artifact: None,
        })),
        Backend::Pjrt { runtime, registry, scores, schedules } => {
            let score_name = format!("{}_score", proto.family());
            if registry.get(&score_name).is_err() {
                return Ok(None);
            }
            let score = match scores.get(proto.family()) {
                Some(s) => Arc::clone(s),
                None => {
                    let s = Arc::new(ArtifactScore::new(
                        runtime.clone(),
                        registry,
                        proto.family(),
                    )?);
                    scores.insert(proto.family().to_string(), Arc::clone(&s));
                    s
                }
            };
            Ok(Some(ScoredJob {
                score: Arc::clone(&score) as Arc<dyn ScoreSource>,
                schedules: Arc::clone(schedules),
                artifact: Some(score),
            }))
        }
    }
}

/// Box one scored evaluation for the dispatch worker.  Everything moved
/// in is a cheap handle (Arcs, lane clones, event senders); the
/// evaluation itself is pure, so re-boxing a fresh closure per retry
/// attempt replays the identical computation.
fn make_work(
    job: ScoredJob,
    proto: SamplingSpec,
    lanes: Vec<batcher::Lane>,
    progress_txs: Vec<Sender<JobEvent>>,
) -> Box<dyn FnOnce() -> Result<scheduler::BatchResult> + Send> {
    Box::new(move || {
        let mut obs_fn;
        let obs: Option<&mut dyn FnMut(crate::solvers::driver::Progress)> =
            if progress_txs.is_empty() {
                None
            } else {
                obs_fn = |p: crate::solvers::driver::Progress| {
                    for tx in &progress_txs {
                        let _ = tx.send(JobEvent::Progress {
                            done: p.done,
                            total: p.total,
                            phase: p.phase,
                        });
                    }
                };
                Some(&mut obs_fn)
            };
        let result = scheduler::run_batch_scored_obs(
            job.score.as_ref(),
            &proto,
            &lanes,
            &job.schedules,
            obs,
        )?;
        if let Some(artifact) = &job.artifact {
            // Score dispatch failures poison the source instead of
            // surfacing through the trait; convert them to a batch error.
            if let Some(err) = artifact.take_error() {
                return Err(anyhow!("score artifact dispatch failed: {err}"));
            }
        }
        Ok(result)
    })
}

/// Classified outcome of one dispatch attempt (see
/// [`LoopState::attempt_batch`]): timeouts and `[transient]`-marked
/// panics are the retryable arms.
enum Attempt {
    Done(scheduler::BatchResult),
    Failed(anyhow::Error),
    Panicked(Box<dyn std::any::Any + Send>),
    TimedOut,
}

/// Per-job sink state the loop thread keeps.
struct Sink {
    events: Sender<JobEvent>,
    stream: bool,
    priority: u8,
    /// The job asked for driver progress heartbeats (QoS; streamed only).
    progress: bool,
    /// Claimed idempotency key, released when the job leaves the table.
    key: Option<String>,
    /// Brownout ladder rung applied at admission (echoed on the response
    /// as `degraded`); `None` for undegraded requests.
    degraded: Option<u8>,
}

fn finish_job(
    jobs: &mut BTreeMap<u64, Sink>,
    shared: &Shared,
    id: u64,
    event: JobEvent,
) {
    lock_cancels(shared).remove(&id);
    if let Some(sink) = jobs.remove(&id) {
        release_key(shared, &sink.key);
        let _ = sink.events.send(event);
    }
}

/// Learned cost model for deadline feasibility: an EWMA of milliseconds
/// per score evaluation, observed from batch wall times.  Starts with no
/// evidence, so nothing is rejected until dispatches calibrate it.
struct CostModel {
    ms_per_nfe: f64,
}

impl CostModel {
    fn new() -> Self {
        Self { ms_per_nfe: 0.0 }
    }

    fn observe(&mut self, wall_ms: f64, nfe: usize) {
        if nfe == 0 {
            return;
        }
        let rate = wall_ms / nfe as f64;
        self.ms_per_nfe = if self.ms_per_nfe == 0.0 {
            rate
        } else {
            0.8 * self.ms_per_nfe + 0.2 * rate
        };
    }

    fn estimate_ms(&self, nfe: usize) -> f64 {
        self.ms_per_nfe * nfe as f64
    }
}

/// All loop-owned serving state, gathered so [`supervised_loop`] can catch
/// a panic anywhere in the scheduler and still hold the pieces: it fails
/// in-flight jobs typed ([`LoopState::recover`]) and re-enters
/// [`LoopState::run`].
struct LoopState {
    backend: Backend,
    policy: BatchPolicy,
    max_lanes: usize,
    cfg: CoordinatorCfg,
    batcher: DynamicBatcher,
    assembler: ResponseAssembler,
    jobs: BTreeMap<u64, Sink>,
    metrics: Metrics,
    cost: CostModel,
    /// Backend health: EWMA latency + the circuit breaker.
    health: HealthTracker,
    /// The watchdog's long-lived dispatch thread; `None` until the first
    /// watched dispatch, and again after a timeout abandons it (the next
    /// dispatch respawns lazily).
    worker: Option<DispatchWorker>,
    started: Instant,
    open: bool,
}

impl LoopState {
    fn now_ms(&self) -> f64 {
        self.started.elapsed().as_secs_f64() * 1e3
    }

    fn run(&mut self, rx: &Receiver<Msg>, shared: &Shared) {
        while self.open || self.batcher.pending() > 0 {
            // Drain inbound messages (block briefly when idle).
            let deadline = match self.policy {
                BatchPolicy::Greedy => Duration::from_millis(1),
                BatchPolicy::Timeout(d) => d.min(Duration::from_millis(5)),
            };
            loop {
                match rx.recv_timeout(if self.batcher.pending() > 0 {
                    Duration::from_micros(100)
                } else {
                    deadline
                }) {
                    Ok(Msg::Submit(job)) => self.admit(shared, job),
                    Ok(Msg::Metrics(reply)) => {
                        let mut m = self.metrics.clone();
                        m.in_flight = self.assembler.in_flight() as u64;
                        m.queued_lanes = self.batcher.pending() as u64;
                        m.registry_entries = lock_cancels(shared).len() as u64;
                        m.breaker_state = self.health.state_name().to_string();
                        let _ = reply.send(m);
                    }
                    Ok(Msg::Crash(reason)) => {
                        panic!("injected coordinator crash: {reason}")
                    }
                    Ok(Msg::Shutdown) => {
                        self.open = false;
                        break;
                    }
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                    Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                        self.open = false;
                        break;
                    }
                }
            }

            // Dispatch due batches, each under its own fault boundary.
            while let Some((_key, proto, lanes)) =
                self.batcher.next_batch(Instant::now())
            {
                self.metrics.dispatches += 1;
                self.metrics
                    .occupancy
                    .push(lanes.len() as f64 / self.batcher.max_lanes as f64);
                for lane in &lanes {
                    self.metrics
                        .queue_wait_ms
                        .push(lane.enqueued.elapsed().as_secs_f64() * 1e3);
                }
                // Jobs cancelled while still queued are NOT special-cased:
                // the solver loops poll the token before the first window,
                // so a pre-cancelled lane costs only its (all-masked) init
                // and comes back with the correct sequence shape —
                // still-masked positions carrying the mask id, exactly the
                // partial-result contract.  Fabricating empty sequences
                // here would break it.
                // Progress fan-out: clone the event sender of every
                // streaming job in this batch that opted in.  The driver's
                // heartbeat is batch-level (one sweep/window covers all
                // lanes), so each opted-in job sees the same frames.
                let mut progress_txs: Vec<Sender<JobEvent>> = Vec::new();
                let mut seen: BTreeSet<u64> = BTreeSet::new();
                for lane in &lanes {
                    if seen.insert(lane.request_id) {
                        if let Some(sink) = self.jobs.get(&lane.request_id) {
                            if sink.stream && sink.progress {
                                progress_txs.push(sink.events.clone());
                            }
                        }
                    }
                }
                // Breaker gate: an open breaker fails the batch fast,
                // typed, instead of queueing work behind a sick backend.
                match self.health.admit_dispatch() {
                    Gate::Allow => {}
                    Gate::Probe => self.metrics.breaker_probes += 1,
                    Gate::FastFail => {
                        self.metrics.backend_unavailable += 1;
                        self.fail_requests(
                            shared,
                            &lanes,
                            codes::BACKEND_UNAVAILABLE,
                            "score backend unavailable: circuit breaker open"
                                .to_string(),
                        );
                        continue;
                    }
                }
                self.dispatch_batch(shared, &proto, lanes, progress_txs);
            }
        }

        // Shutdown with jobs still registered (e.g. admitted after the
        // Shutdown message): fail typed, leak nothing.
        let leftover: Vec<u64> = self.jobs.keys().copied().collect();
        for id in leftover {
            self.assembler.abort(id);
            self.batcher.purge_request(id);
            finish_job(
                &mut self.jobs,
                shared,
                id,
                JobEvent::Failed {
                    code: codes::SHUTDOWN,
                    message: "coordinator shut down before the job completed".to_string(),
                },
            );
        }
        // Submissions that raced the shutdown are already in the channel
        // but will never be admitted: fail them typed too.
        while let Ok(msg) = rx.try_recv() {
            match msg {
                Msg::Submit(job) => {
                    lock_cancels(shared).remove(&job.id);
                    release_key(shared, &job.key);
                    let _ = job.events.send(JobEvent::Failed {
                        code: codes::SHUTDOWN,
                        message: "coordinator is shut down".to_string(),
                    });
                }
                Msg::Metrics(reply) => {
                    let _ = reply.send(self.metrics.clone());
                }
                Msg::Crash(_) | Msg::Shutdown => {}
            }
        }
    }

    /// Execute one admitted batch under the robustness stack: the stall
    /// watchdog (when the cost model can price a bound), bounded retry of
    /// timeouts and `[transient]`-marked faults under capped backoff, and
    /// breaker accounting — then the usual complete/fail/isolate routing.
    ///
    /// Retry parity: each attempt re-runs the identical pure evaluation
    /// (per-lane seeds are re-derived inside the solver, no RNG state
    /// crosses attempts), so a retried-then-succeeded batch is
    /// bit-identical to a never-faulted one — pinned by the chaos suite.
    fn dispatch_batch(
        &mut self,
        shared: &Shared,
        proto: &SamplingSpec,
        lanes: Vec<batcher::Lane>,
        progress_txs: Vec<Sender<JobEvent>>,
    ) {
        // Clamped so pathological test configs cannot trip the Backoff
        // constructor's invariants.
        let initial = self.cfg.health.backoff_initial.max(Duration::from_micros(1));
        let mut backoff = Backoff::new(initial, self.cfg.health.backoff_cap.max(initial));
        let mut attempt = 0u32;
        loop {
            let t0 = Instant::now();
            match self.attempt_batch(proto, &lanes, &progress_txs) {
                Attempt::Done(result) => {
                    let wall_ms = t0.elapsed().as_secs_f64() * 1e3;
                    self.health.on_success(wall_ms);
                    // The batch's critical path is its longest lane.
                    self.cost
                        .observe(wall_ms, result.nfe.iter().copied().max().unwrap_or(0));
                    self.complete_lanes(shared, &lanes, result);
                    return;
                }
                Attempt::Failed(err) => {
                    // Backend execution errors are request-shaped (bad
                    // schedule for the fused path, poisoned artifact):
                    // fail typed without feeding the breaker, so a stream
                    // of unservable requests cannot open it against
                    // healthy ones.
                    self.fail_requests(
                        shared,
                        &lanes,
                        codes::BATCH_FAILED,
                        format!("batch execution failed: {err:#}"),
                    );
                    return;
                }
                Attempt::Panicked(payload) => {
                    if !health::is_transient(payload.as_ref()) {
                        // A lane bug, not backend sickness: isolate as
                        // before (solo re-runs; the culprit fails
                        // `lane_failed`, siblings complete).
                        let msg = supervise::panic_message(payload.as_ref());
                        self.isolate_lanes(shared, proto, lanes, &msg);
                        return;
                    }
                    // Transient: retry below.
                }
                Attempt::TimedOut => {
                    self.metrics.eval_timeouts += 1;
                }
            }
            // A timed-out or transient attempt: retry within the budget.
            if attempt >= self.cfg.health.retry_budget {
                self.health.on_failure();
                self.metrics.backend_unavailable += 1;
                self.fail_requests(
                    shared,
                    &lanes,
                    codes::BACKEND_UNAVAILABLE,
                    format!(
                        "score backend unavailable: eval retries exhausted \
                         ({} attempts)",
                        attempt + 1
                    ),
                );
                return;
            }
            attempt += 1;
            self.metrics.retries += 1;
            std::thread::sleep(backoff.next_delay());
        }
    }

    /// One dispatch attempt.  Scored batches ship to the watchdog worker
    /// (bounded by `recv_timeout` when the cost model is warm); on expiry
    /// the worker is *abandoned* — dropping its job channel lets the
    /// stalled thread exit once it wakes — and the next attempt respawns
    /// a fresh one, so a stalled eval never blocks the loop thread.  The
    /// legacy fused path (and the fallback when the OS refuses a worker
    /// thread) runs inline, exactly the historical behavior, but is still
    /// classified so transient faults retry even there.
    fn attempt_batch(
        &mut self,
        proto: &SamplingSpec,
        lanes: &[batcher::Lane],
        progress_txs: &[Sender<JobEvent>],
    ) -> Attempt {
        if self.cfg.health.watchdog {
            let job = match scored_job(&mut self.backend, proto) {
                Ok(Some(job)) => Some(job),
                Ok(None) => None,
                Err(err) => return Attempt::Failed(err),
            };
            if let Some(job) = job {
                if self.worker.is_none() {
                    self.worker = DispatchWorker::spawn();
                }
                if let Some(worker) = &self.worker {
                    let timeout = proto
                        .planned_nfe()
                        .and_then(|nfe| {
                            self.cfg.health.eval_timeout(self.cost.estimate_ms(nfe))
                        });
                    let work =
                        make_work(job, proto.clone(), lanes.to_vec(), progress_txs.to_vec());
                    return match worker.dispatch(work, timeout) {
                        WorkerReply::Done(Ok(Ok(result))) => Attempt::Done(result),
                        WorkerReply::Done(Ok(Err(err))) => Attempt::Failed(err),
                        WorkerReply::Done(Err(payload)) => Attempt::Panicked(payload),
                        WorkerReply::TimedOut => {
                            self.worker = None;
                            Attempt::TimedOut
                        }
                        WorkerReply::Dead => {
                            self.worker = None;
                            Attempt::Failed(anyhow!("dispatch worker died"))
                        }
                    };
                }
                // The OS refused the worker thread: dispatch inline
                // (unwatched) rather than failing the batch.
            }
        }
        let mut obs_fn;
        let obs: Option<&mut dyn FnMut(crate::solvers::driver::Progress)> =
            if progress_txs.is_empty() {
                None
            } else {
                obs_fn = |p: crate::solvers::driver::Progress| {
                    for tx in progress_txs {
                        let _ = tx.send(JobEvent::Progress {
                            done: p.done,
                            total: p.total,
                            phase: p.phase,
                        });
                    }
                };
                Some(&mut obs_fn)
            };
        match catch_unwind(AssertUnwindSafe(|| {
            execute_batch(&mut self.backend, proto, lanes, obs)
        })) {
            Ok(Ok(result)) => Attempt::Done(result),
            Ok(Err(err)) => Attempt::Failed(err),
            Err(payload) => Attempt::Panicked(payload),
        }
    }

    /// Intake: deadline feasibility, then capacity (with priority-aware
    /// shedding), then bookkeeping.  Rejections are typed and remove the
    /// registry entry the submitter just created.
    fn admit(&mut self, shared: &Shared, mut job: Job) {
        self.metrics.requests += 1;
        // Brownout: under sustained pressure — or any non-closed breaker —
        // walk the spec down the pre-declared degradation ladder
        // ([`SamplingSpec::degrade`]) instead of (eventually) shedding it.
        // Runs before feasibility so a degraded (cheaper) plan is the one
        // priced against the deadline.  `no_degrade` specs are never
        // touched: they take their chances with the capacity loop below
        // and shed typed.  The rungs engage strictly below the shed
        // threshold (utilization 1.0), so brownout degrades what shedding
        // would otherwise kill.
        let mut degraded_rung = None;
        if self.cfg.health.brownout && !job.spec.no_degrade() {
            let rung = if self.health.is_degraded() {
                crate::api::spec::MAX_DEGRADE_RUNG
            } else {
                let n = job.spec.n_samples();
                let queue_u = self
                    .cfg
                    .queue_cap
                    .map(|q| (self.batcher.pending() + n) as f64 / q.max(1) as f64)
                    .unwrap_or(0.0);
                let inflight_u = self
                    .cfg
                    .max_inflight
                    .map(|m| self.assembler.in_flight() as f64 / m.max(1) as f64)
                    .unwrap_or(0.0);
                let u = queue_u.max(inflight_u);
                if u >= 0.875 {
                    3
                } else if u >= 0.625 {
                    2
                } else if u >= 0.375 {
                    1
                } else {
                    0
                }
            };
            if rung > 0 {
                if let Some((degraded, applied)) = job.spec.degrade(rung) {
                    job.spec = degraded;
                    degraded_rung = Some(applied);
                }
            }
        }
        // Deadline feasibility: the resolved plan's NFE (the spec's own
        // cost model) times the learned ms/NFE rate.  Plans with unbounded
        // NFE (uncapped exact) and cold cost models are never rejected.
        if let (Some(deadline), Some(nfe)) =
            (job.spec.deadline_ms(), job.spec.planned_nfe())
        {
            let est = self.cost.estimate_ms(nfe);
            if est > deadline as f64 {
                self.metrics.deadline_rejects += 1;
                lock_cancels(shared).remove(&job.id);
                release_key(shared, &job.key);
                let _ = job.events.send(JobEvent::Failed {
                    code: codes::DEADLINE_INFEASIBLE,
                    message: format!(
                        "deadline {deadline}ms infeasible: the plan needs {nfe} \
                         evaluations (~{est:.1}ms at the current rate)"
                    ),
                });
                return;
            }
        }
        // Capacity: shed strictly-lower-priority untouched work to make
        // room; if none exists, the arriving request is the one shed.
        let n = job.spec.n_samples();
        loop {
            let over_inflight = self
                .cfg
                .max_inflight
                .is_some_and(|m| self.assembler.in_flight() >= m);
            let over_queue =
                self.cfg.queue_cap.is_some_and(|q| self.batcher.pending() + n > q);
            if !over_inflight && !over_queue {
                break;
            }
            if !self.shed_one_below(shared, job.spec.priority()) {
                self.metrics.sheds += 1;
                lock_cancels(shared).remove(&job.id);
                release_key(shared, &job.key);
                let _ = job.events.send(JobEvent::Failed {
                    code: codes::OVERLOADED,
                    message: "coordinator overloaded: queue and in-flight caps reached"
                        .to_string(),
                });
                return;
            }
        }
        self.metrics.lanes += n as u64;
        // Ledger the rung only now: a degraded-then-shed request is a
        // shed, not a degraded admission.
        match degraded_rung {
            None => {}
            Some(1) => self.metrics.degraded_rung1 += 1,
            Some(2) => self.metrics.degraded_rung2 += 1,
            Some(_) => self.metrics.degraded_rung3 += 1,
        }
        let now = self.now_ms();
        self.assembler.register(job.id, n, now);
        let priority = job.spec.priority();
        let progress = job.spec.progress();
        self.jobs.insert(
            job.id,
            Sink {
                events: job.events,
                stream: job.stream,
                priority,
                progress,
                key: job.key,
                degraded: degraded_rung,
            },
        );
        self.batcher.enqueue(GenerateRequest::new(job.id, job.spec), job.cancel);
    }

    /// Evict one untouched (no completed lanes), strictly-lower-priority
    /// in-flight request — lowest priority first, newest among ties.
    /// Returns whether a victim was found.
    fn shed_one_below(&mut self, shared: &Shared, incoming: u8) -> bool {
        let victim = self
            .jobs
            .iter()
            .filter(|(id, s)| s.priority < incoming && self.assembler.untouched(**id))
            .min_by_key(|(id, s)| (s.priority, u64::MAX - **id))
            .map(|(id, _)| *id);
        let Some(id) = victim else { return false };
        self.metrics.sheds += 1;
        self.assembler.abort(id);
        self.batcher.purge_request(id);
        finish_job(
            &mut self.jobs,
            shared,
            id,
            JobEvent::Failed {
                code: codes::OVERLOADED,
                message: "shed at admission: displaced by higher-priority work"
                    .to_string(),
            },
        );
        true
    }

    /// Route one successful batch result: stream lane chunks, assemble
    /// responses, account deadline expiries.
    fn complete_lanes(
        &mut self,
        shared: &Shared,
        lanes: &[batcher::Lane],
        result: scheduler::BatchResult,
    ) {
        self.metrics.nfe_total += result.nfe.iter().sum::<usize>() as u64;
        self.metrics.pit_sweeps += result.pit_sweeps;
        self.metrics.pit_converged_lanes += result.pit_converged;
        self.metrics.pit_sweep_limit_hits += result.pit_sweep_limit;
        let scheduler::BatchResult { tokens, nfe, partial, .. } = result;
        let now = self.now_ms();
        for (idx, (lane, toks)) in lanes.iter().zip(tokens.into_iter()).enumerate() {
            let lane_nfe = nfe[idx];
            let lane_partial = partial[idx];
            if let Some(sink) = self.jobs.get(&lane.request_id) {
                if sink.stream {
                    let _ = sink.events.send(JobEvent::Lane {
                        sample_idx: lane.sample_idx,
                        tokens: toks.clone(),
                        nfe: lane_nfe,
                        partial: lane_partial,
                    });
                }
            }
            if let Some(mut resp) = self.assembler.complete_lane(
                lane.request_id,
                lane.sample_idx,
                toks,
                lane_nfe,
                lane_partial,
                now,
            ) {
                // Patch in the brownout echo before the response leaves
                // the loop (the rung lives on the sink, not lane state).
                resp.degraded =
                    self.jobs.get(&resp.id).and_then(|sink| sink.degraded);
                // Partial because the deadline passed (and nobody fired an
                // explicit cancel) = a deadline expiry, not an error.
                if resp.partial && lane.cancel.deadline_expired() && !lane.cancel.fired()
                {
                    self.metrics.deadline_expiries += 1;
                }
                self.metrics.latency_ms.push(resp.latency_ms);
                finish_job(&mut self.jobs, shared, resp.id, JobEvent::Done(resp));
            }
        }
    }

    /// Fail every request with a lane in `lanes` — and clean each up
    /// fully: discard its assembler state (a leaked Pending entry would
    /// grow the long-lived coordinator on every failing request), purge
    /// its still-queued lanes (they would execute into a request that no
    /// longer exists), and drop its registry entry.
    fn fail_requests(
        &mut self,
        shared: &Shared,
        lanes: &[batcher::Lane],
        code: &'static str,
        message: String,
    ) {
        let mut failed: Vec<u64> = lanes.iter().map(|l| l.request_id).collect();
        failed.sort_unstable();
        failed.dedup();
        for id in failed {
            self.assembler.abort(id);
            self.batcher.purge_request(id);
            finish_job(
                &mut self.jobs,
                shared,
                id,
                JobEvent::Failed { code, message: message.clone() },
            );
        }
    }

    /// Blast-radius containment after a panic inside `execute_batch`:
    /// rerun each lane alone (also caught).  The panicking lane's request
    /// fails `lane_failed`; sibling lanes complete — bit-identical to the
    /// uninjected batch for fixed-grid and exact plans (per-lane seeded
    /// streams; PR 1's batch-invariance).  Adaptive siblings re-run under
    /// a solo dt vote, the documented trade-off of shared online control.
    fn isolate_lanes(
        &mut self,
        shared: &Shared,
        proto: &SamplingSpec,
        lanes: Vec<batcher::Lane>,
        batch_panic: &str,
    ) {
        if lanes.len() == 1 {
            self.metrics.lane_failures += 1;
            let message = format!(
                "lane {} panicked during dispatch: {batch_panic}",
                lanes[0].sample_idx
            );
            self.fail_requests(shared, &lanes, codes::LANE_FAILED, message);
            return;
        }
        let mut failed_requests: BTreeSet<u64> = BTreeSet::new();
        for lane in lanes {
            if failed_requests.contains(&lane.request_id) {
                continue;
            }
            // Solo re-runs skip the progress sink: a fault-isolation pass
            // replays work the stream already heartbeat through once.
            let solo = catch_unwind(AssertUnwindSafe(|| {
                execute_batch(&mut self.backend, proto, std::slice::from_ref(&lane), None)
            }));
            match solo {
                Ok(Ok(result)) => {
                    self.complete_lanes(shared, std::slice::from_ref(&lane), result);
                }
                Ok(Err(err)) => {
                    failed_requests.insert(lane.request_id);
                    self.fail_requests(
                        shared,
                        std::slice::from_ref(&lane),
                        codes::BATCH_FAILED,
                        format!("batch execution failed: {err:#}"),
                    );
                }
                Err(payload) => {
                    failed_requests.insert(lane.request_id);
                    self.metrics.lane_failures += 1;
                    let msg = supervise::panic_message(payload.as_ref());
                    self.fail_requests(
                        shared,
                        std::slice::from_ref(&lane),
                        codes::LANE_FAILED,
                        format!(
                            "lane {} panicked during dispatch: {msg}",
                            lane.sample_idx
                        ),
                    );
                }
            }
        }
    }

    /// Post-crash cleanup (the supervisor calls this between restarts):
    /// every in-flight job fails `coordinator_restarted`, its registry
    /// entry is cleared, and batching state is rebuilt fresh.  Metrics
    /// (including the restart counter) and the backend survive.
    fn recover(&mut self, shared: &Shared, panic_msg: &str) {
        let jobs = std::mem::take(&mut self.jobs);
        let mut cancels = lock_cancels(shared);
        for (id, sink) in jobs {
            cancels.remove(&id);
            release_key(shared, &sink.key);
            let _ = sink.events.send(JobEvent::Failed {
                code: codes::COORDINATOR_RESTARTED,
                message: format!(
                    "coordinator restarted after a scheduler-loop crash: {panic_msg}"
                ),
            });
        }
        drop(cancels);
        self.batcher = DynamicBatcher::new(self.policy, self.max_lanes);
        self.assembler = ResponseAssembler::new();
        // Drop any worker too: a loop crash mid-dispatch may have left it
        // holding an eval nobody is waiting on; the next watched dispatch
        // respawns a fresh one.
        self.worker = None;
    }
}

/// Run the scheduler loop under a supervisor: a panic anywhere inside is
/// caught, in-flight jobs fail typed ([`LoopState::recover`]), and the
/// loop re-enters under capped exponential backoff ([`Backoff`]) — reset
/// once a restart proves healthy (a dispatch completed since the previous
/// crash).
fn supervised_loop(
    backend: Backend,
    policy: BatchPolicy,
    max_lanes: usize,
    cfg: CoordinatorCfg,
    rx: Receiver<Msg>,
    shared: Arc<Shared>,
) {
    let mut state = LoopState {
        backend,
        policy,
        max_lanes,
        cfg,
        batcher: DynamicBatcher::new(policy, max_lanes),
        assembler: ResponseAssembler::new(),
        jobs: BTreeMap::new(),
        metrics: Metrics::new(),
        cost: CostModel::new(),
        health: HealthTracker::new(cfg.health),
        worker: None,
        started: Instant::now(),
        open: true,
    };
    let mut backoff = Backoff::default();
    let mut last_dispatches = 0u64;
    loop {
        match catch_unwind(AssertUnwindSafe(|| state.run(&rx, &shared))) {
            Ok(()) => return,
            Err(payload) => {
                if state.metrics.dispatches > last_dispatches {
                    backoff.reset();
                }
                last_dispatches = state.metrics.dispatches;
                state.metrics.supervisor_restarts += 1;
                state.recover(&shared, &supervise::panic_message(payload.as_ref()));
                std::thread::sleep(backoff.next_delay());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::hmm::HmmUniformOracle;
    use crate::score::markov::{MarkovChain, MarkovOracle};
    use crate::solvers::{grid, masked, Solver};
    use crate::util::rng::Xoshiro256;

    fn coordinator(policy: BatchPolicy) -> Option<Coordinator> {
        if !crate::runtime::artifacts_available("artifacts") {
            return None;
        }
        let runtime = RuntimeHandle::spawn("artifacts").unwrap();
        let registry = Registry::load("artifacts").unwrap();
        Some(Coordinator::start(runtime, registry, policy))
    }

    fn local_oracle(vocab: usize, seq_len: usize) -> Arc<MarkovOracle> {
        let mut rng = Xoshiro256::seed_from_u64(23);
        Arc::new(MarkovOracle::new(
            MarkovChain::generate(&mut rng, vocab, 0.5),
            seq_len,
        ))
    }

    fn req(id: u64, solver: Solver, nfe: usize, n: usize, seed: u64) -> GenerateRequest {
        GenerateRequest::new(
            id,
            SamplingSpec::builder()
                .solver(solver)
                .nfe(nfe)
                .n_samples(n)
                .seed(seed)
                .build()
                .unwrap(),
        )
    }

    #[test]
    fn local_backend_serves_adaptive_and_tuned_schedules() {
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let solver = Solver::Trapezoidal { theta: 0.5 };

        // Adaptive with a hard budget: all lanes finish, nobody overdraws.
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(64)
            .n_samples(3)
            .seed(7)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .nfe_budget(Some(24))
            .build()
            .unwrap();
        let resp = c.generate_spec(spec).unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        assert!(resp.nfe_used <= 24, "budget exceeded: {}", resp.nfe_used);
        assert!(!resp.partial);

        // Tuned: fit-on-first-use, then cache hit; deterministic replay.
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .n_samples(2)
            .seed(9)
            .schedule(ScheduleSpec::Tuned { steps: 8 })
            .build()
            .unwrap();
        let a = c.generate_spec(spec.clone()).unwrap();
        let b = c.generate_spec(spec).unwrap();
        assert_eq!(a.sequences, b.sequences, "tuned grid must be cached + reused");

        // Log schedule still serves.
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .seed(1)
            .schedule(ScheduleSpec::Log)
            .build()
            .unwrap();
        let resp = c.generate_spec(spec).unwrap();
        assert!(resp.sequences[0].iter().all(|&t| t < 6));
        c.shutdown();
    }

    #[test]
    fn local_backend_serves_exact_solver() {
        // Solver::Exact dispatches through batcher -> scheduler like any
        // approximate scheme; nfe_used echoes the realized jump count.
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let resp = c.generate(req(1, Solver::Exact, 16, 3, 11)).unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 20);
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        // Realized NFE: <= one eval per dim + one finalize, independent of
        // the requested planning budget.
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 21, "nfe={}", resp.nfe_used);

        // Same seed -> identical samples (per-lane seeded fhs streams).
        let again = c.generate(req(2, Solver::Exact, 16, 3, 11)).unwrap();
        assert_eq!(again.sequences, resp.sequences);
        c.shutdown();
    }

    #[test]
    fn local_backend_persists_tuned_schedules_across_restart() {
        let dir = std::env::temp_dir().join(format!(
            "fastdds_coord_sched_{}",
            std::process::id()
        ));
        let dir = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);
        let solver = Solver::Trapezoidal { theta: 0.5 };

        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .n_samples(2)
            .seed(9)
            .schedule(ScheduleSpec::Tuned { steps: 8 })
            .build()
            .unwrap();
        let first = {
            let oracle = local_oracle(6, 20);
            let c = Coordinator::start_local_with_schedule_dir(
                oracle,
                BatchPolicy::Greedy,
                8,
                Some(&dir),
            );
            let resp = c.generate_spec(spec.clone()).unwrap();
            c.shutdown();
            resp.sequences
        };
        // The fit must have been flushed to disk.
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().flatten().collect();
        assert!(!files.is_empty(), "tuned schedule not flushed to {dir:?}");

        // Restarted coordinator (same oracle construction): the reloaded
        // grid reproduces the samples exactly.
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local_with_schedule_dir(
            oracle,
            BatchPolicy::Greedy,
            8,
            Some(&dir),
        );
        let resp = c.generate_spec(spec).unwrap();
        assert_eq!(resp.sequences, first, "reloaded tuned grid must replay");
        c.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn end_to_end_generation() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        let resp = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 3, 7))
            .unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 32);
            assert!(s.iter().all(|&t| t < 16), "masks left: {s:?}");
        }
        // Sparse skipping lets a lane finish under budget; finalize adds at
        // most one evaluation on top.
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 34, "nfe={}", resp.nfe_used);
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 3);
        c.shutdown();
    }

    #[test]
    fn local_backend_serves_without_artifacts() {
        let oracle = local_oracle(6, 24);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let resp = c
            .generate(req(1, Solver::Trapezoidal { theta: 0.5 }, 32, 3, 7))
            .unwrap();
        assert_eq!(resp.sequences.len(), 3);
        for s in &resp.sequences {
            assert_eq!(s.len(), 24);
            assert!(s.iter().all(|&t| t < 6), "masks left: {s:?}");
        }
        assert!(resp.nfe_used >= 1 && resp.nfe_used <= 33);
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.lanes, 3);
        assert_eq!(m.dispatches, 1, "3 lanes must co-batch in one dispatch");
        c.shutdown();
    }

    #[test]
    fn local_backend_batches_are_lane_reproducible() {
        // The whole stack — batcher lane seeding, run_batch_scored,
        // generate_batch — must produce exactly what a single-lane
        // masked::generate with the derived lane seed produces.
        let oracle = local_oracle(5, 16);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let solver = Solver::TauLeaping;
        let (nfe, n, seed) = (16usize, 4usize, 99u64);
        let resp = c.generate(req(1, solver, nfe, n, seed)).unwrap();
        assert_eq!(resp.sequences.len(), n);
        let grid_ts = grid::masked_uniform(solver.steps_for_nfe(nfe), scheduler::DELTA);
        for (idx, seq) in resp.sequences.iter().enumerate() {
            let lane_seed =
                seed.wrapping_add((idx as u64).wrapping_mul(0x9E3779B97F4A7C15));
            let mut rng = Xoshiro256::seed_from_u64(lane_seed);
            let (want, _) = masked::generate(oracle.as_ref(), solver, &grid_ts, &mut rng);
            assert_eq!(seq, &want, "lane {idx}");
        }
        // Same request again: identical samples even with different
        // co-batching partners in flight.
        let again = c.generate(req(2, solver, nfe, n, seed)).unwrap();
        assert_eq!(again.sequences, resp.sequences);
        c.shutdown();
    }

    #[test]
    fn streaming_job_chunks_concatenate_to_blocking_response() {
        // n_samples > max_lanes forces multiple dispatches: the streamed
        // per-lane chunks, placed by sample index, must equal the blocking
        // response for the same spec + seed bit for bit.
        let oracle = local_oracle(5, 12);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 2);
        let spec = SamplingSpec::builder()
            .solver(Solver::TauLeaping)
            .nfe(16)
            .n_samples(5)
            .seed(42)
            .build()
            .unwrap();
        let blocking = c.generate_spec(spec.clone()).unwrap();

        let job = c.submit_stream(spec);
        let mut chunks: Vec<Option<Vec<Tok>>> = vec![None; 5];
        let mut n_chunks = 0usize;
        let done = loop {
            match job.recv().unwrap() {
                JobEvent::Lane { sample_idx, tokens, partial, .. } => {
                    assert!(!partial);
                    assert!(chunks[sample_idx].replace(tokens).is_none(), "dup lane");
                    n_chunks += 1;
                }
                JobEvent::Progress { .. } => {
                    panic!("progress frames require opt-in")
                }
                JobEvent::Done(resp) => break resp,
                JobEvent::Failed { message, .. } => panic!("{message}"),
            }
        };
        assert_eq!(n_chunks, 5, "every lane must stream exactly once");
        let assembled: Vec<Vec<Tok>> = chunks.into_iter().map(Option::unwrap).collect();
        assert_eq!(assembled, blocking.sequences, "chunks must concatenate bitwise");
        assert_eq!(done.sequences, blocking.sequences);
        assert_eq!(done.nfe_used, blocking.nfe_used);
        c.shutdown();
    }

    #[test]
    fn cancel_interrupts_long_exact_job_with_partial_result() {
        // A large HMM exact job is the unbounded workload cancellation is
        // for: fire the token mid-run and require a prompt partial Done.
        let mut rng = Xoshiro256::seed_from_u64(29);
        let chain = MarkovChain::generate(&mut rng, 6, 0.6);
        let oracle = Arc::new(HmmUniformOracle::new(chain, 48));
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 4);
        let spec = SamplingSpec::builder()
            .solver(Solver::Exact)
            .n_samples(2)
            .seed(3)
            .build()
            .unwrap();
        let job = c.submit_stream(spec);
        let id = job.id;
        // Cancel from "another thread" (the handle's token IS the registry
        // entry, but go through the coordinator API like the server does).
        assert!(c.cancel(id), "in-flight job must be found");
        let resp = job.wait().unwrap();
        assert!(resp.partial, "cancelled run must be partial");
        assert_eq!(resp.sequences.len(), 2);
        // Completed job: the registry entry is gone.
        assert!(!c.cancel(id), "completed job must be unknown to cancel");
        c.shutdown();
    }

    #[test]
    fn pit_jobs_stream_progress_and_count_metrics() {
        let oracle = local_oracle(6, 16);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .n_samples(2)
            .seed(7)
            .pit(true)
            .progress(true)
            .build()
            .unwrap();
        let job = c.submit_stream(spec);
        let mut beats = 0usize;
        let mut lanes_seen = 0usize;
        let resp = loop {
            match job.recv().unwrap() {
                JobEvent::Progress { done, total, phase } => {
                    assert_eq!(phase, "sweep");
                    assert!(done >= 1 && done <= total, "done={done} total={total}");
                    beats += 1;
                }
                JobEvent::Lane { .. } => lanes_seen += 1,
                JobEvent::Done(resp) => break resp,
                JobEvent::Failed { message, .. } => panic!("{message}"),
            }
        };
        assert!(beats >= 1, "a PIT job must heartbeat at least one sweep");
        assert_eq!(lanes_seen, 2);
        assert!(!resp.partial, "tol=0 PIT must converge exactly");

        // tol=0 convergence ⇒ bit-identical to the sequential driver.
        let seq = c
            .generate(req(91, solver, 16, 2, 7))
            .unwrap();
        assert_eq!(resp.sequences, seq.sequences, "PIT fixed point must match");

        // Blocking jobs never opt in: wait() sees no Progress frames
        // (progress is streamed-only QoS), and metrics count the sweeps.
        let m = c.metrics();
        assert!(m.pit_sweeps >= 2, "pit_sweeps={}", m.pit_sweeps);
        assert_eq!(m.pit_converged_lanes, 2);
        assert_eq!(m.pit_sweep_limit_hits, 0);
        c.shutdown();
    }

    #[test]
    fn request_keys_dedupe_in_flight_jobs() {
        // A long unbounded exact HMM job (the cancellation workload) keeps
        // the key claimed while we probe the duplicate path.
        let mut rng = Xoshiro256::seed_from_u64(29);
        let chain = MarkovChain::generate(&mut rng, 6, 0.6);
        let oracle = Arc::new(HmmUniformOracle::new(chain, 48));
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 4);
        let spec = SamplingSpec::builder()
            .solver(Solver::Exact)
            .n_samples(2)
            .seed(3)
            .build()
            .unwrap();
        let job = c.submit_stream_keyed(spec.clone(), Some("job-a".to_string()));
        let original_id = job.id;

        // Same key while in flight: typed duplicate echoing the claimant.
        let dup = c.submit_spec_keyed(spec.clone(), Some("job-a".to_string()));
        let err = dup.wait().expect_err("duplicate key must fail");
        let job_err = err
            .downcast_ref::<JobError>()
            .expect("failure must carry a typed JobError");
        assert_eq!(job_err.code, codes::DUPLICATE_REQUEST);
        assert!(
            job_err.message.contains(&format!("job {original_id}")),
            "message must echo the original id: {}",
            job_err.message
        );

        // A different key is admitted (and cancelled right away to keep
        // the test fast); the duplicate rejection burned no registry slot.
        let other = c.submit_stream_keyed(spec.clone(), Some("job-b".to_string()));
        c.cancel(other.id);
        assert!(other.wait().unwrap().partial);

        // Finish the claimant; its key must be immediately reusable.
        c.cancel(original_id);
        assert!(job.wait().unwrap().partial);
        let reuse = c.submit_stream_keyed(spec, Some("job-a".to_string()));
        c.cancel(reuse.id);
        assert!(reuse.wait().is_ok(), "a finished key must be reusable");
        let m = c.metrics();
        assert_eq!(m.registry_entries, 0, "keys/cancels must drain");
        c.shutdown();
    }

    #[test]
    fn max_events_caps_exact_runs() {
        let oracle = local_oracle(6, 20);
        let c = Coordinator::start_local(oracle.clone(), BatchPolicy::Greedy, 8);
        let spec = SamplingSpec::builder()
            .solver(Solver::Exact)
            .n_samples(2)
            .seed(5)
            .max_events(Some(4))
            .build()
            .unwrap();
        let resp = c.generate_spec(spec).unwrap();
        assert!(resp.partial, "20 dims cannot finish in 4 events");
        for s in &resp.sequences {
            let masked = s.iter().filter(|&&t| t == oracle.mask_id()).count();
            assert!(masked >= 16, "at most 4 positions may reveal, {masked} masks");
        }
        c.shutdown();
    }

    #[test]
    fn concurrent_requests_batched_and_reproducible() {
        let Some(c) = coordinator(BatchPolicy::Greedy) else { return };
        // Same seed/solver twice -> identical sequences even when batched
        // with different partners.
        let h1 = c.submit(req(1, Solver::TauLeaping, 16, 2, 99));
        let h2 = c.submit(req(2, Solver::TauLeaping, 16, 4, 55));
        let h3 = c.submit(req(3, Solver::Euler, 16, 1, 1));
        let r1 = h1.wait().unwrap();
        let r2 = h2.wait().unwrap();
        let r3 = h3.wait().unwrap();
        assert_eq!(r1.sequences.len(), 2);
        assert_eq!(r2.sequences.len(), 4);
        assert_eq!(r3.sequences.len(), 1);

        let r1b = c.generate(req(9, Solver::TauLeaping, 16, 2, 99)).unwrap();
        assert_eq!(r1.sequences, r1b.sequences, "seeded lanes must be batch-invariant");
        c.shutdown();
    }

    #[test]
    fn far_future_deadline_does_not_perturb_sampling() {
        // Arming the deadline token must not consume RNG or change the
        // step sequence: a run with a far-future deadline is bit-identical
        // to the same spec without one (also pinned by the golden suite).
        let oracle = local_oracle(6, 16);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 8);
        let base = SamplingSpec::builder()
            .solver(Solver::Trapezoidal { theta: 0.5 })
            .nfe(32)
            .n_samples(3)
            .seed(17)
            .build()
            .unwrap();
        let qos = SamplingSpec::builder()
            .solver(Solver::Trapezoidal { theta: 0.5 })
            .nfe(32)
            .n_samples(3)
            .seed(17)
            .deadline_ms(Some(600_000))
            .priority(crate::api::spec::MAX_PRIORITY)
            .build()
            .unwrap();
        let a = c.generate_spec(base).unwrap();
        let b = c.generate_spec(qos).unwrap();
        assert_eq!(a.sequences, b.sequences, "deadline token must be free");
        assert!(!b.partial, "a 10-minute deadline cannot expire here");
        c.shutdown();
    }

    #[test]
    fn supervisor_restarts_loop_after_injected_crash() {
        let oracle = local_oracle(5, 12);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 4);
        let spec = SamplingSpec::builder()
            .solver(Solver::TauLeaping)
            .nfe(16)
            .n_samples(2)
            .seed(31)
            .build()
            .unwrap();
        let before = c.generate_spec(spec.clone()).unwrap();
        // Crash the loop; the same channel then carries the next submit,
        // so FIFO ordering guarantees the crash is processed first.
        c.inject_loop_panic("unit test");
        let after = c.generate_spec(spec).unwrap();
        assert_eq!(
            after.sequences, before.sequences,
            "the restarted loop must serve identically"
        );
        let m = c.metrics();
        assert_eq!(m.supervisor_restarts, 1);
        assert_eq!(m.in_flight, 0, "no request may survive the crash");
        assert_eq!(m.registry_entries, 0, "crash must not leak cancel entries");
        c.shutdown();
    }

    #[test]
    fn submit_after_shutdown_fails_typed() {
        let oracle = local_oracle(5, 8);
        let c = Coordinator::start_local(oracle, BatchPolicy::Greedy, 4);
        c.shutdown();
        let spec = SamplingSpec::builder()
            .solver(Solver::Euler)
            .nfe(8)
            .seed(1)
            .build()
            .unwrap();
        // Submissions racing the drain may still be served; once the loop
        // thread exits, every later submit must fail typed — never panic
        // or hang the submitter.
        for attempt in 0..200 {
            match c.generate_spec(spec.clone()) {
                Ok(_) => std::thread::sleep(Duration::from_millis(1)),
                Err(err) => {
                    let job_err = err
                        .downcast_ref::<JobError>()
                        .expect("failure must carry a typed JobError");
                    assert_eq!(job_err.code, codes::SHUTDOWN);
                    return;
                }
            }
            assert!(attempt < 199, "coordinator never shut down");
        }
    }

    #[test]
    fn timeout_policy_improves_occupancy() {
        let Some(c) = coordinator(BatchPolicy::Timeout(Duration::from_millis(30)))
        else {
            return;
        };
        let handles: Vec<_> = (0..4)
            .map(|i| c.submit(req(i, Solver::TauLeaping, 16, 2, i)))
            .collect();
        for h in handles {
            h.wait().unwrap();
        }
        let m = c.metrics();
        // 8 lanes with batch size 8: with the hold-for-timeout policy these
        // should need very few dispatches (the exact count depends on
        // arrival timing, so just check it beats one-lane-per-dispatch).
        assert!(m.dispatches <= 4, "dispatches={}", m.dispatches);
        assert!(m.occupancy.mean() > 0.25, "occupancy={}", m.occupancy.mean());
        c.shutdown();
    }
}
