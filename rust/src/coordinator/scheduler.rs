//! Step scheduler: drives one packed batch through its whole backward pass.
//!
//! Two execution paths:
//!
//! - [`run_batch_scored`] — the preferred path: a [`ScoreSource`] (analytic
//!   oracle or the `{family}_score` artifact) plus the pure-rust solver
//!   loop `solvers::masked::generate_batch`, which steps every lane in
//!   lock-step with one batched, masked-sparse score call per stage.
//! - [`run_batch`] — the legacy fused-step-graph path: one PJRT dispatch
//!   per grid step (two-stage solvers are FUSED into a single step graph by
//!   L2, so a trapezoidal step is still one dispatch but counts 2 NFE).
//!   Lanes shorter than the artifact batch are padded with dummy lanes.
//!
//! In both paths each real lane draws from its own seeded stream, so a
//! sample depends only on (request seed, sample index) — not on co-batching.

use anyhow::{bail, Result};

use crate::coordinator::batcher::Lane;
use crate::coordinator::request::GenerateRequest;
use crate::runtime::{ArtifactSpec, Registry, RuntimeHandle, Value};
use crate::schedule::adaptive::{AdaptiveController, NfeBudget, StepController};
use crate::schedule::{ScheduleCache, ScheduleSpec, ScheduleTuner, TuneKey};
use crate::score::{ScoreSource, Tok};
use crate::solvers::{grid, masked, Solver};
use crate::util::rng::{Rng, Xoshiro256};

pub const DELTA: f64 = 1e-3;

/// Upper bound on a client-requested tuned-grid step count (each distinct
/// count triggers one offline tuner fit, so it must stay sane).
pub const MAX_TUNED_STEPS: usize = 512;

/// Result of one batch pass: per-lane token sequences + NFE actually spent
/// per lane (lanes can differ once the sparse path skips empty steps).
pub struct BatchResult {
    pub tokens: Vec<Vec<Tok>>,
    pub nfe: Vec<usize>,
}

/// Validate the client-controlled solver/budget parameters.  These must be
/// rejected with an error, never allowed to reach the solver asserts (a
/// panic here would kill the long-lived coordinator thread).  The
/// coordinator ALSO runs this at request intake, before batching: the
/// batch key does not encode every validated field (non-exact keys zero
/// the knob bits, for instance), so per-batch validation on the proto
/// request alone could reject a valid co-batched request or silently
/// accept an invalid one.
pub(crate) fn validate_request(req: &GenerateRequest) -> Result<()> {
    match req.solver {
        Solver::Trapezoidal { theta } if !(theta > 0.0 && theta < 1.0) => {
            bail!("trapezoidal theta {theta} outside (0, 1) — second-order range of Thm. 5.4");
        }
        // Request surfaces enforce the second-order range of Thm. 5.5
        // (experiment harnesses sweeping θ past 1/2 construct the enum
        // directly and bypass the serving stack).
        Solver::Rk2 { theta } if !(theta > 0.0 && theta <= 0.5) => {
            bail!("rk2 theta {theta} outside (0, 1/2] — second-order range of Thm. 5.5");
        }
        Solver::Exact if req.nfe_budget.is_some() => {
            bail!(
                "exact simulation cannot honor a hard nfe_budget: its NFE is the \
                 realized jump count (use an approximate scheme to cap spend)"
            );
        }
        _ => {}
    }
    // Exact-path knobs: only meaningful for Solver::Exact, and bounded so
    // a client cannot request degenerate windows or an invalid bound.
    if (req.window_ratio.is_some() || req.slack.is_some())
        && !matches!(req.solver, Solver::Exact)
    {
        bail!(
            "window_ratio/slack are exact-simulation knobs; solver {} ignores them",
            req.solver.name()
        );
    }
    if let Some(w) = req.window_ratio {
        if !(w > 0.0 && w < 1.0) {
            bail!("window_ratio {w} outside (0, 1)");
        }
    }
    if let Some(s) = req.slack {
        if !(s.is_finite() && s >= 1.0) {
            bail!("slack {s} must be finite and >= 1 (a thinning bound inflation)");
        }
    }
    if matches!(req.solver, Solver::Exact) {
        // The thinning bound evaluates at the window's small end, but
        // data-consistent positions RISE with t (by up to ~1/window_ratio
        // at small t; see score::hmm::rise_envelope) — slack must cover
        // that rise or the dominating rate is silently invalid.  The
        // margin is the bracket's own drift margin, so the floor and the
        // envelope stay in lock-step.
        let cfg = req.exact_cfg();
        let floor = crate::score::hmm::SUP_DRIFT_MARGIN / cfg.window_ratio;
        if cfg.slack < floor {
            bail!(
                "slack {} too small for window_ratio {}: the thinning bound \
                 needs slack >= {}/window_ratio (= {floor:.2}) to dominate \
                 the in-window intensity rise",
                cfg.slack,
                cfg.window_ratio,
                crate::score::hmm::SUP_DRIFT_MARGIN
            );
        }
    }
    if req.nfe < req.solver.nfe_per_step() {
        bail!("nfe budget {} below one step ({})", req.nfe, req.solver.nfe_per_step());
    }
    if let Some(b) = req.nfe_budget {
        // One full step plus the reserved terminal denoise must fit.
        if b < req.solver.nfe_per_step() + 1 {
            bail!(
                "nfe_budget {b} below one step + terminal denoise ({})",
                req.solver.nfe_per_step() + 1
            );
        }
    }
    if let ScheduleSpec::Tuned { steps } = req.schedule {
        // Client-controlled fit size: each distinct step count is an
        // offline tuner run; keep it bounded.
        if steps > MAX_TUNED_STEPS {
            bail!("tuned steps {steps} above the supported maximum {MAX_TUNED_STEPS}");
        }
        // The tuner's pilot runs are adaptive passes, which need the
        // two-stage estimator — reaching the solver assert from a
        // well-formed request would panic the coordinator thread.
        if req.solver.nfe_per_step() != 2 {
            bail!(
                "tuned schedules are fitted with the two-stage estimator \
                 (θ-trapezoidal or θ-RK-2), got {}",
                req.solver.name()
            );
        }
    }
    if let ScheduleSpec::Adaptive { tol } = req.schedule {
        if req.solver.nfe_per_step() != 2 {
            bail!(
                "adaptive schedules need the embedded two-stage estimator \
                 (θ-trapezoidal or θ-RK-2), got {}",
                req.solver.name()
            );
        }
        if !(tol.is_finite() && tol >= 0.0) {
            bail!("adaptive tol {tol} must be finite and >= 0");
        }
    }
    Ok(())
}

/// Step count for the fixed schedules: the request NFE, additionally capped
/// by the hard budget (one evaluation reserved for the terminal denoise so
/// the cap can never be exceeded).
fn fixed_steps(req: &GenerateRequest) -> usize {
    let nfe = match req.nfe_budget {
        Some(b) => req.nfe.min(b - 1),
        None => req.nfe,
    };
    req.solver.steps_for_nfe(nfe)
}

/// Run one packed batch through the solvers on a score source: one batched
/// masked-sparse score call per stage, per-lane seeded RNG streams.
/// [`Solver::Exact`] runs the per-lane first-hitting sampler (nothing to
/// co-batch — jump times are data-dependent) and reports the realized
/// event count as the lane's NFE.  The
/// request's schedule decides the discretisation: fixed grids (uniform /
/// log / tuned) run [`masked::generate_batch`] and stay bit-identical to
/// serving each lane alone; adaptive runs
/// [`masked::generate_batch_adaptive`], where lanes vote on a shared dt —
/// the realized grid (and therefore the samples) can depend on which
/// same-key lanes were co-batched, the documented trade-off of shared
/// online control (pin the grid with "tuned" when exact replayability
/// across batch compositions is required).  Tuned grids are fitted on
/// first use (a few pilot runs, synchronous on the coordinator thread)
/// and memoised in `cache`.
pub fn run_batch_scored(
    score: &dyn ScoreSource,
    req: &GenerateRequest,
    lanes: &[Lane],
    cache: &mut ScheduleCache,
) -> Result<BatchResult> {
    validate_request(req)?;
    let solver = req.solver;
    let seeds: Vec<u64> = lanes.iter().map(|l| l.seed).collect();

    if matches!(solver, Solver::Exact) {
        // Exact lanes dispatch through the knob-aware path: sources with a
        // native uniform-state process run bracketed uniformization under
        // the request's (window_ratio, slack); others run the window-free
        // first-hitting sampler.  Fixed schedules only reach here (the
        // adaptive/tuned specs were rejected above), and their interior
        // grid points are irrelevant to exact simulation — only the
        // terminal DELTA matters.
        let results = masked::exact_batch(score, DELTA, &req.exact_cfg(), &seeds);
        return Ok(BatchResult {
            nfe: results.iter().map(|(_, s)| s.nfe).collect(),
            tokens: results.into_iter().map(|(t, _)| t).collect(),
        });
    }

    let results = match req.schedule {
        ScheduleSpec::Uniform => {
            let grid_ts = grid::masked_uniform(fixed_steps(req), DELTA);
            masked::generate_batch(score, solver, &grid_ts, &seeds)
        }
        ScheduleSpec::Log => {
            let grid_ts = grid::masked_log(fixed_steps(req), DELTA);
            masked::generate_batch(score, solver, &grid_ts, &seeds)
        }
        ScheduleSpec::Tuned { steps } => {
            let mut steps = if steps == 0 { fixed_steps(req) } else { steps };
            if let Some(b) = req.nfe_budget {
                // Hard cap also binds an explicit step count (one
                // evaluation stays reserved for the terminal denoise).
                steps = steps.min(solver.steps_for_nfe(b - 1));
            }
            let key = TuneKey::new(&req.family, score.vocab(), score.seq_len(), solver, steps);
            let tuned = cache.get_or_fit(key, || {
                // Serving-time fit: cheaper pilots than the offline-bench
                // tuner — this runs inline on the coordinator thread.
                ScheduleTuner { pilots: 2, tol: 1e-3, ..Default::default() }
                    .fit_masked(score, solver, steps, DELTA, &req.family)
            });
            masked::generate_batch(score, solver, &tuned.grid, &seeds)
        }
        ScheduleSpec::Adaptive { tol } => {
            let dt0 = (1.0 - DELTA) / solver.steps_for_nfe(req.nfe) as f64;
            let mut ctl = StepController::new(
                AdaptiveController::for_span(tol, 1.0, DELTA),
                dt0,
            );
            if let Some(b) = req.nfe_budget {
                ctl = ctl.with_budget(NfeBudget {
                    total: b,
                    nfe_per_step: solver.nfe_per_step(),
                    reserve: 1,
                });
            }
            masked::generate_batch_adaptive(score, solver, ctl, DELTA, &seeds).0
        }
    };
    Ok(BatchResult {
        nfe: results.iter().map(|(_, s)| s.nfe).collect(),
        tokens: results.into_iter().map(|(t, _)| t).collect(),
    })
}

/// Which artifact implements a solver step for a family.
pub fn artifact_name(family: &str, solver: Solver) -> String {
    let s = match solver {
        Solver::Euler => "euler",
        Solver::TauLeaping => "tau",
        Solver::Tweedie => "tweedie",
        Solver::Trapezoidal { .. } => "trapezoidal",
        Solver::Rk2 { .. } => "rk2",
        Solver::ParallelDecoding => "parallel",
        // Exact simulation has no fused step graph (its jump times are
        // data-dependent); it is servable only through the score-source
        // paths, so this name can never resolve — by design.
        Solver::Exact => "exact",
    };
    format!("{family}_step_{s}")
}

pub struct StepPlan {
    pub artifact: String,
    pub spec: ArtifactSpec,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub stages: usize,
    pub steps: usize,
}

impl StepPlan {
    pub fn build(registry: &Registry, req: &GenerateRequest) -> Result<StepPlan> {
        let artifact = artifact_name(&req.family, req.solver);
        let spec = registry.get(&artifact)?.clone();
        let batch = spec.batch()?;
        let seq_len = spec
            .seq_len()
            .ok_or_else(|| anyhow::anyhow!("{artifact} has no seq_len"))?;
        let vocab = spec
            .vocab()
            .ok_or_else(|| anyhow::anyhow!("{artifact} has no vocab"))?;
        let stages = if spec.nfe_per_step == 2 { 2 } else { 1 };
        if req.nfe < spec.nfe_per_step {
            bail!("nfe budget {} below one step ({})", req.nfe, spec.nfe_per_step);
        }
        Ok(StepPlan {
            artifact,
            spec: spec.clone(),
            batch,
            seq_len,
            vocab,
            stages,
            steps: req.solver.steps_for_nfe(req.nfe),
        })
    }
}

/// Run the whole backward pass for one packed batch.
pub fn run_batch(
    runtime: &RuntimeHandle,
    plan: &StepPlan,
    solver: Solver,
    lanes: &[Lane],
) -> Result<BatchResult> {
    assert!(lanes.len() <= plan.batch);
    let (b, l, v) = (plan.batch, plan.seq_len, plan.vocab);
    let mask = v as i32;
    let mut tokens = vec![mask; b * l];
    let mut rngs: Vec<Xoshiro256> = lanes
        .iter()
        .map(|lane| Xoshiro256::seed_from_u64(lane.seed))
        .collect();
    // Padding lanes reuse a throwaway stream so shapes stay fixed.
    let mut pad_rng = Xoshiro256::seed_from_u64(0xDEAD_BEEF);

    let grid_ts = grid::masked_uniform(plan.steps, DELTA);
    let mut nfe = 0usize;

    let theta = match solver {
        Solver::Trapezoidal { theta } | Solver::Rk2 { theta } => theta as f32,
        _ => 0.0,
    };

    for (step_idx, w) in grid_ts.windows(2).enumerate() {
        let uniforms = fill_uniforms(plan.stages, b, l, &mut rngs, &mut pad_rng);
        let mut inputs = vec![
            Value::i32(tokens.clone(), vec![b, l]),
            Value::scalar_f32(w[0] as f32),
        ];
        match solver {
            Solver::ParallelDecoding => {
                // arccos schedule (App. D.4): k tokens to reveal this step.
                let n_steps = plan.steps;
                let frac = (step_idx + 1) as f64 / n_steps as f64;
                let target = if step_idx + 1 == n_steps {
                    0
                } else {
                    ((std::f64::consts::FRAC_PI_2 * frac).cos() * l as f64).ceil()
                        as usize
                };
                let masked_now = tokens.iter().filter(|&&x| x == mask).count() / b.max(1);
                let k = masked_now.saturating_sub(target) as i32;
                inputs.push(Value::scalar_i32(k.max(0)));
            }
            Solver::Trapezoidal { .. } | Solver::Rk2 { .. } => {
                inputs.push(Value::scalar_f32(w[1] as f32));
                inputs.push(Value::scalar_f32(theta));
            }
            _ => inputs.push(Value::scalar_f32(w[1] as f32)),
        }
        inputs.push(Value::f32(uniforms, vec![plan.stages, 2, b, l]));
        let out = runtime.execute(&plan.artifact, inputs)?;
        tokens = out[0].as_i32()?.to_vec();
        nfe += plan.spec.nfe_per_step;
    }

    // Terminal denoise of any still-masked dims: one exact (Tweedie) step
    // from DELTA to ~0 — gate probability ~1, destinations from the score.
    if tokens.iter().any(|&x| x == mask) {
        let tw = format!(
            "{}_step_tweedie",
            plan.artifact.split("_step_").next().unwrap()
        );
        let uniforms = fill_uniforms(1, b, l, &mut rngs, &mut pad_rng);
        let out = runtime.execute(
            &tw,
            vec![
                Value::i32(tokens.clone(), vec![b, l]),
                Value::scalar_f32(DELTA as f32),
                Value::scalar_f32((DELTA * 1e-6) as f32),
                Value::f32(uniforms, vec![1, 2, b, l]),
            ],
        )?;
        tokens = out[0].as_i32()?.to_vec();
        nfe += 1;
    }

    let out_tokens = lanes
        .iter()
        .enumerate()
        .map(|(i, _)| {
            tokens[i * l..(i + 1) * l]
                .iter()
                .map(|&x| x as Tok)
                .collect()
        })
        .collect();
    Ok(BatchResult { tokens: out_tokens, nfe: vec![nfe; lanes.len()] })
}

/// Uniforms layout (stages, 2, B, L): lane b owns [.., .., b, ..] across all
/// stages/gates, drawn from its own stream.
fn fill_uniforms(
    stages: usize,
    b: usize,
    l: usize,
    rngs: &mut [Xoshiro256],
    pad_rng: &mut Xoshiro256,
) -> Vec<f32> {
    let mut u = vec![0.0f32; stages * 2 * b * l];
    for lane in 0..b {
        let rng: &mut Xoshiro256 = if lane < rngs.len() {
            &mut rngs[lane]
        } else {
            pad_rng
        };
        for s in 0..stages {
            for g in 0..2 {
                let base = ((s * 2 + g) * b + lane) * l;
                rng.fill_f32(&mut u[base..base + l]);
            }
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(
            artifact_name("markov", Solver::Trapezoidal { theta: 0.5 }),
            "markov_step_trapezoidal"
        );
        assert_eq!(artifact_name("toy", Solver::TauLeaping), "toy_step_tau");
        assert_eq!(
            artifact_name("transformer", Solver::ParallelDecoding),
            "transformer_step_parallel"
        );
    }

    fn scored_req(solver: Solver, nfe: usize) -> GenerateRequest {
        GenerateRequest { solver, nfe, ..Default::default() }
    }

    fn test_lanes(n: usize) -> Vec<Lane> {
        use std::time::Instant;
        (0..n)
            .map(|i| Lane {
                request_id: 1,
                sample_idx: i,
                seed: 1000 + i as u64 * 17,
                enqueued: Instant::now(),
            })
            .collect()
    }

    #[test]
    fn run_batch_scored_matches_single_lane_generation() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(13);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 12);
        let lanes = test_lanes(3);
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let mut cache = ScheduleCache::new();
        let result =
            run_batch_scored(&oracle, &scored_req(solver, 16), &lanes, &mut cache).unwrap();
        assert_eq!(result.tokens.len(), 3);
        assert_eq!(result.nfe.len(), 3);
        let grid_ts = grid::masked_uniform(solver.steps_for_nfe(16), DELTA);
        for (k, lane) in lanes.iter().enumerate() {
            let mut r = Xoshiro256::seed_from_u64(lane.seed);
            let (toks, stats) =
                crate::solvers::masked::generate(&oracle, solver, &grid_ts, &mut r);
            assert_eq!(result.tokens[k], toks, "lane {k}");
            assert_eq!(result.nfe[k], stats.nfe, "lane {k}");
        }
    }

    #[test]
    fn run_batch_scored_adaptive_and_tuned_schedules() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(17);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 10);
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let mut cache = ScheduleCache::new();
        let lanes = test_lanes(2);

        let mut req = scored_req(solver, 32);
        req.schedule = ScheduleSpec::Adaptive { tol: 1e-2 };
        req.nfe_budget = Some(20);
        let result = run_batch_scored(&oracle, &req, &lanes, &mut cache).unwrap();
        for (k, &nfe) in result.nfe.iter().enumerate() {
            assert!(nfe <= 20, "lane {k} overdrew: {nfe}");
            assert!(result.tokens[k].iter().all(|&t| t < 5), "masks left");
        }

        let mut req = scored_req(solver, 16);
        req.schedule = ScheduleSpec::Tuned { steps: 6 };
        let result = run_batch_scored(&oracle, &req, &lanes, &mut cache).unwrap();
        assert_eq!(cache.len(), 1, "tuned grid must be memoised");
        assert!(result.tokens.iter().all(|t| t.iter().all(|&c| c < 5)));
        // Second call hits the cache (still one entry).
        let _ = run_batch_scored(&oracle, &req, &lanes, &mut cache).unwrap();
        assert_eq!(cache.len(), 1);

        // An explicit tuned step count is still bound by the hard budget.
        let mut req = scored_req(solver, 16);
        req.schedule = ScheduleSpec::Tuned { steps: 64 };
        req.nfe_budget = Some(9);
        let result = run_batch_scored(&oracle, &req, &lanes, &mut cache).unwrap();
        for &nfe in &result.nfe {
            assert!(nfe <= 9, "tuned+budget overdrew: {nfe}");
        }
        // ... and an absurd explicit step count is rejected outright.
        let mut req = scored_req(solver, 16);
        req.schedule = ScheduleSpec::Tuned { steps: MAX_TUNED_STEPS + 1 };
        let err = run_batch_scored(&oracle, &req, &[], &mut cache).unwrap_err();
        assert!(format!("{err:#}").contains("tuned steps"), "{err:#}");
    }

    #[test]
    fn run_batch_scored_exact_matches_per_lane_fhs() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(29);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 12);
        let lanes = test_lanes(3);
        let mut cache = ScheduleCache::new();
        let result =
            run_batch_scored(&oracle, &scored_req(Solver::Exact, 16), &lanes, &mut cache)
                .unwrap();
        assert_eq!(result.tokens.len(), 3);
        for (k, lane) in lanes.iter().enumerate() {
            let mut r = Xoshiro256::seed_from_u64(lane.seed);
            let (toks, stats, _) = crate::solvers::masked::fhs_generate(&oracle, DELTA, &mut r);
            assert_eq!(result.tokens[k], toks, "lane {k}");
            assert_eq!(result.nfe[k], stats.nfe, "lane {k} realized NFE");
            // Realized NFE: one eval per unmask event + at most one finalize.
            assert!(result.nfe[k] >= 1 && result.nfe[k] <= 13, "lane {k}");
        }

        // Exact cannot promise a hard budget: clean error, no panic.
        let mut req = scored_req(Solver::Exact, 16);
        req.nfe_budget = Some(10);
        let err = run_batch_scored(&oracle, &req, &[], &mut cache).unwrap_err();
        assert!(format!("{err:#}").contains("exact"), "{err:#}");
        // ... and neither adaptive nor tuned schedules apply to it.
        let mut req = scored_req(Solver::Exact, 16);
        req.schedule = ScheduleSpec::Adaptive { tol: 1e-3 };
        assert!(run_batch_scored(&oracle, &req, &[], &mut cache).is_err());
    }

    #[test]
    fn run_batch_scored_validates_and_threads_exact_knobs() {
        use crate::score::hmm::HmmUniformOracle;
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(41);
        let chain = MarkovChain::generate(&mut rng, 5, 0.6);
        let mut cache = ScheduleCache::new();

        // Knobs on a non-exact solver: clean error.
        let oracle = MarkovOracle::new(chain.clone(), 8);
        let mut req = scored_req(Solver::TauLeaping, 16);
        req.slack = Some(2.0);
        let err = run_batch_scored(&oracle, &req, &[], &mut cache).unwrap_err();
        assert!(format!("{err:#}").contains("exact"), "{err:#}");
        // Out-of-range knobs on exact: clean errors.
        for (wr, sl) in [(Some(0.0), None), (Some(1.0), None), (None, Some(0.5)), (None, Some(f64::NAN))] {
            let mut req = scored_req(Solver::Exact, 16);
            req.window_ratio = wr;
            req.slack = sl;
            assert!(
                run_batch_scored(&oracle, &req, &[], &mut cache).is_err(),
                "wr={wr:?} slack={sl:?} must be rejected"
            );
        }
        // Markov (no uniform-state process): knobs accepted, FHS fallback
        // still bit-identical to the per-lane sampler.
        let lanes = test_lanes(2);
        let mut req = scored_req(Solver::Exact, 16);
        req.window_ratio = Some(0.9);
        req.slack = Some(2.0);
        let result = run_batch_scored(&oracle, &req, &lanes, &mut cache).unwrap();
        for (k, lane) in lanes.iter().enumerate() {
            let mut r = Xoshiro256::seed_from_u64(lane.seed);
            let (toks, stats, _) = crate::solvers::masked::fhs_generate(&oracle, DELTA, &mut r);
            assert_eq!(result.tokens[k], toks, "lane {k}");
            assert_eq!(result.nfe[k], stats.nfe, "lane {k}");
        }
        // HMM family: exact runs bracketed uniformization under the knobs;
        // samples are mask-free, deterministic per lane seed, and nfe_used
        // reports evaluations actually performed (>= 1).
        let hmm = HmmUniformOracle::new(chain, 8);
        let mut req = scored_req(Solver::Exact, 16);
        req.window_ratio = Some(0.6);
        req.slack = Some(3.0);
        let a = run_batch_scored(&hmm, &req, &lanes, &mut cache).unwrap();
        let b = run_batch_scored(&hmm, &req, &lanes, &mut cache).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.nfe, b.nfe);
        for (toks, &nfe) in a.tokens.iter().zip(&a.nfe) {
            assert_eq!(toks.len(), 8);
            assert!(toks.iter().all(|&t| (t as usize) < 5), "{toks:?}");
            assert!(nfe >= 1);
        }
    }

    #[test]
    fn run_batch_scored_rejects_rk2_theta_past_half() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(31);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 4, 0.5), 8);
        let mut cache = ScheduleCache::new();
        let err = run_batch_scored(&oracle, &scored_req(Solver::Rk2 { theta: 0.7 }, 16), &[], &mut cache)
            .unwrap_err();
        assert!(format!("{err:#}").contains("1/2"), "{err:#}");
        // The boundary value is fine.
        assert!(run_batch_scored(
            &oracle,
            &scored_req(Solver::Rk2 { theta: 0.5 }, 8),
            &test_lanes(1),
            &mut cache
        )
        .is_ok());
    }

    #[test]
    fn run_batch_scored_rejects_absurd_budget() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(13);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 4, 0.5), 8);
        let mut cache = ScheduleCache::new();
        let err = run_batch_scored(
            &oracle,
            &scored_req(Solver::Trapezoidal { theta: 0.5 }, 1),
            &[],
            &mut cache,
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("below one step"), "{err:#}");
        // Malformed client-supplied theta must error, never panic (a panic
        // would kill the coordinator thread).
        for bad in [
            Solver::Trapezoidal { theta: 0.0 },
            Solver::Trapezoidal { theta: 1.0 },
            Solver::Trapezoidal { theta: f64::NAN },
            Solver::Rk2 { theta: 1.5 },
            Solver::Rk2 { theta: 0.0 },
        ] {
            let err =
                run_batch_scored(&oracle, &scored_req(bad, 16), &[], &mut cache).unwrap_err();
            assert!(format!("{err:#}").contains("theta"), "{err:#}");
        }
        // Adaptive with a one-stage solver and under-budgeted requests
        // must error cleanly too.
        let mut req = scored_req(Solver::TauLeaping, 16);
        req.schedule = ScheduleSpec::Adaptive { tol: 1e-3 };
        let err = run_batch_scored(&oracle, &req, &[], &mut cache).unwrap_err();
        assert!(format!("{err:#}").contains("two-stage"), "{err:#}");
        // Same for tuned (the pilot fits are adaptive passes).
        let mut req = scored_req(Solver::Tweedie, 16);
        req.schedule = ScheduleSpec::Tuned { steps: 0 };
        let err = run_batch_scored(&oracle, &req, &[], &mut cache).unwrap_err();
        assert!(format!("{err:#}").contains("two-stage"), "{err:#}");
        let mut req = scored_req(Solver::Trapezoidal { theta: 0.5 }, 16);
        req.nfe_budget = Some(2);
        let err = run_batch_scored(&oracle, &req, &[], &mut cache).unwrap_err();
        assert!(format!("{err:#}").contains("nfe_budget"), "{err:#}");
    }

    #[test]
    fn fill_uniforms_lane_isolation() {
        // Lane 0's stream must be identical regardless of other lanes.
        let mut r1 = vec![Xoshiro256::seed_from_u64(7)];
        let mut pad = Xoshiro256::seed_from_u64(1);
        let a = fill_uniforms(2, 4, 8, &mut r1, &mut pad);
        let mut r2 = vec![
            Xoshiro256::seed_from_u64(7),
            Xoshiro256::seed_from_u64(8),
        ];
        let mut pad = Xoshiro256::seed_from_u64(2);
        let b = fill_uniforms(2, 4, 8, &mut r2, &mut pad);
        for s in 0..2 {
            for g in 0..2 {
                let base = ((s * 2 + g) * 4) * 8;
                assert_eq!(&a[base..base + 8], &b[base..base + 8], "stage {s} gate {g}");
            }
        }
    }

    #[test]
    fn fill_uniforms_values_in_range() {
        let mut rngs = vec![Xoshiro256::seed_from_u64(1)];
        let mut pad = Xoshiro256::seed_from_u64(2);
        let u = fill_uniforms(1, 2, 4, &mut rngs, &mut pad);
        assert_eq!(u.len(), 1 * 2 * 2 * 4);
        assert!(u.iter().all(|&x| x > 0.0 && x < 1.0));
    }
}
