//! Step scheduler: drives one packed batch through its whole backward pass.
//!
//! Two execution paths:
//!
//! - [`run_batch_scored`] — the preferred path: a [`ScoreSource`] (analytic
//!   oracle or the `{family}_score` artifact) plus the pure-rust solver
//!   loop `solvers::masked::generate_batch`, which steps every lane in
//!   lock-step with one batched, masked-sparse score call per stage.
//! - [`run_batch`] — the legacy fused-step-graph path: one PJRT dispatch
//!   per grid step (two-stage solvers are FUSED into a single step graph by
//!   L2, so a trapezoidal step is still one dispatch but counts 2 NFE).
//!   Lanes shorter than the artifact batch are padded with dummy lanes.
//!
//! **No validation happens here.**  The scheduler consumes a
//! [`SamplingSpec`], which is valid by construction (the builder at the
//! wire boundary is the only constructor), and executes its *resolved plan*
//! ([`SamplingSpec::plan`]) — the same plan the batch key hashes, so every
//! co-batched lane runs under identical parameters by construction.  The
//! pre-redesign scheduler validated flat knobs both here and at
//! coordinator intake precisely because its key did not encode every
//! validated field; that entire class of bug is now unrepresentable.
//!
//! In both paths each real lane draws from its own seeded stream, so a
//! sample depends only on (request seed, sample index) — not on
//! co-batching.  Cancellation: exact lanes poll their own request's token
//! per window/event; lock-step scheme batches poll the shared token when
//! every lane belongs to one request (the common case for long runs).

use std::sync::Mutex;

use anyhow::{bail, Result};

use crate::api::{ExecPlan, SamplingSpec};
use crate::coordinator::batcher::Lane;
use crate::runtime::{ArtifactSpec, Registry, RuntimeHandle, Value};
use crate::schedule::adaptive::{AdaptiveController, NfeBudget, StepController};
use crate::schedule::{ScheduleCache, ScheduleTuner, TuneKey};
use crate::score::{ScoreSource, Tok};
use crate::solvers::{grid, masked, Solver};
use crate::util::cancel::CancelToken;
use crate::util::rng::{Rng, Xoshiro256};

pub use crate::api::spec::{DELTA, MAX_TUNED_STEPS};

/// Result of one batch pass: per-lane token sequences + NFE actually spent
/// per lane (lanes can differ once the sparse path skips empty steps) +
/// per-lane partial markers (set when a lane was interrupted by a cancel
/// token or the exact-path `max_events` cap).
pub struct BatchResult {
    pub tokens: Vec<Vec<Tok>>,
    pub nfe: Vec<usize>,
    pub partial: Vec<bool>,
    /// PIT-only counters, zero for sequential/exact batches: sweeps summed
    /// over lanes, lanes whose convergence criterion fired, and lanes that
    /// hit the `sweeps_max` divergence guard.
    pub pit_sweeps: u64,
    pub pit_converged: u64,
    pub pit_sweep_limit: u64,
}

impl BatchResult {
    /// A result from a non-PIT path (PIT counters zero).
    fn sequential(tokens: Vec<Vec<Tok>>, nfe: Vec<usize>, partial: Vec<bool>) -> BatchResult {
        BatchResult { tokens, nfe, partial, pit_sweeps: 0, pit_converged: 0, pit_sweep_limit: 0 }
    }
}

/// The one cancel token a lock-step scheme batch polls: the request's
/// token when every lane shares it, a never-token otherwise (scheme
/// batches are NFE-bounded, so best-effort cancellation at batch
/// granularity is acceptable for mixed batches; exact lanes are always
/// individually cancellable).
fn shared_token(lanes: &[Lane]) -> CancelToken {
    match lanes.first() {
        Some(first)
            if lanes
                .iter()
                .all(|l| CancelToken::same(&l.cancel, &first.cancel)) =>
        {
            first.cancel.clone()
        }
        _ => CancelToken::never(),
    }
}

/// Run one packed batch through the solvers on a score source: one batched
/// masked-sparse score call per stage, per-lane seeded RNG streams.
/// Execution parameters come from [`SamplingSpec::plan`] — the resolved
/// discretisation the batch key hashes.  [`Solver::Exact`] runs the
/// per-lane exact sampler ([`masked::exact_batch_ctl`]: bracketed
/// uniformization for sources with a native uniform-state process,
/// first-hitting otherwise) and reports realized evaluations as NFE.
/// Fixed grids are bit-identical to serving each lane alone; adaptive
/// batches share one voted dt (the documented trade-off of shared online
/// control — pin the grid with "tuned" when exact replayability across
/// batch compositions is required).  Tuned grids are fitted on first use
/// (a few pilot runs, synchronous on the coordinator thread) and memoised
/// in `cache` (behind a mutex so the watchdog's dispatch worker and the
/// coordinator thread can share one cache; it is locked only for the
/// tuned-arm lookup, never across an evaluation).
pub fn run_batch_scored(
    score: &dyn ScoreSource,
    spec: &SamplingSpec,
    lanes: &[Lane],
    cache: &Mutex<ScheduleCache>,
) -> Result<BatchResult> {
    run_batch_scored_obs(score, spec, lanes, cache, None)
}

/// [`run_batch_scored`] with an optional progress sink: the driver's
/// per-window (or per-sweep, for PIT) heartbeat, forwarded to streaming
/// responses that opted in.  Exact batches have no grid, hence no
/// heartbeat.
pub fn run_batch_scored_obs(
    score: &dyn ScoreSource,
    spec: &SamplingSpec,
    lanes: &[Lane],
    cache: &Mutex<ScheduleCache>,
    obs: Option<&mut dyn FnMut(crate::solvers::driver::Progress)>,
) -> Result<BatchResult> {
    let solver = spec.solver();
    let seeds: Vec<u64> = lanes.iter().map(|l| l.seed).collect();

    let cancel = shared_token(lanes);
    let (results, completed) = match spec.plan() {
        ExecPlan::Exact { cfg, max_events } => {
            // Exact lanes are individually interruptible: each polls its
            // own request's token per window/event.
            let cancels: Vec<CancelToken> = lanes.iter().map(|l| l.cancel.clone()).collect();
            let results =
                masked::exact_batch_ctl(score, DELTA, &cfg, max_events, &seeds, &cancels);
            let nfe = results.iter().map(|r| r.stats.nfe).collect();
            let partial = results.iter().map(|r| r.partial).collect();
            let tokens = results.into_iter().map(|r| r.tokens).collect();
            return Ok(BatchResult::sequential(tokens, nfe, partial));
        }
        ExecPlan::Pit { steps, sweeps_max, tol } => {
            let grid_ts = grid::masked_uniform(steps, DELTA);
            let cfg = crate::solvers::pit::PitCfg::new(sweeps_max, tol);
            let outs = masked::pit_generate_batch_ctl(
                score, solver, &grid_ts, &seeds, &cfg, &cancel, obs,
            );
            return Ok(BatchResult {
                nfe: outs.iter().map(|o| o.stats.nfe).collect(),
                partial: outs.iter().map(|o| !o.outcome.complete()).collect(),
                pit_sweeps: outs.iter().map(|o| o.sweeps as u64).sum(),
                pit_converged: outs.iter().filter(|o| o.outcome.converged()).count() as u64,
                pit_sweep_limit: outs
                    .iter()
                    .filter(|o| o.outcome == crate::solvers::pit::PitOutcome::SweepLimit)
                    .count() as u64,
                tokens: outs.into_iter().map(|o| o.out).collect(),
            });
        }
        ExecPlan::Uniform { steps } => {
            let grid_ts = grid::masked_uniform(steps, DELTA);
            masked::generate_batch_ctl_obs(score, solver, &grid_ts, &seeds, &cancel, obs)
        }
        ExecPlan::Log { steps } => {
            let grid_ts = grid::masked_log(steps, DELTA);
            masked::generate_batch_ctl_obs(score, solver, &grid_ts, &seeds, &cancel, obs)
        }
        ExecPlan::Tuned { steps } => {
            let key = TuneKey::new(spec.family(), score.vocab(), score.seq_len(), solver, steps);
            // The guard drops at the end of the statement (`get_or_fit`
            // hands back an `Arc`), so the lock is held for the lookup —
            // or the synchronous first-use fit — but never the generation.
            let tuned = cache.lock().unwrap_or_else(|e| e.into_inner()).get_or_fit(key, || {
                // Serving-time fit: cheaper pilots than the offline-bench
                // tuner — this runs inline on the dispatching thread.
                ScheduleTuner { pilots: 2, tol: 1e-3, ..Default::default() }
                    .fit_masked(score, solver, steps, DELTA, spec.family())
            });
            masked::generate_batch_ctl_obs(score, solver, &tuned.grid, &seeds, &cancel, obs)
        }
        ExecPlan::Adaptive { tol, dt0, budget } => {
            let mut ctl =
                StepController::new(AdaptiveController::for_span(tol, 1.0, DELTA), dt0);
            if let Some(b) = budget {
                ctl = ctl.with_budget(NfeBudget {
                    total: b,
                    nfe_per_step: solver.nfe_per_step(),
                    reserve: 1,
                });
            }
            let (results, _, completed) = masked::generate_batch_adaptive_ctl_obs(
                score, solver, ctl, DELTA, &seeds, &cancel, obs,
            );
            (results, completed)
        }
    };
    // `completed` is the driver's own report of whether it broke early —
    // authoritative, unlike re-polling the token here, which would race
    // with a cancel landing just after the final window and mislabel a
    // fully-complete response as partial.
    let nfe = results.iter().map(|(_, s)| s.nfe).collect();
    let partial = vec![!completed; results.len()];
    let tokens = results.into_iter().map(|(t, _)| t).collect();
    Ok(BatchResult::sequential(tokens, nfe, partial))
}

/// Which artifact implements a solver step for a family.
pub fn artifact_name(family: &str, solver: Solver) -> String {
    let s = match solver {
        Solver::Euler => "euler",
        Solver::TauLeaping => "tau",
        Solver::Tweedie => "tweedie",
        Solver::Trapezoidal { .. } => "trapezoidal",
        Solver::Rk2 { .. } => "rk2",
        Solver::Midpoint { .. } => "midpoint",
        Solver::ParallelDecoding => "parallel",
        // Exact simulation has no fused step graph (its jump times are
        // data-dependent); it is servable only through the score-source
        // paths, so this name can never resolve — by design.
        Solver::Exact => "exact",
    };
    format!("{family}_step_{s}")
}

pub struct StepPlan {
    pub artifact: String,
    pub spec: ArtifactSpec,
    pub batch: usize,
    pub seq_len: usize,
    pub vocab: usize,
    pub stages: usize,
    pub steps: usize,
}

impl StepPlan {
    pub fn build(registry: &Registry, req: &SamplingSpec) -> Result<StepPlan> {
        let artifact = artifact_name(req.family(), req.solver());
        let spec = registry.get(&artifact)?.clone();
        let batch = spec.batch()?;
        let seq_len = spec
            .seq_len()
            .ok_or_else(|| anyhow::anyhow!("{artifact} has no seq_len"))?;
        let vocab = spec
            .vocab()
            .ok_or_else(|| anyhow::anyhow!("{artifact} has no vocab"))?;
        let stages = if spec.nfe_per_step == 2 { 2 } else { 1 };
        if req.nfe() < spec.nfe_per_step {
            bail!("nfe budget {} below one step ({})", req.nfe(), spec.nfe_per_step);
        }
        Ok(StepPlan {
            artifact,
            spec: spec.clone(),
            batch,
            seq_len,
            vocab,
            stages,
            steps: req.solver().steps_for_nfe(req.nfe()),
        })
    }
}

/// Run the whole backward pass for one packed batch.  The legacy fused
/// path honors cancellation at the same granularity as the scored path:
/// the shared batch token is polled once per PJRT step dispatch, and a
/// fired token skips the remaining steps and the terminal denoise
/// (partial lanes keep the mask id).
pub fn run_batch(
    runtime: &RuntimeHandle,
    plan: &StepPlan,
    solver: Solver,
    lanes: &[Lane],
) -> Result<BatchResult> {
    assert!(lanes.len() <= plan.batch);
    let cancel = shared_token(lanes);
    let mut cancelled = false;
    let (b, l, v) = (plan.batch, plan.seq_len, plan.vocab);
    let mask = v as i32;
    let mut tokens = vec![mask; b * l];
    let mut rngs: Vec<Xoshiro256> = lanes
        .iter()
        .map(|lane| Xoshiro256::seed_from_u64(lane.seed))
        .collect();
    // Padding lanes reuse a throwaway stream so shapes stay fixed.
    let mut pad_rng = Xoshiro256::seed_from_u64(0xDEAD_BEEF);

    let grid_ts = grid::masked_uniform(plan.steps, DELTA);
    let mut nfe = 0usize;

    let theta = match solver {
        Solver::Trapezoidal { theta } | Solver::Rk2 { theta } | Solver::Midpoint { theta } => {
            theta as f32
        }
        _ => 0.0,
    };

    for (step_idx, w) in grid_ts.windows(2).enumerate() {
        if cancel.is_cancelled() {
            cancelled = true;
            break;
        }
        let uniforms = fill_uniforms(plan.stages, b, l, &mut rngs, &mut pad_rng);
        let mut inputs = vec![
            Value::i32(tokens.clone(), vec![b, l]),
            Value::scalar_f32(w[0] as f32),
        ];
        match solver {
            Solver::ParallelDecoding => {
                // arccos schedule (App. D.4): k tokens to reveal this step.
                let n_steps = plan.steps;
                let frac = (step_idx + 1) as f64 / n_steps as f64;
                let target = if step_idx + 1 == n_steps {
                    0
                } else {
                    ((std::f64::consts::FRAC_PI_2 * frac).cos() * l as f64).ceil()
                        as usize
                };
                let masked_now = tokens.iter().filter(|&&x| x == mask).count() / b.max(1);
                let k = masked_now.saturating_sub(target) as i32;
                inputs.push(Value::scalar_i32(k.max(0)));
            }
            Solver::Trapezoidal { .. } | Solver::Rk2 { .. } | Solver::Midpoint { .. } => {
                inputs.push(Value::scalar_f32(w[1] as f32));
                inputs.push(Value::scalar_f32(theta));
            }
            _ => inputs.push(Value::scalar_f32(w[1] as f32)),
        }
        inputs.push(Value::f32(uniforms, vec![plan.stages, 2, b, l]));
        let out = runtime.execute(&plan.artifact, inputs)?;
        tokens = out[0].as_i32()?.to_vec();
        nfe += plan.spec.nfe_per_step;
    }

    // Terminal denoise of any still-masked dims: one exact (Tweedie) step
    // from DELTA to ~0 — gate probability ~1, destinations from the score.
    // Skipped on cancellation: partial lanes keep the mask id.
    if !cancelled && tokens.iter().any(|&x| x == mask) {
        let family = plan.artifact.split("_step_").next().unwrap_or(&plan.artifact);
        let tw = format!("{family}_step_tweedie");
        let uniforms = fill_uniforms(1, b, l, &mut rngs, &mut pad_rng);
        let out = runtime.execute(
            &tw,
            vec![
                Value::i32(tokens.clone(), vec![b, l]),
                Value::scalar_f32(DELTA as f32),
                Value::scalar_f32((DELTA * 1e-6) as f32),
                Value::f32(uniforms, vec![1, 2, b, l]),
            ],
        )?;
        tokens = out[0].as_i32()?.to_vec();
        nfe += 1;
    }

    let out_tokens = lanes
        .iter()
        .enumerate()
        .map(|(i, _)| {
            tokens[i * l..(i + 1) * l]
                .iter()
                .map(|&x| x as Tok)
                .collect()
        })
        .collect();
    Ok(BatchResult::sequential(
        out_tokens,
        vec![nfe; lanes.len()],
        vec![cancelled; lanes.len()],
    ))
}

/// Uniforms layout (stages, 2, B, L): lane b owns [.., .., b, ..] across all
/// stages/gates, drawn from its own stream.
fn fill_uniforms(
    stages: usize,
    b: usize,
    l: usize,
    rngs: &mut [Xoshiro256],
    pad_rng: &mut Xoshiro256,
) -> Vec<f32> {
    let mut u = vec![0.0f32; stages * 2 * b * l];
    for lane in 0..b {
        let rng: &mut Xoshiro256 = if lane < rngs.len() {
            &mut rngs[lane]
        } else {
            pad_rng
        };
        for s in 0..stages {
            for g in 0..2 {
                let base = ((s * 2 + g) * b + lane) * l;
                rng.fill_f32(&mut u[base..base + l]);
            }
        }
    }
    u
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::SamplingSpec;
    use crate::schedule::ScheduleSpec;

    #[test]
    fn artifact_names() {
        assert_eq!(
            artifact_name("markov", Solver::Trapezoidal { theta: 0.5 }),
            "markov_step_trapezoidal"
        );
        assert_eq!(artifact_name("toy", Solver::TauLeaping), "toy_step_tau");
        assert_eq!(
            artifact_name("transformer", Solver::ParallelDecoding),
            "transformer_step_parallel"
        );
    }

    fn scored_spec(solver: Solver, nfe: usize) -> SamplingSpec {
        SamplingSpec::builder().solver(solver).nfe(nfe).build().unwrap()
    }

    fn test_lanes(n: usize) -> Vec<Lane> {
        use std::time::Instant;
        (0..n)
            .map(|i| Lane {
                request_id: 1,
                sample_idx: i,
                seed: 1000 + i as u64 * 17,
                enqueued: Instant::now(),
                cancel: CancelToken::never(),
            })
            .collect()
    }

    #[test]
    fn run_batch_scored_matches_single_lane_generation() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(13);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 12);
        let lanes = test_lanes(3);
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let cache = Mutex::new(ScheduleCache::new());
        let result =
            run_batch_scored(&oracle, &scored_spec(solver, 16), &lanes, &cache).unwrap();
        assert_eq!(result.tokens.len(), 3);
        assert_eq!(result.nfe.len(), 3);
        assert!(result.partial.iter().all(|&p| !p));
        let grid_ts = grid::masked_uniform(solver.steps_for_nfe(16), DELTA);
        for (k, lane) in lanes.iter().enumerate() {
            let mut r = Xoshiro256::seed_from_u64(lane.seed);
            let (toks, stats) =
                crate::solvers::masked::generate(&oracle, solver, &grid_ts, &mut r);
            assert_eq!(result.tokens[k], toks, "lane {k}");
            assert_eq!(result.nfe[k], stats.nfe, "lane {k}");
        }
    }

    #[test]
    fn run_batch_scored_adaptive_and_tuned_schedules() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(17);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 10);
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let cache = Mutex::new(ScheduleCache::new());
        let lanes = test_lanes(2);

        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(32)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-2 })
            .nfe_budget(Some(20))
            .build()
            .unwrap();
        let result = run_batch_scored(&oracle, &spec, &lanes, &cache).unwrap();
        for (k, &nfe) in result.nfe.iter().enumerate() {
            assert!(nfe <= 20, "lane {k} overdrew: {nfe}");
            assert!(result.tokens[k].iter().all(|&t| t < 5), "masks left");
        }

        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .schedule(ScheduleSpec::Tuned { steps: 6 })
            .build()
            .unwrap();
        let result = run_batch_scored(&oracle, &spec, &lanes, &cache).unwrap();
        assert_eq!(cache.lock().unwrap().len(), 1, "tuned grid must be memoised");
        assert!(result.tokens.iter().all(|t| t.iter().all(|&c| c < 5)));
        // Second call hits the cache (still one entry).
        let _ = run_batch_scored(&oracle, &spec, &lanes, &cache).unwrap();
        assert_eq!(cache.lock().unwrap().len(), 1);

        // An explicit tuned step count is still bound by the hard budget —
        // resolved in the PLAN, so the batch key reflects it too.
        let spec = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .schedule(ScheduleSpec::Tuned { steps: 64 })
            .nfe_budget(Some(9))
            .build()
            .unwrap();
        assert_eq!(spec.plan(), crate::api::ExecPlan::Tuned { steps: 4 });
        let result = run_batch_scored(&oracle, &spec, &lanes, &cache).unwrap();
        for &nfe in &result.nfe {
            assert!(nfe <= 9, "tuned+budget overdrew: {nfe}");
        }
    }

    #[test]
    fn run_batch_scored_pit_matches_sequential_and_counts() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(23);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 12);
        let lanes = test_lanes(3);
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let cache = Mutex::new(ScheduleCache::new());
        let pit_spec = SamplingSpec::builder().solver(solver).nfe(16).pit(true).build().unwrap();
        let seq_spec = scored_spec(solver, 16);
        let pit = run_batch_scored(&oracle, &pit_spec, &lanes, &cache).unwrap();
        let seq = run_batch_scored(&oracle, &seq_spec, &lanes, &cache).unwrap();
        // tol = 0 → bit-identical samples, per lane.
        assert_eq!(pit.tokens, seq.tokens);
        assert!(pit.partial.iter().all(|&p| !p));
        // Counters: every lane converged, nobody hit the sweep cap, and
        // the sweep total is positive and bounded by lanes × steps.
        assert_eq!(pit.pit_converged, 3);
        assert_eq!(pit.pit_sweep_limit, 0);
        assert!(pit.pit_sweeps >= 3 && pit.pit_sweeps <= 3 * 8, "{}", pit.pit_sweeps);
        // Sequential paths report zeroed PIT counters.
        assert_eq!(
            (seq.pit_sweeps, seq.pit_converged, seq.pit_sweep_limit),
            (0, 0, 0)
        );
        // A 1-sweep cap yields typed partials, not a spin.
        let capped = SamplingSpec::builder()
            .solver(solver)
            .nfe(16)
            .pit(true)
            .sweeps_max(Some(1))
            .build()
            .unwrap();
        let r = run_batch_scored(&oracle, &capped, &lanes, &cache).unwrap();
        assert!(r.partial.iter().all(|&p| p));
        assert_eq!(r.pit_sweep_limit, 3);
        assert_eq!(r.pit_converged, 0);
        // Progress sink sees per-sweep heartbeats.
        let mut beats = 0usize;
        let mut sink = |p: crate::solvers::driver::Progress| {
            assert_eq!(p.phase, "sweep");
            beats += 1;
        };
        let _ = run_batch_scored_obs(&oracle, &pit_spec, &lanes, &cache, Some(&mut sink))
            .unwrap();
        assert!(beats >= 1);
    }

    #[test]
    fn run_batch_scored_exact_matches_per_lane_fhs() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(29);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 12);
        let lanes = test_lanes(3);
        let cache = Mutex::new(ScheduleCache::new());
        let result =
            run_batch_scored(&oracle, &scored_spec(Solver::Exact, 16), &lanes, &cache)
                .unwrap();
        assert_eq!(result.tokens.len(), 3);
        for (k, lane) in lanes.iter().enumerate() {
            let mut r = Xoshiro256::seed_from_u64(lane.seed);
            let (toks, stats, _) = crate::solvers::masked::fhs_generate(&oracle, DELTA, &mut r);
            assert_eq!(result.tokens[k], toks, "lane {k}");
            assert_eq!(result.nfe[k], stats.nfe, "lane {k} realized NFE");
            // Realized NFE: one eval per unmask event + at most one finalize.
            assert!(result.nfe[k] >= 1 && result.nfe[k] <= 13, "lane {k}");
            assert!(!result.partial[k]);
        }
    }

    #[test]
    fn run_batch_scored_threads_exact_knobs_and_cancel() {
        use crate::score::hmm::HmmUniformOracle;
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(41);
        let chain = MarkovChain::generate(&mut rng, 5, 0.6);
        let cache = Mutex::new(ScheduleCache::new());

        // Markov (no uniform-state process): knobs accepted, FHS fallback
        // still bit-identical to the per-lane sampler.
        let oracle = MarkovOracle::new(chain.clone(), 8);
        let lanes = test_lanes(2);
        let spec = SamplingSpec::builder()
            .solver(Solver::Exact)
            .window_ratio(Some(0.9))
            .slack(Some(2.0))
            .build()
            .unwrap();
        let result = run_batch_scored(&oracle, &spec, &lanes, &cache).unwrap();
        for (k, lane) in lanes.iter().enumerate() {
            let mut r = Xoshiro256::seed_from_u64(lane.seed);
            let (toks, stats, _) = crate::solvers::masked::fhs_generate(&oracle, DELTA, &mut r);
            assert_eq!(result.tokens[k], toks, "lane {k}");
            assert_eq!(result.nfe[k], stats.nfe, "lane {k}");
        }
        // HMM family: exact runs bracketed uniformization under the knobs;
        // samples are mask-free, deterministic per lane seed, and nfe_used
        // reports evaluations actually performed (>= 1).
        let hmm = HmmUniformOracle::new(chain, 8);
        let spec = SamplingSpec::builder()
            .solver(Solver::Exact)
            .window_ratio(Some(0.6))
            .slack(Some(3.0))
            .build()
            .unwrap();
        let a = run_batch_scored(&hmm, &spec, &lanes, &cache).unwrap();
        let b = run_batch_scored(&hmm, &spec, &lanes, &cache).unwrap();
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.nfe, b.nfe);
        for (toks, &nfe) in a.tokens.iter().zip(&a.nfe) {
            assert_eq!(toks.len(), 8);
            assert!(toks.iter().all(|&t| (t as usize) < 5), "{toks:?}");
            assert!(nfe >= 1);
        }
        // A pre-fired per-lane token marks exactly that lane partial.
        let mut lanes = test_lanes(2);
        lanes[0].cancel = CancelToken::new();
        lanes[0].cancel.cancel();
        let r = run_batch_scored(&hmm, &spec, &lanes, &cache).unwrap();
        assert!(r.partial[0], "cancelled lane must be partial");
        assert!(!r.partial[1], "co-batched lane must complete");
        assert_eq!(r.tokens[1], a.tokens[1], "surviving lane is bit-identical");
    }

    #[test]
    fn run_batch_scored_scheme_cancel_skips_finalize() {
        use crate::score::markov::{MarkovChain, MarkovOracle};
        let mut rng = Xoshiro256::seed_from_u64(31);
        let oracle = MarkovOracle::new(MarkovChain::generate(&mut rng, 4, 0.5), 8);
        let cache = Mutex::new(ScheduleCache::new());
        // All lanes share one fired token → the whole batch stops at the
        // first window and reports partial with fully masked sequences.
        let token = CancelToken::new();
        token.cancel();
        let mut lanes = test_lanes(2);
        for l in &mut lanes {
            l.cancel = token.clone();
        }
        let spec = scored_spec(Solver::Trapezoidal { theta: 0.5 }, 16);
        let r = run_batch_scored(&oracle, &spec, &lanes, &cache).unwrap();
        assert!(r.partial.iter().all(|&p| p));
        for toks in &r.tokens {
            assert!(
                toks.iter().all(|&t| t == oracle.mask_id()),
                "no window may run after cancellation: {toks:?}"
            );
        }
        assert!(r.nfe.iter().all(|&n| n == 0));
    }

    #[test]
    fn fill_uniforms_lane_isolation() {
        // Lane 0's stream must be identical regardless of other lanes.
        let mut r1 = vec![Xoshiro256::seed_from_u64(7)];
        let mut pad = Xoshiro256::seed_from_u64(1);
        let a = fill_uniforms(2, 4, 8, &mut r1, &mut pad);
        let mut r2 = vec![
            Xoshiro256::seed_from_u64(7),
            Xoshiro256::seed_from_u64(8),
        ];
        let mut pad = Xoshiro256::seed_from_u64(2);
        let b = fill_uniforms(2, 4, 8, &mut r2, &mut pad);
        for s in 0..2 {
            for g in 0..2 {
                let base = ((s * 2 + g) * 4) * 8;
                assert_eq!(&a[base..base + 8], &b[base..base + 8], "stage {s} gate {g}");
            }
        }
    }

    #[test]
    fn fill_uniforms_values_in_range() {
        let mut rngs = vec![Xoshiro256::seed_from_u64(1)];
        let mut pad = Xoshiro256::seed_from_u64(2);
        let u = fill_uniforms(1, 2, 4, &mut rngs, &mut pad);
        assert_eq!(u.len(), 1 * 2 * 2 * 4);
        assert!(u.iter().all(|&x| x > 0.0 && x < 1.0));
    }
}
