//! Backend health: a circuit breaker over score-backend dispatches, the
//! stall-watchdog dispatch worker, and the transient-failure contract.
//!
//! Three pieces, consumed by the coordinator loop (`coordinator::run`):
//!
//! - [`HealthTracker`] — per-backend EWMA dispatch latency plus a
//!   consecutive-failure count feeding a closed → open → half-open
//!   **circuit breaker**.  While the breaker is open, new dispatches fail
//!   fast with typed `backend_unavailable` instead of queueing work that
//!   will stall behind a sick backend; after [`HealthCfg::cooldown`] the
//!   breaker admits a single **probe** dispatch (half-open) and closes
//!   again only if the probe succeeds.  The dispatch loop is sequential,
//!   so one probe at a time is guaranteed by construction.
//! - [`DispatchWorker`] — a long-lived worker thread the loop offloads
//!   score evaluations to, so it can bound each one with
//!   `recv_timeout(eval_timeout)`.  On expiry the loop *abandons* the
//!   worker (dropping the job channel; the stalled thread exits on its
//!   own once it wakes) and lazily respawns a fresh one — a stalled eval
//!   can therefore no longer delay unrelated queued requests past the
//!   watchdog bound.  The timeout derives from the admission cost model
//!   (EWMA ms/NFE) via [`HealthCfg::eval_timeout`]; a cold model never
//!   times anything out.
//! - [`TRANSIENT`] / [`is_transient`] — the marker contract by which a
//!   backend signals a *retryable* fault: a panic whose payload contains
//!   [`TRANSIENT`] (see `testkit::fault::FaultKind::Err`) is retried
//!   under capped exponential backoff ([`super::supervise::Backoff`])
//!   within [`HealthCfg::retry_budget`]; any other panic is a lane bug
//!   and goes through fault isolation as before.  Because score
//!   evaluations are pure (each lane re-seeds from `lane_seed(i)` per
//!   attempt, no RNG is drawn between attempts), a retried-then-succeeded
//!   request is bit-identical to a never-faulted run — pinned by the
//!   chaos suite.

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

use super::scheduler::BatchResult;

/// Marker a backend embeds in a panic payload to flag the failure as
/// *transient* (retryable): timeouts and `[transient]`-marked panics are
/// retried within the budget, anything else is treated as a lane bug.
pub const TRANSIENT: &str = "[transient]";

/// Whether a `catch_unwind` payload carries the [`TRANSIENT`] marker.
pub fn is_transient(payload: &(dyn Any + Send)) -> bool {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s.contains(TRANSIENT)
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.contains(TRANSIENT)
    } else {
        false
    }
}

/// Health/robustness knobs, carried on `CoordinatorCfg`.  Defaults keep
/// every mechanism on with production-shaped constants; tests and benches
/// shrink the time constants or switch single mechanisms off.
#[derive(Clone, Copy, Debug)]
pub struct HealthCfg {
    /// Consecutive dispatch failures that trip the breaker open.
    pub failure_threshold: u32,
    /// How long an open breaker fails fast before admitting a probe.
    pub cooldown: Duration,
    /// Retries per dispatch after the first attempt (so `retry_budget = 2`
    /// allows three attempts total) before failing `backend_unavailable`.
    pub retry_budget: u32,
    /// First retry delay; doubles per attempt up to [`Self::backoff_cap`].
    pub backoff_initial: Duration,
    pub backoff_cap: Duration,
    /// Eval timeout = `watchdog_mult` × the cost model's estimate for the
    /// batch's planned NFE, floored at [`Self::watchdog_floor`].  The
    /// generous multiple keeps honest slow batches (cache-cold fits,
    /// co-batched stragglers) from tripping the watchdog.
    pub watchdog_mult: f64,
    /// Smallest eval timeout the watchdog will arm (keeps the multiple
    /// from producing hair-trigger timeouts on microsecond batches).
    pub watchdog_floor: Duration,
    /// Master switch for the stall watchdog (off = dispatch inline on the
    /// loop thread, exactly the historical behavior).
    pub watchdog: bool,
    /// Master switch for the brownout degradation ladder at admission.
    pub brownout: bool,
}

impl Default for HealthCfg {
    fn default() -> HealthCfg {
        HealthCfg {
            failure_threshold: 3,
            cooldown: Duration::from_millis(250),
            retry_budget: 2,
            backoff_initial: Duration::from_millis(10),
            backoff_cap: Duration::from_millis(100),
            watchdog_mult: 24.0,
            watchdog_floor: Duration::from_millis(50),
            watchdog: true,
            brownout: true,
        }
    }
}

impl HealthCfg {
    /// The watchdog bound for one eval, given the cost model's estimate
    /// for the batch (`ms/NFE × planned NFE`).  `None` = run unwatched:
    /// the watchdog is off, or the cost model is still cold (estimate 0)
    /// and no sane bound exists yet.
    pub fn eval_timeout(&self, estimate_ms: f64) -> Option<Duration> {
        if !self.watchdog || estimate_ms <= 0.0 {
            return None;
        }
        let bounded = Duration::from_secs_f64(self.watchdog_mult * estimate_ms / 1e3);
        Some(bounded.max(self.watchdog_floor))
    }
}

/// The breaker's verdict for one incoming dispatch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gate {
    /// Breaker closed: dispatch normally.
    Allow,
    /// Breaker was open, cooldown elapsed: this dispatch is the half-open
    /// probe — success closes the breaker, failure reopens it.
    Probe,
    /// Breaker open: fail the batch fast, typed `backend_unavailable`.
    FastFail,
}

#[derive(Clone, Copy, Debug)]
enum Breaker {
    Closed,
    Open { since: Instant },
    HalfOpen,
}

/// Per-backend health: consecutive-failure count + EWMA dispatch latency
/// feeding the circuit breaker.  Single-owner (the loop thread); the
/// sequential dispatch loop is what guarantees at most one in-flight
/// probe.
pub struct HealthTracker {
    cfg: HealthCfg,
    breaker: Breaker,
    consecutive_failures: u32,
    /// EWMA of per-dispatch wall time (ms); 0 until the first success.
    ewma_latency_ms: f64,
}

impl HealthTracker {
    pub fn new(cfg: HealthCfg) -> HealthTracker {
        HealthTracker {
            cfg,
            breaker: Breaker::Closed,
            consecutive_failures: 0,
            ewma_latency_ms: 0.0,
        }
    }

    /// Consult the breaker for one dispatch.  An open breaker whose
    /// cooldown has elapsed transitions to half-open and admits the
    /// caller as the probe.
    pub fn admit_dispatch(&mut self) -> Gate {
        match self.breaker {
            Breaker::Closed => Gate::Allow,
            Breaker::Open { since } => {
                if since.elapsed() >= self.cfg.cooldown {
                    self.breaker = Breaker::HalfOpen;
                    Gate::Probe
                } else {
                    Gate::FastFail
                }
            }
            // Unreachable under the sequential loop (the probe resolves
            // before the next admit); admit as another probe if reached.
            Breaker::HalfOpen => Gate::Probe,
        }
    }

    /// A dispatch (all retries included) succeeded: close the breaker,
    /// clear the failure streak, fold the wall time into the EWMA.
    pub fn on_success(&mut self, wall_ms: f64) {
        self.breaker = Breaker::Closed;
        self.consecutive_failures = 0;
        self.ewma_latency_ms = if self.ewma_latency_ms == 0.0 {
            wall_ms
        } else {
            0.8 * self.ewma_latency_ms + 0.2 * wall_ms
        };
    }

    /// A dispatch exhausted its retries (timeouts / transient faults /
    /// backend errors): bump the streak; trip the breaker at the
    /// threshold, and immediately on a failed half-open probe.
    pub fn on_failure(&mut self) {
        self.consecutive_failures += 1;
        let probe_failed = matches!(self.breaker, Breaker::HalfOpen);
        if probe_failed || self.consecutive_failures >= self.cfg.failure_threshold {
            self.breaker = Breaker::Open { since: Instant::now() };
        }
    }

    /// Whether admission should treat the backend as sick (brownout hard
    /// rung): any non-closed breaker state.
    pub fn is_degraded(&self) -> bool {
        !matches!(self.breaker, Breaker::Closed)
    }

    /// Stable name for the `stats` verb: `closed` / `open` / `half-open`.
    pub fn state_name(&self) -> &'static str {
        match self.breaker {
            Breaker::Closed => "closed",
            Breaker::Open { .. } => "open",
            Breaker::HalfOpen => "half-open",
        }
    }

    pub fn ewma_latency_ms(&self) -> f64 {
        self.ewma_latency_ms
    }
}

/// A dispatch job shipped to the worker: the boxed evaluation plus the
/// one-shot channel its (caught) outcome comes back on.
type Work = Box<dyn FnOnce() -> anyhow::Result<BatchResult> + Send>;
type Caught = std::thread::Result<anyhow::Result<BatchResult>>;

/// What came back from one watched dispatch.
pub enum WorkerReply {
    /// The eval finished (successfully, with an error, or panicking —
    /// panics are caught on the worker and carried as the payload).
    Done(Caught),
    /// The watchdog expired first.  The caller must drop this worker
    /// (abandoning the stalled eval) and respawn before the next dispatch.
    TimedOut,
    /// The worker thread is gone (its reply channel closed without a
    /// reply) — treated like a transient failure.
    Dead,
}

/// Long-lived dispatch thread: the loop sends boxed evals over a channel
/// and bounds the reply wait, so a stalled backend blocks the *worker*,
/// never the loop.  Dropping the handle closes the job channel; a stalled
/// worker then exits on its own the moment its eval returns, and any late
/// reply lands on a receiver nobody holds.
pub struct DispatchWorker {
    jobs: Sender<(Work, Sender<Caught>)>,
}

impl DispatchWorker {
    /// Spawn a fresh worker.  `None` if the OS refuses the thread — the
    /// caller falls back to inline (unwatched) dispatch rather than
    /// failing the batch.
    pub fn spawn() -> Option<DispatchWorker> {
        let (jobs, inbox) = channel::<(Work, Sender<Caught>)>();
        let spawned = std::thread::Builder::new()
            .name("dispatch-worker".into())
            .spawn(move || {
                while let Ok((work, reply)) = inbox.recv() {
                    // A dropped reply receiver (abandoned eval) is fine.
                    let _ = reply.send(catch_unwind(AssertUnwindSafe(work)));
                }
            });
        match spawned {
            Ok(_) => Some(DispatchWorker { jobs }),
            Err(_) => None,
        }
    }

    /// Run one eval on the worker, waiting at most `timeout` (forever if
    /// `None` — used when the cost model is cold but the worker exists).
    pub fn dispatch(&self, work: Work, timeout: Option<Duration>) -> WorkerReply {
        let (reply_tx, reply_rx) = channel();
        if self.jobs.send((work, reply_tx)).is_err() {
            return WorkerReply::Dead;
        }
        match timeout {
            Some(bound) => match reply_rx.recv_timeout(bound) {
                Ok(caught) => WorkerReply::Done(caught),
                Err(RecvTimeoutError::Timeout) => WorkerReply::TimedOut,
                Err(RecvTimeoutError::Disconnected) => WorkerReply::Dead,
            },
            None => match reply_rx.recv() {
                Ok(caught) => WorkerReply::Done(caught),
                Err(_) => WorkerReply::Dead,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A minimal non-PIT batch result (the ctor is scheduler-private).
    fn result(tokens: Vec<Vec<crate::score::Tok>>) -> BatchResult {
        let lanes = tokens.len();
        BatchResult {
            tokens,
            nfe: vec![1; lanes],
            partial: vec![false; lanes],
            pit_sweeps: 0,
            pit_converged: 0,
            pit_sweep_limit: 0,
        }
    }

    fn fast_cfg() -> HealthCfg {
        HealthCfg {
            failure_threshold: 3,
            cooldown: Duration::from_millis(20),
            ..Default::default()
        }
    }

    #[test]
    fn breaker_trips_cools_probes_and_closes() {
        let mut h = HealthTracker::new(fast_cfg());
        assert_eq!(h.admit_dispatch(), Gate::Allow);
        assert_eq!(h.state_name(), "closed");
        assert!(!h.is_degraded());
        // Two failures: still under the threshold.
        h.on_failure();
        h.on_failure();
        assert_eq!(h.admit_dispatch(), Gate::Allow);
        // Third consecutive failure trips it open.
        h.on_failure();
        assert_eq!(h.state_name(), "open");
        assert!(h.is_degraded());
        assert_eq!(h.admit_dispatch(), Gate::FastFail);
        // Cooldown elapses: the next dispatch is the half-open probe.
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(h.admit_dispatch(), Gate::Probe);
        assert_eq!(h.state_name(), "half-open");
        // Probe succeeds: closed, streak cleared.
        h.on_success(5.0);
        assert_eq!(h.state_name(), "closed");
        assert_eq!(h.admit_dispatch(), Gate::Allow);
        // One fresh failure must NOT re-trip (streak was reset).
        h.on_failure();
        assert_eq!(h.admit_dispatch(), Gate::Allow);
    }

    #[test]
    fn failed_probe_reopens_immediately() {
        let mut h = HealthTracker::new(fast_cfg());
        for _ in 0..3 {
            h.on_failure();
        }
        std::thread::sleep(Duration::from_millis(25));
        assert_eq!(h.admit_dispatch(), Gate::Probe);
        // The probe fails: straight back to open, no threshold wait.
        h.on_failure();
        assert_eq!(h.state_name(), "open");
        assert_eq!(h.admit_dispatch(), Gate::FastFail);
    }

    #[test]
    fn success_tracks_latency_ewma() {
        let mut h = HealthTracker::new(HealthCfg::default());
        assert_eq!(h.ewma_latency_ms(), 0.0);
        h.on_success(10.0);
        assert!((h.ewma_latency_ms() - 10.0).abs() < 1e-12, "first obs seeds");
        h.on_success(20.0);
        assert!((h.ewma_latency_ms() - 12.0).abs() < 1e-12, "0.8*10 + 0.2*20");
    }

    #[test]
    fn eval_timeout_scales_and_floors() {
        let cfg = HealthCfg { watchdog_mult: 10.0, ..Default::default() };
        // Cold cost model: never timed out.
        assert!(cfg.eval_timeout(0.0).is_none());
        // Tiny estimate: floored.
        assert_eq!(cfg.eval_timeout(0.01), Some(cfg.watchdog_floor));
        // Real estimate: mult × estimate.
        assert_eq!(cfg.eval_timeout(100.0), Some(Duration::from_secs(1)));
        // Watchdog off: unwatched regardless.
        let off = HealthCfg { watchdog: false, ..Default::default() };
        assert!(off.eval_timeout(100.0).is_none());
    }

    #[test]
    fn transient_marker_detected_in_panic_payloads() {
        let p = catch_unwind(|| panic!("fault {TRANSIENT} score call 3")).unwrap_err();
        assert!(is_transient(p.as_ref()));
        let p = catch_unwind(|| panic!("ordinary lane bug")).unwrap_err();
        assert!(!is_transient(p.as_ref()));
        let p = catch_unwind(|| std::panic::panic_any(42i32)).unwrap_err();
        assert!(!is_transient(p.as_ref()), "non-string payloads are not transient");
    }

    #[test]
    fn worker_runs_work_and_catches_panics() {
        let w = DispatchWorker::spawn().expect("spawn worker");
        let ok = w.dispatch(
            Box::new(|| Ok(result(vec![vec![1]]))),
            Some(Duration::from_secs(5)),
        );
        match ok {
            WorkerReply::Done(Ok(Ok(r))) => assert_eq!(r.tokens, vec![vec![1]]),
            _ => panic!("expected a successful reply"),
        }
        // A panicking eval comes back caught, and the worker survives it.
        let caught = w.dispatch(
            Box::new(|| panic!("boom {TRANSIENT}")),
            Some(Duration::from_secs(5)),
        );
        match caught {
            WorkerReply::Done(Err(payload)) => assert!(is_transient(payload.as_ref())),
            _ => panic!("expected a caught panic"),
        }
        let again = w.dispatch(
            Box::new(|| Ok(result(vec![vec![2]]))),
            Some(Duration::from_secs(5)),
        );
        assert!(matches!(again, WorkerReply::Done(Ok(Ok(_)))), "worker must survive");
    }

    #[test]
    fn watchdog_abandons_stalled_worker() {
        let w = DispatchWorker::spawn().expect("spawn worker");
        let reply = w.dispatch(
            Box::new(|| {
                std::thread::sleep(Duration::from_millis(400));
                Ok(result(vec![vec![9]]))
            }),
            Some(Duration::from_millis(30)),
        );
        assert!(matches!(reply, WorkerReply::TimedOut));
        // Abandon: drop the handle; the stalled thread exits once it wakes
        // (nothing to assert beyond not hanging — the job channel closed).
        drop(w);
        // A fresh worker serves the retry.
        let w = DispatchWorker::spawn().expect("respawn worker");
        let reply = w.dispatch(
            Box::new(|| Ok(result(vec![vec![7]]))),
            Some(Duration::from_secs(5)),
        );
        assert!(matches!(reply, WorkerReply::Done(Ok(Ok(_)))));
    }
}
