//! # fastdds — Fast solvers for discrete diffusion models, as a serving stack
//!
//! Rust implementation of the NeurIPS 2025 paper *"Fast Solvers for Discrete
//! Diffusion Models: Theory and Applications of High-Order Algorithms"*:
//! the θ-trapezoidal (Alg. 2) and θ-RK-2 (Alg. 1/4) high-order samplers, all
//! baselines the paper evaluates (Euler, τ-leaping, Tweedie τ-leaping,
//! parallel decoding, uniformization, first-hitting), and a production-style
//! coordinator that serves generation requests over AOT-compiled JAX/Pallas
//! artifacts through PJRT.  Python never runs on the request path.
//!
//! Layer map (see DESIGN.md):
//! - L3 (this crate): [`coordinator`], [`server`], [`runtime`], [`registry`]
//!   (content-addressed artifact sharing), [`solvers`], [`ctmc`], [`score`],
//!   [`eval`], [`data`], [`exp`] + the from-scratch substrates in [`util`]
//!   and [`testkit`].
//! - L2/L1 (build-time python): `python/compile/` lowers score models and
//!   whole sampler step graphs (with Pallas kernels inside) to
//!   `artifacts/*.hlo.txt`.

pub mod util;
pub mod testkit;
pub mod api;
pub mod ctmc;
pub mod score;
pub mod schedule;
pub mod solvers;
pub mod eval;
pub mod data;
pub mod runtime;
pub mod registry;
pub mod coordinator;
pub mod server;
pub mod bench;
pub mod exp;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
