//! `fastdds` CLI — the leader entrypoint.
//!
//! ```text
//! fastdds exp <fig1|fig2|fig3|fig4|fig5|fig7|tab1|tab2|ablations|all> [--full]
//! fastdds serve   [--addr 127.0.0.1:7878] [--policy greedy|timeout:<ms>]
//!                 [--local [--oracle markov|hmm|digest:<hex>]]
//!                 [--vocab 16] [--seq-len 32]
//!                 [--schedule-dir tuned_schedules] [--registry-dir artifacts_reg]
//!                 [--max-inflight N] [--queue-cap N] [--max-conns 256]
//! fastdds registry <put|get|stat|list> [--addr ...]
//!                 put:  --kind tuned_schedule|score_model|compat_corpus
//!                       --name N [--family F] [--vocab V] [--seq-len L]
//!                       [--blobs f1,f2,...] [--oracle markov|hmm]
//!                 get:  --digest <64 hex> [--out dir]
//!                 stat: --digest <64 hex>
//!                 list: [--kind ...] [--family ...]
//! fastdds client  [--addr ...] --solver trapezoidal:0.5 --nfe 64 [--n 4] [--seed 1]
//!                 [--schedule adaptive:tol=1e-3] [--nfe-budget 48]
//!                 [--window-ratio 0.5] [--slack 4] [--max-events 1000]
//!                 [--pit] [--sweeps-max 8] [--tol 0.01]
//!                 [--deadline-ms 500] [--priority 0..3] [--no-degrade]
//!                 [--spec spec.json] [--stream] [--progress]
//!                 [--request-key my-key] [--timeout-ms 5000]
//! fastdds info    [--artifacts artifacts]
//! ```
//!
//! `serve --local` serves an exact oracle in-process — every schedule
//! variant works without PJRT or artifacts; `--oracle hmm` picks the
//! uniform-state HMM oracle, whose `--solver exact` path is bracketed
//! windowed uniformization (tunable with `client --window-ratio --slack`).
//! `--schedule-dir` persists tuned schedules to disk so restarts never
//! re-pay the pilot fits.
//!
//! `--registry-dir` attaches a content-addressed artifact registry
//! ([`fastdds::registry`]): the server then speaks the `registry_*` wire
//! verbs, the schedule cache pulls/publishes tuned grids by digest (point
//! several servers at one directory and only the first fits), and
//! `--oracle digest:<hex>` rebuilds a served Markov/HMM oracle from a
//! `score_model` artifact instead of regenerating one from a seed.  The
//! `fastdds registry` subcommand drives the same verbs over the wire;
//! `registry put --oracle markov|hmm` synthesizes and publishes the
//! score-model blob that `serve --oracle digest:<hex>` consumes.
//!
//! The client maps its flags through the typed `api::SpecBuilder`, so an
//! invalid knob combination fails locally with the same typed error the
//! server would return, then sends the v2 wire envelope.  `--spec f.json`
//! sends a spec read from a file (either a bare spec object or a full
//! `{"v":2,"spec":...}` envelope); `--stream` uses `generate_stream` and
//! prints chunks as lanes complete; `--timeout-ms` bounds connect/read so
//! a hung server fails the call instead of blocking forever.
//!
//! `--pit` runs the request through the parallel-in-time Picard driver
//! (uniform grids only): `--sweeps-max` caps the fixed-point sweeps
//! (default = the step count, the worst-case exact bound) and `--tol`
//! accepts early once the embedded per-step error estimate falls below it
//! (0 = bit-exact convergence).  `--progress` (with `--stream`) asks for
//! per-window/per-sweep heartbeat frames; `--request-key` attaches an
//! idempotency key — a duplicate submission while the original is in
//! flight fails typed `duplicate_request` instead of re-running.
//!
//! QoS: `client --deadline-ms` attaches a wall-clock deadline (infeasible
//! deadlines are rejected at intake with code `deadline_infeasible`;
//! feasible ones that expire mid-run return a PARTIAL response), and
//! `--priority` (0..=3, default 1) lets urgent requests displace queued
//! lower-priority ones when the server runs with admission caps.  `serve
//! --max-inflight/--queue-cap` enable those caps (unbounded if omitted);
//! `--max-conns` bounds concurrent connections (over-cap connections get
//! one typed `overloaded` frame and are closed).  Under sustained
//! overload the server may serve a request in a *degraded* form (echoed
//! as `DEGRADED rung N`); `client --no-degrade` opts out — such requests
//! are shed typed `overloaded` rather than silently degraded.

use anyhow::{bail, Result};
use fastdds::api::{wire, SamplingSpec};
use fastdds::coordinator::{BatchPolicy, Coordinator};
use fastdds::ctmc::ToyModel;
use fastdds::exp::{self, Scale};
use fastdds::runtime::{Registry, RuntimeHandle};
use fastdds::schedule::ScheduleSpec;
use fastdds::solvers::Solver;
use fastdds::util::cli::Args;
use fastdds::util::json::Json;
use fastdds::util::rng::Xoshiro256;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand() {
        Some("exp") => cmd_exp(&args),
        Some("serve") => cmd_serve(&args),
        Some("client") => cmd_client(&args),
        Some("cancel") => cmd_cancel(&args),
        Some("registry") => cmd_registry(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!(
                "fastdds — fast high-order solvers for discrete diffusion models\n\
                 usage: fastdds <exp|serve|client|cancel|registry|info> [options]\n\
                 see README.md"
            );
            Ok(())
        }
    }
}

fn toy_model(args: &Args) -> ToyModel {
    let path = args.get_str("artifacts", "artifacts") + "/toy_model.json";
    ToyModel::from_artifact(&path).unwrap_or_else(|_| {
        let mut rng = Xoshiro256::seed_from_u64(7);
        ToyModel::paper_default(&mut rng)
    })
}

fn cmd_exp(args: &Args) -> Result<()> {
    let scale = Scale::from_args(args);
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let all = which == "all";
    if all || which == "fig1" {
        exp::fig1::run(&exp::fig1::Fig1Config::new(scale));
    }
    if all || which == "fig2" {
        let model = toy_model(args);
        exp::fig2::run(&model, &exp::fig2::Fig2Config::new(scale));
    }
    if all || which == "tab1" || which == "tab2" {
        exp::tab2::run(&exp::tab2::Tab2Config::new(scale));
    }
    if all || which == "fig3" || which == "fig6" {
        exp::fig3::run(&exp::fig3::Fig3Config::new(scale));
    }
    if all || which == "fig4" {
        exp::fig4::run(&exp::fig4::Fig4Config::new(scale));
    }
    if all || which == "fig5" {
        exp::fig5::run(scale);
    }
    if all || which == "fig7" {
        exp::fig7::run(scale);
    }
    if all || which == "ablations" {
        exp::ablations::run(scale);
    }
    if !all
        && ![
            "fig1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "tab1", "tab2",
            "ablations",
        ]
        .contains(&which)
    {
        bail!("unknown experiment {which:?}");
    }
    Ok(())
}

fn parse_policy(s: &str) -> Result<BatchPolicy> {
    if s == "greedy" {
        return Ok(BatchPolicy::Greedy);
    }
    if let Some(ms) = s.strip_prefix("timeout:") {
        return Ok(BatchPolicy::Timeout(std::time::Duration::from_millis(
            ms.parse()?,
        )));
    }
    bail!("unknown policy {s:?} (greedy|timeout:<ms>)")
}

fn cmd_serve(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let policy = parse_policy(&args.get_str("policy", "greedy"))?;
    let schedule_dir = args.str_opt("schedule-dir");
    let cfg = fastdds::coordinator::CoordinatorCfg {
        max_inflight: args.usize_opt("max-inflight")?,
        queue_cap: args.usize_opt("queue-cap")?,
        ..Default::default()
    };
    let artifacts = match args.str_opt("registry-dir") {
        None => None,
        Some(root) => Some(fastdds::registry::ArtifactRegistry::open(root)?),
    };
    let coordinator = if args.flag("local") {
        // Explicitly requested in-process oracle backend: no artifacts
        // needed, all schedules (uniform/log/adaptive/tuned) available.
        // (Never an implicit fallback — a missing artifacts dir must stay
        // a hard startup error, not silently serve a synthetic oracle.)
        let which = args.get_str("oracle", "markov");
        let (oracle, vocab, seq_len): (
            std::sync::Arc<dyn fastdds::score::ScoreSource>,
            usize,
            usize,
        ) = if let Some(digest) = which.strip_prefix("digest:") {
            // Rebuild the oracle from a registry score_model artifact:
            // the artifact carries its own vocab/seq_len coordinates, so
            // --vocab/--seq-len are ignored on this path.
            let Some(reg) = artifacts.as_ref() else {
                bail!("--oracle digest:<hex> requires --registry-dir");
            };
            let (manifest, blobs) = reg.get(digest)?;
            let m = manifest.v1();
            if m.kind != fastdds::registry::ArtifactKind::ScoreModel {
                bail!(
                    "artifact {digest} is a {:?}, not a score_model",
                    m.kind.as_str()
                );
            }
            let Some(blob) = blobs.first() else {
                bail!("score_model artifact {digest} has no blobs");
            };
            fastdds::registry::oracle_from_score_model(blob)?
        } else {
            let vocab = args.get_usize("vocab", 16)?;
            let seq_len = args.get_usize("seq-len", 32)?;
            let mut rng = Xoshiro256::seed_from_u64(args.get_u64("oracle-seed", 23)?);
            let chain =
                fastdds::score::markov::MarkovChain::generate(&mut rng, vocab, 0.5);
            let oracle: std::sync::Arc<dyn fastdds::score::ScoreSource> =
                match which.as_str() {
                    // Uniform-state HMM oracle: `--solver exact` then runs
                    // bracketed windowed uniformization, tunable with the
                    // client's --window-ratio / --slack knobs.
                    "hmm" => std::sync::Arc::new(
                        fastdds::score::hmm::HmmUniformOracle::new(chain, seq_len),
                    ),
                    "markov" => std::sync::Arc::new(
                        fastdds::score::markov::MarkovOracle::new(chain, seq_len),
                    ),
                    other => bail!("unknown --oracle {other:?} (markov|hmm|digest:<hex>)"),
                };
            (oracle, vocab, seq_len)
        };
        println!("serving local {which} oracle (vocab {vocab}, seq_len {seq_len})");
        Coordinator::start_local_with_registry(
            oracle,
            policy,
            args.get_usize("max-lanes", 8)?,
            schedule_dir,
            cfg,
            artifacts,
        )
    } else {
        let runtime = RuntimeHandle::spawn(&dir)?;
        let registry = Registry::load(&dir)?;
        // Warm-up: compile the markov step family before accepting traffic.
        let names: Vec<String> = registry
            .by_family("markov")
            .iter()
            .map(|a| a.name.clone())
            .collect();
        runtime.preload(&names.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;
        Coordinator::start_with_registry(runtime, registry, policy, schedule_dir, cfg, artifacts)
    };
    let max_conns =
        args.get_usize("max-conns", fastdds::server::DEFAULT_MAX_CONNS)?;
    let server = fastdds::server::Server::start_with_limit(&addr, coordinator, max_conns)?;
    println!("fastdds serving on {} (policy {:?})", server.addr, policy);
    println!("press ctrl-c to stop");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Build the request spec from the CLI flags (or `--spec file.json`),
/// through the validating builder — invalid combinations fail here with
/// the same typed error the server would produce.
fn client_spec(args: &Args) -> Result<SamplingSpec> {
    if let Some(path) = args.str_opt("spec") {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text)?;
        // Accept a bare spec object or a full {"v":2,"spec":...} envelope.
        let spec_obj = match j.opt("spec") {
            Some(inner) => inner,
            None => &j,
        };
        return Ok(wire::spec_from_json(spec_obj)?);
    }
    let solver = Solver::parse(&args.get_str("solver", "trapezoidal:0.5"))?;
    let mut b = SamplingSpec::builder()
        .family(&args.get_str("family", "markov"))
        .solver(solver)
        .nfe(args.get_usize("nfe", 64)?)
        .n_samples(args.get_usize("n", 1)?)
        .seed(args.get_u64("seed", 0)?)
        .nfe_budget(args.usize_opt("nfe-budget")?)
        .window_ratio(args.f64_opt("window-ratio")?)
        .slack(args.f64_opt("slack")?)
        .max_events(args.usize_opt("max-events")?)
        .pit(args.flag("pit"))
        .sweeps_max(args.usize_opt("sweeps-max")?)
        .tol(args.f64_opt("tol")?)
        .progress(args.flag("progress"))
        .no_degrade(args.flag("no-degrade"))
        .deadline_ms(args.usize_opt("deadline-ms")?.map(|ms| ms as u64));
    if let Some(p) = args.usize_opt("priority")? {
        let p = u8::try_from(p).map_err(|_| {
            anyhow::anyhow!("--priority {p} does not fit in a byte")
        })?;
        b = b.priority(p);
    }
    if let Some(s) = args.str_opt("schedule") {
        b = b.schedule(ScheduleSpec::parse(s)?);
    }
    Ok(b.build()?)
}

fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let timeout = args
        .usize_opt("timeout-ms")?
        .map(|ms| std::time::Duration::from_millis(ms as u64));
    let mut client = fastdds::server::client::Client::connect_with(&addr, timeout)?;
    let spec = client_spec(args)?;
    let request_key = args.str_opt("request-key");
    let resp = if args.flag("stream") {
        let id = client.start_stream_keyed(&spec, request_key)?;
        println!("accepted id={id} (interrupt with: fastdds cancel --id {id})");
        let out = client.finish_stream(spec.n_samples())?;
        if out.progress_frames > 0 {
            println!(
                "streamed {} chunk(s), {} progress frame(s)",
                out.chunks, out.progress_frames
            );
        } else {
            println!("streamed {} chunk(s)", out.chunks);
        }
        out.response
    } else {
        client.generate_spec_keyed(&spec, request_key)?
    };
    println!(
        "id={} nfe_used={} latency_ms={:.2}{}{}",
        resp.id,
        resp.nfe_used,
        resp.latency_ms,
        if resp.partial { " (PARTIAL)" } else { "" },
        match resp.degraded {
            Some(rung) => format!(" (DEGRADED rung {rung})"),
            None => String::new(),
        }
    );
    for s in &resp.sequences {
        println!("{}", fastdds::data::corpus::decode_pretty(s, 64));
    }
    println!("{}", client.metrics()?);
    Ok(())
}

/// `fastdds cancel --id N [--addr ...]`: fire the cancel verb.
fn cmd_cancel(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let timeout = args
        .usize_opt("timeout-ms")?
        .map(|ms| std::time::Duration::from_millis(ms as u64));
    let mut client = fastdds::server::client::Client::connect_with(&addr, timeout)?;
    let id = args.get_u64("id", 0)?;
    let found = client.cancel(id)?;
    println!("id={id} cancelled={found}");
    Ok(())
}

/// `fastdds registry <put|get|stat|list>`: drive the content-addressed
/// artifact registry over the wire (the server must be running with
/// `--registry-dir`, else every verb fails typed `registry_disabled`).
fn cmd_registry(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7878");
    let timeout = args
        .usize_opt("timeout-ms")?
        .map(|ms| std::time::Duration::from_millis(ms as u64));
    let mut client = fastdds::server::client::Client::connect_with(&addr, timeout)?;
    let verb = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    match verb {
        "put" => {
            let mut m = fastdds::registry::ManifestV1::new(
                fastdds::registry::ArtifactKind::parse(
                    &args.get_str("kind", "compat_corpus"),
                )?,
                &args.get_str("name", "unnamed"),
            );
            m.family = args.get_str("family", "");
            m.vocab = args.get_usize("vocab", 0)?;
            m.seq_len = args.get_usize("seq-len", 0)?;
            m.solver = args.get_str("solver", "");
            m.steps = args.get_usize("steps", 0)?;
            m.created_by = args.get_str("created-by", "fastdds-cli");
            let mut blobs: Vec<Vec<u8>> = Vec::new();
            if let Some(list) = args.str_opt("blobs") {
                for path in list.split(',').filter(|s| !s.is_empty()) {
                    blobs.push(std::fs::read(path)?);
                }
            }
            if let Some(oracle) = args.str_opt("oracle") {
                // Synthesize the score_model blob that `serve --oracle
                // digest:<hex>` consumes; the blob's actual coordinates
                // override whatever kind/family/shape flags were given.
                if oracle != "markov" && oracle != "hmm" {
                    bail!("--oracle {oracle:?} (markov|hmm)");
                }
                let vocab = args.get_usize("vocab", 16)?;
                let seq_len = args.get_usize("seq-len", 32)?;
                let mut rng =
                    Xoshiro256::seed_from_u64(args.get_u64("oracle-seed", 23)?);
                let chain = fastdds::score::markov::MarkovChain::generate(
                    &mut rng, vocab, 0.5,
                );
                blobs.push(fastdds::registry::score_model_blob(
                    oracle, &chain, seq_len,
                ));
                m.kind = fastdds::registry::ArtifactKind::ScoreModel;
                m.family = oracle.to_string();
                m.vocab = vocab;
                m.seq_len = seq_len;
            }
            if blobs.is_empty() {
                bail!("registry put needs --blobs f1,f2,... or --oracle markov|hmm");
            }
            let digest = client.registry_put(&m, &blobs)?;
            println!("{digest}");
        }
        "get" => {
            let digest = require_digest(args)?;
            let (manifest, blobs) = client.registry_get(digest)?;
            print_manifest(digest, &manifest);
            let stem = digest.get(..16).unwrap_or(digest);
            if let Some(out) = args.str_opt("out") {
                std::fs::create_dir_all(out)?;
                for (i, b) in blobs.iter().enumerate() {
                    let path = format!("{out}/{stem}-{i}");
                    std::fs::write(&path, b)?;
                    println!("  blob {i}: {} bytes -> {path}", b.len());
                }
            } else {
                for (i, b) in blobs.iter().enumerate() {
                    println!("  blob {i}: {} bytes", b.len());
                }
            }
        }
        "stat" => {
            let digest = require_digest(args)?;
            let (manifest, blobs) = client.registry_stat(digest)?;
            print_manifest(digest, &manifest);
            for (i, (d, size)) in blobs.iter().enumerate() {
                match size {
                    Some(n) => println!("  blob {i}: {d} ({n} bytes)"),
                    None => println!("  blob {i}: {d} (MISSING)"),
                }
            }
        }
        "list" => {
            let kind = match args.str_opt("kind") {
                None => None,
                Some(k) => Some(fastdds::registry::ArtifactKind::parse(k)?),
            };
            let arts = client.registry_list(kind, args.str_opt("family"))?;
            for (digest, m) in &arts {
                let v1 = m.v1();
                println!(
                    "{digest} kind={} name={:?} family={:?} vocab={} seq_len={}",
                    v1.kind.as_str(),
                    v1.name,
                    v1.family,
                    v1.vocab,
                    v1.seq_len
                );
            }
            println!("{} artifact(s)", arts.len());
        }
        other => bail!("unknown registry verb {other:?} (put|get|stat|list)"),
    }
    Ok(())
}

fn require_digest(args: &Args) -> Result<&str> {
    args.str_opt("digest")
        .ok_or_else(|| anyhow::anyhow!("--digest <64 hex> is required"))
}

fn print_manifest(digest: &str, m: &fastdds::registry::Manifest) {
    let v1 = m.v1();
    println!(
        "{digest}\n  kind={} name={:?} family={:?} vocab={} seq_len={} \
         solver={:?} steps={} created_by={:?}",
        v1.kind.as_str(),
        v1.name,
        v1.family,
        v1.vocab,
        v1.seq_len,
        v1.solver,
        v1.steps,
        v1.created_by
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.get_str("artifacts", "artifacts");
    if !fastdds::runtime::artifacts_available(&dir) {
        bail!("no artifacts at {dir:?}; run `make artifacts`");
    }
    let registry = Registry::load(&dir)?;
    println!("artifacts in {dir:?}:");
    for name in registry.names() {
        let spec = registry.get(name)?;
        println!(
            "  {name:32} family={:12} nfe/step={} inputs={}",
            spec.family,
            spec.nfe_per_step,
            spec.inputs.len()
        );
    }
    Ok(())
}
