//! Batch-compatibility keys, derived mechanically from the typed spec.
//!
//! Two requests may share a batch **iff** their lanes would execute
//! identically — same family, same per-step kernel, same resolved
//! discretisation (or exact-path configuration).  [`BatchKey::of`] hashes
//! exactly [`SamplingSpec::plan`] plus the kernel identity, so the key can
//! never under-encode a knob the scheduler consumes (the pre-redesign
//! failure mode that forced duplicate validation at coordinator intake):
//! the scheduler executes *from the same plan the key hashes*.
//!
//! Because the plan is resolved, grouping improves for free relative to the
//! raw-knob key:
//!
//! - requests whose raw NFE differs but resolves to the same grid
//!   (`nfe=64` vs `nfe=65`, two-stage) now co-batch;
//! - exact requests explicitly passing the default knobs co-batch with
//!   knob-free ones (resolution happens in the builder);
//! - adaptive requests group by (family, solver, tol, dt0, budget) — the
//!   "error-aware batching" grouping of same-tolerance lanes that PR 3
//!   left as a follow-up falls out of the derivation.

use crate::api::spec::{ExecPlan, SamplingSpec};
use crate::solvers::Solver;
use crate::testkit::fnv1a;

/// Compatibility key: lanes co-batch iff their keys are equal.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct BatchKey {
    pub family_hash: u64,
    /// Kernel identity: solver discriminant + θ bits (exact f64) for the
    /// two-stage schemes.
    pub solver_kind: u8,
    pub theta_bits: u64,
    /// Resolved execution identity ([`ExecPlan`] discriminant + payload).
    pub plan_kind: u8,
    pub plan_a: u64,
    pub plan_b: u64,
    pub plan_c: u64,
}

impl BatchKey {
    pub fn of(spec: &SamplingSpec) -> BatchKey {
        let (solver_kind, theta) = match spec.solver() {
            Solver::Euler => (0u8, 0.0),
            Solver::TauLeaping => (1, 0.0),
            Solver::Tweedie => (2, 0.0),
            Solver::Trapezoidal { theta } => (3, theta),
            Solver::Rk2 { theta } => (4, theta),
            Solver::ParallelDecoding => (5, 0.0),
            Solver::Exact => (6, 0.0),
            Solver::Midpoint { theta } => (7, theta),
        };
        let (plan_kind, plan_a, plan_b, plan_c) = match spec.plan() {
            ExecPlan::Uniform { steps } => (0u8, steps as u64, 0, 0),
            ExecPlan::Log { steps } => (1, steps as u64, 0, 0),
            ExecPlan::Tuned { steps } => (2, steps as u64, 0, 0),
            ExecPlan::Adaptive { tol, dt0, budget } => (
                3,
                tol.to_bits(),
                dt0.to_bits(),
                budget.map(|b| b as u64 + 1).unwrap_or(0),
            ),
            ExecPlan::Exact { cfg, max_events } => (
                4,
                cfg.window_ratio.to_bits(),
                cfg.slack.to_bits(),
                max_events.map(|m| m as u64 + 1).unwrap_or(0),
            ),
            ExecPlan::Pit { steps, sweeps_max, tol } => {
                (5, steps as u64, sweeps_max as u64, tol.to_bits())
            }
        };
        BatchKey {
            family_hash: fnv1a(spec.family()),
            solver_kind,
            theta_bits: theta.to_bits(),
            plan_kind,
            plan_a,
            plan_b,
            plan_c,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ctmc::uniformization::{DEFAULT_SLACK, DEFAULT_WINDOW_RATIO};
    use crate::schedule::ScheduleSpec;

    fn spec(solver: Solver, nfe: usize) -> crate::api::spec::SpecBuilder {
        SamplingSpec::builder().solver(solver).nfe(nfe)
    }

    #[test]
    fn key_splits_on_every_execution_coordinate() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        let base = BatchKey::of(&spec(trap, 32).build().unwrap());
        assert_eq!(base, BatchKey::of(&spec(trap, 32).build().unwrap()));
        // Different θ, different solver, different family, different
        // schedule, different budget → different keys.
        assert_ne!(
            base,
            BatchKey::of(&spec(Solver::Trapezoidal { theta: 0.3 }, 32).build().unwrap())
        );
        assert_ne!(base, BatchKey::of(&spec(Solver::TauLeaping, 32).build().unwrap()));
        assert_ne!(
            base,
            BatchKey::of(&spec(trap, 32).family("toy").build().unwrap())
        );
        assert_ne!(
            base,
            BatchKey::of(
                &spec(trap, 32).schedule(ScheduleSpec::Adaptive { tol: 1e-3 }).build().unwrap()
            )
        );
        assert_ne!(
            base,
            BatchKey::of(&spec(trap, 32).nfe_budget(Some(17)).build().unwrap())
        );
    }

    #[test]
    fn key_groups_equal_resolved_grids() {
        // nfe=64 and nfe=65 resolve to the same 32-step uniform grid for a
        // two-stage scheme: same key (the pre-redesign raw-knob key split
        // them for no execution reason).
        let trap = Solver::Trapezoidal { theta: 0.5 };
        assert_eq!(
            BatchKey::of(&spec(trap, 64).build().unwrap()),
            BatchKey::of(&spec(trap, 65).build().unwrap())
        );
        // A budget that caps to the same step count also groups.
        assert_eq!(
            BatchKey::of(&spec(trap, 32).build().unwrap()),
            BatchKey::of(&spec(trap, 64).nfe_budget(Some(33)).build().unwrap())
        );
    }

    #[test]
    fn exact_keys_use_resolved_knobs() {
        let bare = BatchKey::of(&spec(Solver::Exact, 16).build().unwrap());
        let explicit = BatchKey::of(
            &spec(Solver::Exact, 16)
                .window_ratio(Some(DEFAULT_WINDOW_RATIO))
                .slack(Some(DEFAULT_SLACK))
                .build()
                .unwrap(),
        );
        assert_eq!(bare, explicit, "explicit defaults must co-batch with knob-free");
        let tuned = BatchKey::of(
            &spec(Solver::Exact, 16).slack(Some(8.0)).build().unwrap(),
        );
        assert_ne!(bare, tuned);
        let ratio = BatchKey::of(
            &spec(Solver::Exact, 16).window_ratio(Some(0.9)).build().unwrap(),
        );
        assert_ne!(bare, ratio);
        let capped = BatchKey::of(
            &spec(Solver::Exact, 16).max_events(Some(50)).build().unwrap(),
        );
        assert_ne!(bare, capped);
        // Exact ignores its (historically required) nfe field entirely.
        assert_eq!(
            bare,
            BatchKey::of(&spec(Solver::Exact, 999).build().unwrap())
        );
    }

    #[test]
    fn qos_knobs_never_split_a_batch() {
        // deadline_ms/priority are serving QoS, not execution identity:
        // requests differing only in them MUST co-batch (the key hashes the
        // plan, which never sees them — pinned here so it stays true).
        let trap = Solver::Trapezoidal { theta: 0.5 };
        let base = BatchKey::of(&spec(trap, 32).build().unwrap());
        assert_eq!(
            base,
            BatchKey::of(&spec(trap, 32).deadline_ms(Some(100)).build().unwrap())
        );
        assert_eq!(
            base,
            BatchKey::of(&spec(trap, 32).priority(3).build().unwrap())
        );
        assert_eq!(
            base,
            BatchKey::of(
                &spec(trap, 32).deadline_ms(Some(5)).priority(0).build().unwrap()
            )
        );
    }

    #[test]
    fn pit_keys_split_from_sequential_and_group_resolved() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        let seq = BatchKey::of(&spec(trap, 64).build().unwrap());
        let pit = BatchKey::of(&spec(trap, 64).pit(true).build().unwrap());
        // Same grid, different driver → different keys (PIT lanes share
        // sweep structure; mixing them with sequential lanes is invalid).
        assert_ne!(seq, pit);
        // Explicit resolved defaults co-batch with knob-free PIT.
        assert_eq!(
            pit,
            BatchKey::of(
                &spec(trap, 64).pit(true).sweeps_max(Some(32)).tol(Some(0.0)).build().unwrap()
            )
        );
        // Raw NFE resolving to the same grid groups, as for sequential.
        assert_eq!(pit, BatchKey::of(&spec(trap, 65).pit(true).build().unwrap()));
        // Every PIT coordinate splits.
        assert_ne!(
            pit,
            BatchKey::of(&spec(trap, 64).pit(true).sweeps_max(Some(8)).build().unwrap())
        );
        assert_ne!(
            pit,
            BatchKey::of(&spec(trap, 64).pit(true).tol(Some(0.1)).build().unwrap())
        );
        // Midpoint gets its own kernel identity (θ bits included).
        let mid = BatchKey::of(&spec(Solver::Midpoint { theta: 0.5 }, 64).build().unwrap());
        assert_ne!(mid, BatchKey::of(&spec(Solver::Rk2 { theta: 0.5 }, 64).build().unwrap()));
        assert_ne!(
            mid,
            BatchKey::of(&spec(Solver::Midpoint { theta: 0.75 }, 64).build().unwrap())
        );
        // Progress is QoS: never splits.
        assert_eq!(pit, BatchKey::of(&spec(trap, 64).pit(true).progress(true).build().unwrap()));
    }

    #[test]
    fn adaptive_keys_group_same_tolerance_lanes() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        let mk = |nfe: usize, tol: f64, budget: Option<usize>| {
            BatchKey::of(
                &spec(trap, nfe)
                    .schedule(ScheduleSpec::Adaptive { tol })
                    .nfe_budget(budget)
                    .build()
                    .unwrap(),
            )
        };
        // Same tol + same dt0 + same budget → same key (error-aware
        // batching); any coordinate differing → split.
        assert_eq!(mk(64, 1e-3, None), mk(64, 1e-3, None));
        assert_eq!(mk(64, 1e-3, None), mk(65, 1e-3, None), "same dt0 must group");
        assert_ne!(mk(64, 1e-3, None), mk(64, 2e-3, None));
        assert_ne!(mk(64, 1e-3, None), mk(32, 1e-3, None));
        assert_ne!(mk(64, 1e-3, None), mk(64, 1e-3, Some(24)));
    }
}
