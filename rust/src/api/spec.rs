//! The typed request surface: [`SamplingSpec`] + its validating builder.
//!
//! Every knob a client can set lives in exactly one place.  The spec's
//! fields are private and the only constructor is [`SpecBuilder::build`],
//! so a `SamplingSpec` value *is* the proof that its knob combination is
//! valid — downstream layers (batcher, scheduler, driver) consume it
//! without re-validating, and invalid combinations are caught at the wire
//! boundary with a typed [`SpecError`].
//!
//! Illegal combinations are unrepresentable by shape where the type system
//! can carry it: [`SolverCfg::Exact`] has no `nfe_budget` or `schedule`
//! field (exact simulation cannot honor either), and
//! [`SolverCfg::Scheme`] has no `window_ratio`/`slack`/`max_events` (the
//! uniformization knobs mean nothing to a grid scheme).  What the shape
//! cannot carry — θ ranges, the slack floor, budget minima — the builder
//! checks once.
//!
//! [`SamplingSpec::plan`] derives the *execution identity* mechanically:
//! the resolved discretisation (or exact-path configuration) that fully
//! determines how a lane runs.  `api::key::BatchKey` hashes exactly that
//! plan, so two requests co-batch **iff** they would execute identically —
//! co-batch laundering (smuggling a knob through a key that does not
//! encode it) is impossible by construction, and requests whose raw knobs
//! differ but resolve to the same discretisation (e.g. `nfe=64` vs
//! `nfe=65` for a two-stage scheme) now share a batch for free.

use crate::ctmc::uniformization::{ExactCfg, DEFAULT_SLACK, DEFAULT_WINDOW_RATIO};
use crate::schedule::ScheduleSpec;
use crate::solvers::Solver;
use std::fmt;

/// Serving-wide early-stop time δ of the backward pass (the value the
/// pre-redesign scheduler hardcoded; re-exported there for compatibility).
pub const DELTA: f64 = 1e-3;

/// Upper bound on a client-requested tuned-grid step count (each distinct
/// count triggers one offline tuner fit, so it must stay sane).
pub const MAX_TUNED_STEPS: usize = 512;

/// Per-lane RNG stream spread: lane i of a request draws from
/// `seed.wrapping_add(i * LANE_SEED_STRIDE)` (the golden-ratio increment
/// the batcher has always used — part of the wire contract, since clients
/// replay samples from it).
pub const LANE_SEED_STRIDE: u64 = 0x9E3779B97F4A7C15;

/// Priority assumed when a request does not set one.  Deliberately in the
/// middle of the range so callers can mark traffic as *either* more or
/// less important than the default.
pub const DEFAULT_PRIORITY: u8 = 1;

/// Highest accepted priority (inclusive).  Small on purpose: priorities
/// are shedding classes, not a fine-grained fairness dial.
pub const MAX_PRIORITY: u8 = 3;

/// NFE floor of the brownout ladder's final rung ([`SamplingSpec::degrade`]
/// rung 3): overload never clamps a request below this budget (or below
/// one solver step, whichever is higher), so even maximally degraded
/// responses stay useful samples rather than noise.
pub const DEGRADE_NFE_FLOOR: usize = 8;

/// Number of rungs on the brownout ladder (see [`SamplingSpec::degrade`]).
pub const MAX_DEGRADE_RUNG: u8 = 3;

/// Solver configuration: the typed half of the request surface where the
/// *shape* makes invalid knob combinations unrepresentable.
#[derive(Clone, Debug, PartialEq)]
pub enum SolverCfg {
    /// A grid scheme (everything except exact simulation).
    Scheme {
        /// Never [`Solver::Exact`] (the builder routes that to
        /// [`SolverCfg::Exact`]).
        solver: Solver,
        schedule: ScheduleSpec,
        /// Score-evaluation budget per sample (the paper's NFE axis); sets
        /// the step count for fixed schedules, seeds dt for adaptive ones.
        nfe: usize,
        /// Optional HARD per-sample cap (terminal denoise included).
        nfe_budget: Option<usize>,
    },
    /// Parallel-in-time (Picard) execution of a grid scheme
    /// ([`crate::solvers::pit`]): the same per-step update as
    /// [`SolverCfg::Scheme`], but iterated in whole-trajectory sweeps so
    /// latency scales with the sweep count, not the NFE.  Knobs are stored
    /// RESOLVED (`sweeps_max`/`tol` defaults filled), so explicit-default
    /// requests co-batch with knob-free ones.  v1 is uniform-grid only and
    /// carries no `nfe_budget`: the sweep cap, not an NFE cap, bounds the
    /// run (see [`SamplingSpec::planned_nfe`] for the admission bound).
    Pit {
        /// Never [`Solver::Exact`] (exact simulation has no grid to
        /// iterate) — the builder rejects that combination typed.
        solver: Solver,
        /// Sequential-equivalent NFE: resolves to the step count exactly
        /// as the uniform [`SolverCfg::Scheme`] path would.
        nfe: usize,
        /// Hard sweep cap, >= 1.  A lane that exhausts it returns a typed
        /// partial (the converged prefix) — the divergence guard.
        sweeps_max: usize,
        /// Convergence tolerance fed by the embedded two-stage error
        /// estimator; `0.0` demands the exact fixed point (bit-parity
        /// with the sequential driver on the same seed).
        tol: f64,
    },
    /// Exact simulation (first-hitting / windowed uniformization).  The
    /// knobs are stored RESOLVED (defaults filled), so an explicit request
    /// for the default values is indistinguishable from a knob-free one —
    /// including in the batch key.
    Exact {
        /// Geometric uniformization window ratio, in (0, 1).
        window_ratio: f64,
        /// Thinning safety factor, >= 1 and >= the drift floor.
        slack: f64,
        /// Optional cap on accepted events: a run that exhausts it stops
        /// and returns a partial result (exact NFE is realized, not
        /// planned — this is the only way to bound it).
        max_events: Option<usize>,
    },
}

/// A fully validated, fully resolved generation request (minus the serving
/// id, which the coordinator assigns).  Construct via [`SamplingSpec::builder`].
#[derive(Clone, Debug, PartialEq)]
pub struct SamplingSpec {
    family: String,
    n_samples: usize,
    seed: u64,
    cfg: SolverCfg,
    /// Serving QoS knobs.  Deliberately OUTSIDE [`SolverCfg`] and never
    /// consulted by [`SamplingSpec::plan`]: two requests that differ only
    /// in deadline or priority execute identically and must co-batch
    /// (`BatchKey` hashes the plan, so this holds by construction).
    deadline_ms: Option<u64>,
    priority: u8,
    /// Opt-in per-window/per-sweep progress frames on streaming
    /// responses.  QoS-only like the fields above: never consulted by
    /// [`SamplingSpec::plan`], so it cannot split batches.
    progress: bool,
    /// Opt out of the brownout degradation ladder: an overloaded
    /// coordinator may not trade this request's quality for survival
    /// ([`SamplingSpec::degrade`] is never applied; such requests shed
    /// typed `overloaded` as before the ladder existed).  QoS-only like
    /// the fields above: never consulted by [`SamplingSpec::plan`].
    no_degrade: bool,
}

/// The resolved execution identity of a spec: everything that decides how
/// a lane runs, with raw knobs folded into their effect.  Pure function of
/// the spec; `BatchKey` hashes it verbatim.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecPlan {
    /// Uniform grid with this many steps (budget already folded in).
    Uniform { steps: usize },
    /// Log-spaced grid with this many steps.
    Log { steps: usize },
    /// Offline-tuned grid with this many steps (0-steps requests and
    /// budget caps already resolved).
    Tuned { steps: usize },
    /// Online error control: tolerance, initial dt, optional hard budget.
    Adaptive { tol: f64, dt0: f64, budget: Option<usize> },
    /// Parallel-in-time Picard sweeps over a uniform grid of this many
    /// steps, capped at `sweeps_max` sweeps, converging at `tol`.
    Pit { steps: usize, sweeps_max: usize, tol: f64 },
    /// Exact simulation under these knobs.
    Exact { cfg: ExactCfg, max_events: Option<usize> },
}

impl SamplingSpec {
    pub fn builder() -> SpecBuilder {
        SpecBuilder::default()
    }

    pub fn family(&self) -> &str {
        &self.family
    }

    pub fn n_samples(&self) -> usize {
        self.n_samples
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn cfg(&self) -> &SolverCfg {
        &self.cfg
    }

    /// The solver enum ([`Solver::Exact`] for the exact variant).
    pub fn solver(&self) -> Solver {
        match &self.cfg {
            SolverCfg::Scheme { solver, .. } | SolverCfg::Pit { solver, .. } => *solver,
            SolverCfg::Exact { .. } => Solver::Exact,
        }
    }

    /// Requested NFE (0 for exact specs, whose NFE is realized, not
    /// planned).
    pub fn nfe(&self) -> usize {
        match &self.cfg {
            SolverCfg::Scheme { nfe, .. } | SolverCfg::Pit { nfe, .. } => *nfe,
            SolverCfg::Exact { .. } => 0,
        }
    }

    pub fn schedule(&self) -> ScheduleSpec {
        match &self.cfg {
            SolverCfg::Scheme { schedule, .. } => *schedule,
            // PIT v1 is uniform-only by construction.
            SolverCfg::Pit { .. } | SolverCfg::Exact { .. } => ScheduleSpec::Uniform,
        }
    }

    pub fn nfe_budget(&self) -> Option<usize> {
        match &self.cfg {
            SolverCfg::Scheme { nfe_budget, .. } => *nfe_budget,
            SolverCfg::Pit { .. } | SolverCfg::Exact { .. } => None,
        }
    }

    /// Whether this spec runs the parallel-in-time driver.
    pub fn pit(&self) -> bool {
        matches!(self.cfg, SolverCfg::Pit { .. })
    }

    /// Resolved PIT sweep cap (`None` for non-PIT specs).
    pub fn sweeps_max(&self) -> Option<usize> {
        match &self.cfg {
            SolverCfg::Pit { sweeps_max, .. } => Some(*sweeps_max),
            _ => None,
        }
    }

    /// Resolved PIT convergence tolerance (`None` for non-PIT specs).
    pub fn pit_tol(&self) -> Option<f64> {
        match &self.cfg {
            SolverCfg::Pit { tol, .. } => Some(*tol),
            _ => None,
        }
    }

    /// Resolved exact-path knobs (library defaults for scheme specs, which
    /// never reach the exact path).
    pub fn exact_cfg(&self) -> ExactCfg {
        match &self.cfg {
            SolverCfg::Exact { window_ratio, slack, .. } => {
                ExactCfg { window_ratio: *window_ratio, slack: *slack }
            }
            SolverCfg::Scheme { .. } | SolverCfg::Pit { .. } => ExactCfg::default(),
        }
    }

    pub fn max_events(&self) -> Option<usize> {
        match &self.cfg {
            SolverCfg::Exact { max_events, .. } => *max_events,
            SolverCfg::Scheme { .. } | SolverCfg::Pit { .. } => None,
        }
    }

    /// Wall-clock budget for the whole request, measured from coordinator
    /// intake.  `None` = no deadline.  Enforced at the driver's per-window
    /// cancel poll; an expired run returns a partial response.
    pub fn deadline_ms(&self) -> Option<u64> {
        self.deadline_ms
    }

    /// Shedding class in `0..=MAX_PRIORITY` (higher = kept longer under
    /// overload).  Defaults to [`DEFAULT_PRIORITY`].
    pub fn priority(&self) -> u8 {
        self.priority
    }

    /// Whether the client opted into per-window/per-sweep progress frames
    /// on streaming responses.  QoS-only; never splits a batch.
    pub fn progress(&self) -> bool {
        self.progress
    }

    /// Whether the client opted out of the brownout degradation ladder.
    /// QoS-only; never splits a batch.
    pub fn no_degrade(&self) -> bool {
        self.no_degrade
    }

    /// Walk this spec down the brownout ladder to (at most) `rung`,
    /// returning the degraded spec and the highest rung that **actually
    /// changed** it — `None` when no rung applies (the spec is already at
    /// or below the ladder floor, or exact: exact simulation has no
    /// quality knob the ladder could trade, so it never degrades).
    ///
    /// The ladder is cumulative and pre-declared:
    ///
    /// 1. parallel-in-time off — PIT specs fall back to the sequential
    ///    uniform-grid scheme at the same NFE (sweeps no longer amplify
    ///    the worst-case admission bound);
    /// 2. schedule to uniform — tuned/log/adaptive schedules drop to the
    ///    uniform grid (no pilot fits, no online control);
    /// 3. NFE clamped to [`DEGRADE_NFE_FLOOR`] (or one solver step,
    ///    whichever is higher).
    ///
    /// Every output is produced by rewriting the typed [`SolverCfg`], so a
    /// degraded spec is still a valid spec by construction and resolves to
    /// a valid typed [`ExecPlan`].  QoS fields (deadline, priority,
    /// progress, `no_degrade` itself) are untouched; callers are expected
    /// to consult [`SamplingSpec::no_degrade`] *before* degrading.
    pub fn degrade(&self, rung: u8) -> Option<(SamplingSpec, u8)> {
        let mut cfg = self.cfg.clone();
        let mut applied = 0u8;
        if rung >= 1 {
            if let SolverCfg::Pit { solver, nfe, .. } = &cfg {
                let (solver, nfe) = (*solver, *nfe);
                cfg = SolverCfg::Scheme {
                    solver,
                    schedule: ScheduleSpec::Uniform,
                    nfe,
                    nfe_budget: None,
                };
                applied = 1;
            }
        }
        if rung >= 2 {
            if let SolverCfg::Scheme { schedule, .. } = &mut cfg {
                if *schedule != ScheduleSpec::Uniform {
                    *schedule = ScheduleSpec::Uniform;
                    applied = 2;
                }
            }
        }
        if rung >= 3 {
            if let SolverCfg::Scheme { solver, nfe, .. } = &mut cfg {
                let floor = DEGRADE_NFE_FLOOR.max(solver.nfe_per_step());
                if *nfe > floor {
                    *nfe = floor;
                    applied = 3;
                }
            }
        }
        (applied > 0).then(|| (SamplingSpec { cfg, ..self.clone() }, applied))
    }

    /// Score evaluations this spec is *planned* to spend per lane,
    /// terminal denoise included — the admission-control cost model.
    /// `None` means the plan cannot bound its own NFE up front (exact
    /// simulation with no `max_events` cap): such requests are never
    /// rejected as infeasible, only bounded by their deadline at runtime.
    pub fn planned_nfe(&self) -> Option<usize> {
        match self.plan() {
            ExecPlan::Uniform { steps } | ExecPlan::Log { steps } | ExecPlan::Tuned { steps } => {
                Some(steps * self.solver().nfe_per_step() + 1)
            }
            ExecPlan::Adaptive { dt0, budget, .. } => Some(match budget {
                Some(b) => b,
                // No hard budget: assume the controller keeps the seed dt.
                None => {
                    let steps = ((1.0 - DELTA) / dt0).ceil() as usize;
                    steps * self.solver().nfe_per_step() + 1
                }
            }),
            ExecPlan::Exact { max_events, .. } => max_events.map(|m| m + 1),
            // Worst case: every sweep re-evaluates every slice (plus the
            // terminal denoise).  Converged runs spend far less; the bound
            // is what admission control needs.
            ExecPlan::Pit { steps, sweeps_max, .. } => {
                Some(steps * self.solver().nfe_per_step() * sweeps_max + 1)
            }
        }
    }

    /// RNG stream seed of lane `sample_idx` (see [`LANE_SEED_STRIDE`]).
    pub fn lane_seed(&self, sample_idx: usize) -> u64 {
        self.seed
            .wrapping_add((sample_idx as u64).wrapping_mul(LANE_SEED_STRIDE))
    }

    /// Derive the execution identity (see [`ExecPlan`]).
    pub fn plan(&self) -> ExecPlan {
        match &self.cfg {
            SolverCfg::Exact { window_ratio, slack, max_events } => ExecPlan::Exact {
                cfg: ExactCfg { window_ratio: *window_ratio, slack: *slack },
                max_events: *max_events,
            },
            SolverCfg::Pit { solver, nfe, sweeps_max, tol } => ExecPlan::Pit {
                steps: solver.steps_for_nfe(*nfe),
                sweeps_max: *sweeps_max,
                tol: *tol,
            },
            SolverCfg::Scheme { solver, schedule, nfe, nfe_budget } => {
                // Step count of the fixed schedules: the request NFE capped
                // by the hard budget (one evaluation reserved for the
                // terminal denoise so the cap can never be exceeded).
                let fixed_steps = {
                    let eff = match nfe_budget {
                        Some(b) => (*nfe).min(b - 1),
                        None => *nfe,
                    };
                    solver.steps_for_nfe(eff)
                };
                match schedule {
                    ScheduleSpec::Uniform => ExecPlan::Uniform { steps: fixed_steps },
                    ScheduleSpec::Log => ExecPlan::Log { steps: fixed_steps },
                    ScheduleSpec::Tuned { steps } => {
                        let mut s = if *steps == 0 { fixed_steps } else { *steps };
                        if let Some(b) = nfe_budget {
                            s = s.min(solver.steps_for_nfe(b - 1));
                        }
                        ExecPlan::Tuned { steps: s }
                    }
                    ScheduleSpec::Adaptive { tol } => ExecPlan::Adaptive {
                        tol: *tol,
                        dt0: (1.0 - DELTA) / solver.steps_for_nfe(*nfe) as f64,
                        budget: *nfe_budget,
                    },
                }
            }
        }
    }
}

/// Typed validation errors of the request surface.  [`SpecError::code`] is
/// the stable machine-readable identifier the v2 wire protocol reports.
#[derive(Clone, Debug, PartialEq)]
pub enum SpecError {
    /// θ outside the scheme's second-order range.
    ThetaOutOfRange { scheme: &'static str, theta: f64 },
    /// An exact-only knob on a grid scheme.
    KnobNeedsExact { knob: &'static str, solver: &'static str },
    /// `nfe_budget` on exact simulation (its NFE is realized, not planned).
    BudgetOnExact,
    /// window_ratio outside (0, 1).
    WindowRatioOutOfRange { value: f64 },
    /// slack not finite or below 1.
    SlackOutOfRange { value: f64 },
    /// slack below the drift floor for the requested window ratio.
    SlackBelowFloor { slack: f64, window_ratio: f64, floor: f64 },
    /// max_events must be >= 1 when given.
    MaxEventsZero,
    /// nfe below one solver step.
    NfeBelowOneStep { nfe: usize, per_step: usize },
    /// nfe_budget below one step + the reserved terminal denoise.
    BudgetBelowMinimum { budget: usize, minimum: usize },
    /// Tuned step count above [`MAX_TUNED_STEPS`].
    TunedStepsTooLarge { steps: usize },
    /// Adaptive/tuned schedules need a two-stage scheme.
    NeedsTwoStage { what: &'static str, solver: &'static str },
    /// Adaptive tolerance not finite or negative.
    AdaptiveTolInvalid { tol: f64 },
    /// A PIT-only knob (`sweeps_max`/`tol`) without `pit`.
    KnobNeedsPit { knob: &'static str },
    /// PIT on exact simulation (no grid to iterate).
    PitNeedsScheme,
    /// PIT v1 runs uniform grids only.
    PitNeedsUniform { schedule: &'static str },
    /// `nfe_budget` on a PIT spec (sweeps are capped, not NFE).
    PitBudgetUnsupported,
    /// sweeps_max must be >= 1 when given.
    SweepsMaxZero,
    /// PIT tolerance not finite or negative.
    PitTolInvalid { tol: f64 },
    /// n_samples must be >= 1.
    NoSamples,
    /// deadline_ms must be >= 1 when given.
    DeadlineZero,
    /// priority above [`MAX_PRIORITY`].
    PriorityOutOfRange { priority: u8 },
    /// A wire-level field failed to parse (message from the field parser).
    Parse { field: &'static str, message: String },
    /// A required wire-level field is missing or ill-typed.
    MissingField { field: &'static str, message: String },
}

impl SpecError {
    /// Stable machine-readable error identifier (the v2 `"code"` field).
    pub fn code(&self) -> &'static str {
        match self {
            SpecError::ThetaOutOfRange { .. } => "theta_out_of_range",
            SpecError::KnobNeedsExact { .. } => "knob_needs_exact",
            SpecError::BudgetOnExact => "budget_on_exact",
            SpecError::WindowRatioOutOfRange { .. } => "window_ratio_out_of_range",
            SpecError::SlackOutOfRange { .. } => "slack_out_of_range",
            SpecError::SlackBelowFloor { .. } => "slack_below_floor",
            SpecError::MaxEventsZero => "max_events_zero",
            SpecError::NfeBelowOneStep { .. } => "nfe_below_one_step",
            SpecError::BudgetBelowMinimum { .. } => "budget_below_minimum",
            SpecError::TunedStepsTooLarge { .. } => "tuned_steps_too_large",
            SpecError::NeedsTwoStage { .. } => "needs_two_stage",
            SpecError::AdaptiveTolInvalid { .. } => "adaptive_tol_invalid",
            SpecError::KnobNeedsPit { .. } => "knob_needs_pit",
            SpecError::PitNeedsScheme => "pit_needs_scheme",
            SpecError::PitNeedsUniform { .. } => "pit_needs_uniform",
            SpecError::PitBudgetUnsupported => "pit_budget_unsupported",
            SpecError::SweepsMaxZero => "sweeps_max_zero",
            SpecError::PitTolInvalid { .. } => "pit_tol_invalid",
            SpecError::NoSamples => "no_samples",
            SpecError::DeadlineZero => "deadline_zero",
            SpecError::PriorityOutOfRange { .. } => "priority_out_of_range",
            SpecError::Parse { .. } => "parse_error",
            SpecError::MissingField { .. } => "missing_field",
        }
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpecError::ThetaOutOfRange { scheme, theta } => match *scheme {
                "rk2" => write!(
                    f,
                    "rk2 theta {theta} outside (0, 1/2] — second-order range of Thm. 5.5"
                ),
                "midpoint" => write!(
                    f,
                    "midpoint theta {theta} outside (0, 1] — the predictor leap \
                     must stay inside the window"
                ),
                _ => write!(
                    f,
                    "trapezoidal theta {theta} outside (0, 1) — second-order range of Thm. 5.4"
                ),
            },
            SpecError::KnobNeedsExact { knob, solver } => write!(
                f,
                "{knob} is an exact-simulation knob; solver {solver} ignores it"
            ),
            SpecError::BudgetOnExact => write!(
                f,
                "exact simulation cannot honor a hard nfe_budget: its NFE is the \
                 realized jump count (use max_events to bound the run, or an \
                 approximate scheme to cap spend)"
            ),
            SpecError::WindowRatioOutOfRange { value } => {
                write!(f, "window_ratio {value} outside (0, 1)")
            }
            SpecError::SlackOutOfRange { value } => write!(
                f,
                "slack {value} must be finite and >= 1 (a thinning bound inflation)"
            ),
            SpecError::SlackBelowFloor { slack, window_ratio, floor } => write!(
                f,
                "slack {slack} too small for window_ratio {window_ratio}: the \
                 thinning bound needs slack >= {floor:.2} to dominate the \
                 in-window intensity rise"
            ),
            SpecError::MaxEventsZero => write!(f, "max_events must be >= 1 when given"),
            SpecError::NfeBelowOneStep { nfe, per_step } => {
                write!(f, "nfe budget {nfe} below one step ({per_step})")
            }
            SpecError::BudgetBelowMinimum { budget, minimum } => write!(
                f,
                "nfe_budget {budget} below one step + terminal denoise ({minimum})"
            ),
            SpecError::TunedStepsTooLarge { steps } => write!(
                f,
                "tuned steps {steps} above the supported maximum {MAX_TUNED_STEPS}"
            ),
            SpecError::NeedsTwoStage { what, solver } => write!(
                f,
                "{what} need the embedded two-stage estimator (θ-trapezoidal or \
                 θ-RK-2), got {solver}"
            ),
            SpecError::AdaptiveTolInvalid { tol } => {
                write!(f, "adaptive tol {tol} must be finite and >= 0")
            }
            SpecError::KnobNeedsPit { knob } => write!(
                f,
                "{knob} is a parallel-in-time knob; set pit to use it"
            ),
            SpecError::PitNeedsScheme => write!(
                f,
                "exact simulation has no grid to iterate parallel-in-time; \
                 pit needs a grid scheme"
            ),
            SpecError::PitNeedsUniform { schedule } => write!(
                f,
                "pit runs uniform grids only (got {schedule} schedule)"
            ),
            SpecError::PitBudgetUnsupported => write!(
                f,
                "pit bounds work by sweeps_max, not an NFE cap; nfe_budget \
                 is unsupported on pit specs"
            ),
            SpecError::SweepsMaxZero => write!(f, "sweeps_max must be >= 1 when given"),
            SpecError::PitTolInvalid { tol } => {
                write!(f, "pit tol {tol} must be finite and >= 0")
            }
            SpecError::NoSamples => write!(f, "n_samples must be >= 1"),
            SpecError::DeadlineZero => write!(f, "deadline_ms must be >= 1 when given"),
            SpecError::PriorityOutOfRange { priority } => write!(
                f,
                "priority {priority} above the maximum {MAX_PRIORITY}"
            ),
            SpecError::Parse { field, message } => write!(f, "bad {field}: {message}"),
            SpecError::MissingField { field, message } => {
                write!(f, "field {field:?}: {message}")
            }
        }
    }
}

impl std::error::Error for SpecError {}

/// The one validating constructor of [`SamplingSpec`].  Mirrors the flat
/// knob surface (each CLI flag / wire field is one setter); `build`
/// assembles the typed [`SolverCfg`] and rejects invalid combinations.
#[derive(Clone, Debug)]
pub struct SpecBuilder {
    family: String,
    n_samples: usize,
    seed: u64,
    solver: Solver,
    nfe: usize,
    schedule: ScheduleSpec,
    nfe_budget: Option<usize>,
    window_ratio: Option<f64>,
    slack: Option<f64>,
    max_events: Option<usize>,
    pit: bool,
    sweeps_max: Option<usize>,
    tol: Option<f64>,
    deadline_ms: Option<u64>,
    priority: u8,
    progress: bool,
    no_degrade: bool,
}

impl Default for SpecBuilder {
    fn default() -> Self {
        SpecBuilder {
            family: "markov".into(),
            n_samples: 1,
            seed: 0,
            solver: Solver::Tweedie,
            nfe: 16,
            schedule: ScheduleSpec::Uniform,
            nfe_budget: None,
            window_ratio: None,
            slack: None,
            max_events: None,
            pit: false,
            sweeps_max: None,
            tol: None,
            deadline_ms: None,
            priority: DEFAULT_PRIORITY,
            progress: false,
            no_degrade: false,
        }
    }
}

impl SpecBuilder {
    pub fn family(mut self, family: &str) -> Self {
        self.family = family.to_string();
        self
    }

    pub fn n_samples(mut self, n: usize) -> Self {
        self.n_samples = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn solver(mut self, solver: Solver) -> Self {
        self.solver = solver;
        self
    }

    pub fn nfe(mut self, nfe: usize) -> Self {
        self.nfe = nfe;
        self
    }

    pub fn schedule(mut self, schedule: ScheduleSpec) -> Self {
        self.schedule = schedule;
        self
    }

    pub fn nfe_budget(mut self, budget: Option<usize>) -> Self {
        self.nfe_budget = budget;
        self
    }

    pub fn window_ratio(mut self, ratio: Option<f64>) -> Self {
        self.window_ratio = ratio;
        self
    }

    pub fn slack(mut self, slack: Option<f64>) -> Self {
        self.slack = slack;
        self
    }

    pub fn max_events(mut self, cap: Option<usize>) -> Self {
        self.max_events = cap;
        self
    }

    /// Run the solver parallel-in-time (Picard sweeps over the whole
    /// grid) instead of step by step.
    pub fn pit(mut self, pit: bool) -> Self {
        self.pit = pit;
        self
    }

    /// PIT sweep cap (defaults to the resolved step count, the bound at
    /// which the exact fixed point is guaranteed).
    pub fn sweeps_max(mut self, cap: Option<usize>) -> Self {
        self.sweeps_max = cap;
        self
    }

    /// PIT convergence tolerance (defaults to 0.0 = exact fixed point).
    pub fn tol(mut self, tol: Option<f64>) -> Self {
        self.tol = tol;
        self
    }

    /// Opt into per-window/per-sweep progress frames on streams.
    pub fn progress(mut self, progress: bool) -> Self {
        self.progress = progress;
        self
    }

    /// Opt out of the brownout degradation ladder (see
    /// [`SamplingSpec::degrade`]): under overload this request sheds typed
    /// `overloaded` instead of being degraded.
    pub fn no_degrade(mut self, no_degrade: bool) -> Self {
        self.no_degrade = no_degrade;
        self
    }

    pub fn deadline_ms(mut self, deadline: Option<u64>) -> Self {
        self.deadline_ms = deadline;
        self
    }

    pub fn priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    /// Validate and assemble.  Every serving-surface invariant lives here
    /// (and only here): the scheduler trusts any spec it receives.
    pub fn build(self) -> Result<SamplingSpec, SpecError> {
        if self.n_samples == 0 {
            return Err(SpecError::NoSamples);
        }
        if self.deadline_ms == Some(0) {
            return Err(SpecError::DeadlineZero);
        }
        if self.priority > MAX_PRIORITY {
            return Err(SpecError::PriorityOutOfRange { priority: self.priority });
        }
        // θ ranges of the second-order schemes (Thms. 5.4/5.5).  NaN never
        // passes a range check.
        match self.solver {
            Solver::Trapezoidal { theta } if !(theta > 0.0 && theta < 1.0) => {
                return Err(SpecError::ThetaOutOfRange { scheme: "trapezoidal", theta });
            }
            Solver::Rk2 { theta } if !(theta > 0.0 && theta <= 0.5) => {
                return Err(SpecError::ThetaOutOfRange { scheme: "rk2", theta });
            }
            Solver::Midpoint { theta } if !(theta > 0.0 && theta <= 1.0) => {
                return Err(SpecError::ThetaOutOfRange { scheme: "midpoint", theta });
            }
            _ => {}
        }
        if self.nfe < self.solver.nfe_per_step() {
            return Err(SpecError::NfeBelowOneStep {
                nfe: self.nfe,
                per_step: self.solver.nfe_per_step(),
            });
        }

        // PIT-only knobs without pit are rejected typed (the mirror of the
        // exact-only knob checks below).
        if !self.pit {
            if self.sweeps_max.is_some() {
                return Err(SpecError::KnobNeedsPit { knob: "sweeps_max" });
            }
            if self.tol.is_some() {
                return Err(SpecError::KnobNeedsPit { knob: "tol" });
            }
        }

        if self.pit {
            if matches!(self.solver, Solver::Exact) {
                return Err(SpecError::PitNeedsScheme);
            }
            match self.schedule {
                ScheduleSpec::Uniform => {}
                ScheduleSpec::Log => {
                    return Err(SpecError::PitNeedsUniform { schedule: "log" });
                }
                ScheduleSpec::Tuned { .. } => {
                    return Err(SpecError::PitNeedsUniform { schedule: "tuned" });
                }
                ScheduleSpec::Adaptive { .. } => {
                    return Err(SpecError::PitNeedsUniform { schedule: "adaptive" });
                }
            }
            if self.nfe_budget.is_some() {
                return Err(SpecError::PitBudgetUnsupported);
            }
            let solver_name = self.solver.name();
            if self.window_ratio.is_some() {
                return Err(SpecError::KnobNeedsExact { knob: "window_ratio", solver: solver_name });
            }
            if self.slack.is_some() {
                return Err(SpecError::KnobNeedsExact { knob: "slack", solver: solver_name });
            }
            if self.max_events.is_some() {
                return Err(SpecError::KnobNeedsExact { knob: "max_events", solver: solver_name });
            }
            if self.sweeps_max == Some(0) {
                return Err(SpecError::SweepsMaxZero);
            }
            if let Some(t) = self.tol {
                if !(t.is_finite() && t >= 0.0) {
                    return Err(SpecError::PitTolInvalid { tol: t });
                }
            }
            // Resolve the knobs (sweep cap defaults to the step count: the
            // bound at which convergence to the exact fixed point is
            // guaranteed — the driver advances >= 1 step per sweep).
            let steps = self.solver.steps_for_nfe(self.nfe);
            let sweeps_max = self.sweeps_max.unwrap_or(steps.max(1));
            let tol = self.tol.unwrap_or(0.0);
            return Ok(SamplingSpec {
                family: self.family,
                n_samples: self.n_samples,
                seed: self.seed,
                cfg: SolverCfg::Pit { solver: self.solver, nfe: self.nfe, sweeps_max, tol },
                deadline_ms: self.deadline_ms,
                priority: self.priority,
                progress: self.progress,
                no_degrade: self.no_degrade,
            });
        }

        if matches!(self.solver, Solver::Exact) {
            if self.nfe_budget.is_some() {
                return Err(SpecError::BudgetOnExact);
            }
            match self.schedule {
                // Fixed grids are inert for exact simulation (only the
                // terminal δ matters) and were historically accepted.
                ScheduleSpec::Uniform | ScheduleSpec::Log => {}
                ScheduleSpec::Adaptive { .. } => {
                    return Err(SpecError::NeedsTwoStage {
                        what: "adaptive schedules",
                        solver: "exact",
                    });
                }
                ScheduleSpec::Tuned { .. } => {
                    return Err(SpecError::NeedsTwoStage {
                        what: "tuned schedules",
                        solver: "exact",
                    });
                }
            }
            if let Some(w) = self.window_ratio {
                if !(w > 0.0 && w < 1.0) {
                    return Err(SpecError::WindowRatioOutOfRange { value: w });
                }
            }
            if let Some(s) = self.slack {
                if !(s.is_finite() && s >= 1.0) {
                    return Err(SpecError::SlackOutOfRange { value: s });
                }
            }
            if self.max_events == Some(0) {
                return Err(SpecError::MaxEventsZero);
            }
            // Resolve the knobs, then enforce the drift floor on the
            // RESOLVED values: the thinning bound evaluates at the
            // window's small end, but data-consistent positions rise with
            // t (see score::hmm::rise_envelope) — slack must cover that
            // rise or the dominating rate is silently invalid.
            let window_ratio = self.window_ratio.unwrap_or(DEFAULT_WINDOW_RATIO);
            let slack = self.slack.unwrap_or(DEFAULT_SLACK);
            let floor = crate::score::hmm::SUP_DRIFT_MARGIN / window_ratio;
            if slack < floor {
                return Err(SpecError::SlackBelowFloor { slack, window_ratio, floor });
            }
            return Ok(SamplingSpec {
                family: self.family,
                n_samples: self.n_samples,
                seed: self.seed,
                cfg: SolverCfg::Exact { window_ratio, slack, max_events: self.max_events },
                deadline_ms: self.deadline_ms,
                priority: self.priority,
                progress: self.progress,
                no_degrade: self.no_degrade,
            });
        }

        // Grid schemes: the exact-only knobs are unrepresentable, so reject
        // them with a typed error instead of silently dropping them.
        let solver_name = self.solver.name();
        if self.window_ratio.is_some() {
            return Err(SpecError::KnobNeedsExact { knob: "window_ratio", solver: solver_name });
        }
        if self.slack.is_some() {
            return Err(SpecError::KnobNeedsExact { knob: "slack", solver: solver_name });
        }
        if self.max_events.is_some() {
            return Err(SpecError::KnobNeedsExact { knob: "max_events", solver: solver_name });
        }
        if let Some(b) = self.nfe_budget {
            // One full step plus the reserved terminal denoise must fit.
            let minimum = self.solver.nfe_per_step() + 1;
            if b < minimum {
                return Err(SpecError::BudgetBelowMinimum { budget: b, minimum });
            }
        }
        match self.schedule {
            ScheduleSpec::Tuned { steps } => {
                if steps > MAX_TUNED_STEPS {
                    return Err(SpecError::TunedStepsTooLarge { steps });
                }
                // The tuner's pilot runs are adaptive passes, which need
                // the two-stage estimator.
                if self.solver.nfe_per_step() != 2 {
                    return Err(SpecError::NeedsTwoStage {
                        what: "tuned schedules",
                        solver: solver_name,
                    });
                }
            }
            ScheduleSpec::Adaptive { tol } => {
                if self.solver.nfe_per_step() != 2 {
                    return Err(SpecError::NeedsTwoStage {
                        what: "adaptive schedules",
                        solver: solver_name,
                    });
                }
                if !(tol.is_finite() && tol >= 0.0) {
                    return Err(SpecError::AdaptiveTolInvalid { tol });
                }
            }
            ScheduleSpec::Uniform | ScheduleSpec::Log => {}
        }
        Ok(SamplingSpec {
            family: self.family,
            n_samples: self.n_samples,
            seed: self.seed,
            cfg: SolverCfg::Scheme {
                solver: self.solver,
                schedule: self.schedule,
                nfe: self.nfe,
                nfe_budget: self.nfe_budget,
            },
            deadline_ms: self.deadline_ms,
            priority: self.priority,
            progress: self.progress,
            no_degrade: self.no_degrade,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scheme(solver: Solver, nfe: usize) -> SpecBuilder {
        SamplingSpec::builder().solver(solver).nfe(nfe)
    }

    #[test]
    fn builder_defaults_and_getters() {
        let s = SamplingSpec::builder().build().unwrap();
        assert_eq!(s.family(), "markov");
        assert_eq!(s.n_samples(), 1);
        assert_eq!(s.seed(), 0);
        assert_eq!(s.solver(), Solver::Tweedie);
        assert_eq!(s.nfe(), 16);
        assert_eq!(s.schedule(), ScheduleSpec::Uniform);
        assert_eq!(s.nfe_budget(), None);
    }

    #[test]
    fn exact_knobs_resolve_to_defaults() {
        let bare = scheme(Solver::Exact, 16).build().unwrap();
        let explicit = scheme(Solver::Exact, 16)
            .window_ratio(Some(DEFAULT_WINDOW_RATIO))
            .slack(Some(DEFAULT_SLACK))
            .build()
            .unwrap();
        // Resolution makes the explicit-defaults spec IDENTICAL to the
        // knob-free one — this is what kills co-batch laundering.
        assert_eq!(bare, explicit);
        assert_eq!(bare.exact_cfg(), ExactCfg::default());
        assert_eq!(bare.plan(), explicit.plan());
    }

    #[test]
    fn invalid_combinations_are_rejected_typed() {
        // nfe_budget + exact.
        let e = scheme(Solver::Exact, 16).nfe_budget(Some(32)).build().unwrap_err();
        assert_eq!(e.code(), "budget_on_exact");
        assert!(format!("{e}").contains("exact"));
        // Knobs + non-exact solver.
        let e = scheme(Solver::TauLeaping, 16).slack(Some(2.0)).build().unwrap_err();
        assert_eq!(e.code(), "knob_needs_exact");
        assert!(format!("{e}").contains("exact"));
        let e = scheme(Solver::Trapezoidal { theta: 0.5 }, 16)
            .window_ratio(Some(0.5))
            .build()
            .unwrap_err();
        assert_eq!(e.code(), "knob_needs_exact");
        let e = scheme(Solver::Euler, 16).max_events(Some(5)).build().unwrap_err();
        assert_eq!(e.code(), "knob_needs_exact");
        // θ out of range (NaN included).
        for theta in [0.0, 1.0, 1.5, f64::NAN] {
            let e = scheme(Solver::Trapezoidal { theta }, 16).build().unwrap_err();
            assert_eq!(e.code(), "theta_out_of_range", "theta={theta}");
            assert!(format!("{e}").contains("theta"));
        }
        for theta in [0.0, 0.51, 1.0, f64::NAN] {
            let e = scheme(Solver::Rk2 { theta }, 16).build().unwrap_err();
            assert_eq!(e.code(), "theta_out_of_range", "theta={theta}");
            assert!(format!("{e}").contains("1/2"));
        }
        // Out-of-range exact knobs.
        for wr in [0.0, 1.0, -0.5, f64::NAN] {
            let e = scheme(Solver::Exact, 16).window_ratio(Some(wr)).build().unwrap_err();
            assert_eq!(e.code(), "window_ratio_out_of_range", "wr={wr}");
        }
        for sl in [0.5, 0.0, f64::NAN, f64::INFINITY] {
            let e = scheme(Solver::Exact, 16).slack(Some(sl)).build().unwrap_err();
            assert_eq!(e.code(), "slack_out_of_range", "slack={sl}");
        }
        // Slack floor: valid slack, but below the drift floor for the ratio.
        let e = scheme(Solver::Exact, 16).slack(Some(1.2)).build().unwrap_err();
        assert_eq!(e.code(), "slack_below_floor");
        assert!(format!("{e}").contains("window_ratio"));
        // Budget minima and nfe minima.
        let e = scheme(Solver::Trapezoidal { theta: 0.5 }, 1).build().unwrap_err();
        assert_eq!(e.code(), "nfe_below_one_step");
        assert!(format!("{e}").contains("below one step"));
        let e = scheme(Solver::Trapezoidal { theta: 0.5 }, 16)
            .nfe_budget(Some(2))
            .build()
            .unwrap_err();
        assert_eq!(e.code(), "budget_below_minimum");
        assert!(format!("{e}").contains("below one step"));
        // Adaptive/tuned need two-stage schemes.
        let e = scheme(Solver::TauLeaping, 16)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .build()
            .unwrap_err();
        assert_eq!(e.code(), "needs_two_stage");
        assert!(format!("{e}").contains("two-stage"));
        let e = scheme(Solver::Tweedie, 16)
            .schedule(ScheduleSpec::Tuned { steps: 0 })
            .build()
            .unwrap_err();
        assert_eq!(e.code(), "needs_two_stage");
        let e = scheme(Solver::Exact, 16)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .build()
            .unwrap_err();
        assert_eq!(e.code(), "needs_two_stage");
        // Tuned step cap.
        let e = scheme(Solver::Trapezoidal { theta: 0.5 }, 16)
            .schedule(ScheduleSpec::Tuned { steps: MAX_TUNED_STEPS + 1 })
            .build()
            .unwrap_err();
        assert_eq!(e.code(), "tuned_steps_too_large");
        assert!(format!("{e}").contains("tuned steps"));
        // Degenerate max_events / n_samples.
        let e = scheme(Solver::Exact, 16).max_events(Some(0)).build().unwrap_err();
        assert_eq!(e.code(), "max_events_zero");
        let e = SamplingSpec::builder().n_samples(0).build().unwrap_err();
        assert_eq!(e.code(), "no_samples");
        // Exact + fixed schedules stay accepted (historically inert).
        assert!(scheme(Solver::Exact, 16).schedule(ScheduleSpec::Log).build().is_ok());
    }

    #[test]
    fn plan_resolves_discretisation() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        // Fixed uniform: nfe 64 and 65 resolve to the same 32-step grid.
        let a = scheme(trap, 64).build().unwrap();
        let b = scheme(trap, 65).build().unwrap();
        assert_eq!(a.plan(), ExecPlan::Uniform { steps: 32 });
        assert_eq!(a.plan(), b.plan());
        // Budget folds into the step count.
        let c = scheme(trap, 64).nfe_budget(Some(33)).build().unwrap();
        assert_eq!(c.plan(), ExecPlan::Uniform { steps: 16 });
        // Tuned 0-steps resolves from nfe; explicit steps capped by budget.
        let t = scheme(trap, 64)
            .schedule(ScheduleSpec::Tuned { steps: 0 })
            .build()
            .unwrap();
        assert_eq!(t.plan(), ExecPlan::Tuned { steps: 32 });
        let t = scheme(trap, 16)
            .schedule(ScheduleSpec::Tuned { steps: 64 })
            .nfe_budget(Some(9))
            .build()
            .unwrap();
        assert_eq!(t.plan(), ExecPlan::Tuned { steps: 4 });
        // Adaptive: dt0 from nfe, tol + budget carried.
        let ad = scheme(trap, 64)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .nfe_budget(Some(24))
            .build()
            .unwrap();
        match ad.plan() {
            ExecPlan::Adaptive { tol, dt0, budget } => {
                assert_eq!(tol, 1e-3);
                assert!((dt0 - (1.0 - DELTA) / 32.0).abs() < 1e-15);
                assert_eq!(budget, Some(24));
            }
            p => panic!("wrong plan {p:?}"),
        }
        // Exact plan carries resolved knobs + max_events.
        let ex = scheme(Solver::Exact, 16)
            .window_ratio(Some(0.8))
            .slack(Some(2.5))
            .max_events(Some(100))
            .build()
            .unwrap();
        assert_eq!(
            ex.plan(),
            ExecPlan::Exact {
                cfg: ExactCfg { window_ratio: 0.8, slack: 2.5 },
                max_events: Some(100),
            }
        );
    }

    #[test]
    fn deadline_and_priority_are_qos_only() {
        let s = SamplingSpec::builder().build().unwrap();
        assert_eq!(s.deadline_ms(), None);
        assert_eq!(s.priority(), DEFAULT_PRIORITY);
        let q = SamplingSpec::builder()
            .deadline_ms(Some(250))
            .priority(MAX_PRIORITY)
            .build()
            .unwrap();
        assert_eq!(q.deadline_ms(), Some(250));
        assert_eq!(q.priority(), MAX_PRIORITY);
        // QoS knobs do not change the execution identity.
        assert_eq!(s.plan(), q.plan());
        // Validation.
        let e = SamplingSpec::builder().deadline_ms(Some(0)).build().unwrap_err();
        assert_eq!(e.code(), "deadline_zero");
        let e = SamplingSpec::builder().priority(MAX_PRIORITY + 1).build().unwrap_err();
        assert_eq!(e.code(), "priority_out_of_range");
        assert!(format!("{e}").contains("priority"));
    }

    #[test]
    fn planned_nfe_matches_plan() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        // Fixed grids: steps * per_step + terminal denoise.
        assert_eq!(scheme(trap, 64).build().unwrap().planned_nfe(), Some(65));
        assert_eq!(scheme(Solver::Tweedie, 16).build().unwrap().planned_nfe(), Some(17));
        // Adaptive with a hard budget: the budget IS the bound.
        let ad = scheme(trap, 64)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .nfe_budget(Some(24))
            .build()
            .unwrap();
        assert_eq!(ad.planned_nfe(), Some(24));
        // Adaptive without a budget: derived from the seed dt.
        let ad = scheme(trap, 64)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .build()
            .unwrap();
        assert_eq!(ad.planned_nfe(), Some(65));
        // Exact: bounded only when max_events caps the run.
        assert_eq!(scheme(Solver::Exact, 16).build().unwrap().planned_nfe(), None);
        let ex = scheme(Solver::Exact, 16).max_events(Some(100)).build().unwrap();
        assert_eq!(ex.planned_nfe(), Some(101));
    }

    #[test]
    fn pit_spec_resolves_and_plans() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        // Defaults resolve: sweeps_max = step count, tol = 0.
        let bare = scheme(trap, 64).pit(true).build().unwrap();
        assert!(bare.pit());
        assert_eq!(bare.solver(), trap);
        assert_eq!(bare.nfe(), 64);
        assert_eq!(bare.sweeps_max(), Some(32));
        assert_eq!(bare.pit_tol(), Some(0.0));
        assert_eq!(bare.plan(), ExecPlan::Pit { steps: 32, sweeps_max: 32, tol: 0.0 });
        // Explicit defaults are indistinguishable from knob-free (the
        // co-batch-laundering kill, same as the exact path).
        let explicit = scheme(trap, 64)
            .pit(true)
            .sweeps_max(Some(32))
            .tol(Some(0.0))
            .build()
            .unwrap();
        assert_eq!(bare, explicit);
        // Worst-case admission bound: per_step * steps * sweeps + denoise.
        assert_eq!(bare.planned_nfe(), Some(2 * 32 * 32 + 1));
        // One-stage solvers work too.
        let tau = scheme(Solver::TauLeaping, 16)
            .pit(true)
            .sweeps_max(Some(4))
            .tol(Some(0.25))
            .build()
            .unwrap();
        assert_eq!(tau.plan(), ExecPlan::Pit { steps: 16, sweeps_max: 4, tol: 0.25 });
        assert_eq!(tau.planned_nfe(), Some(16 * 4 + 1));
        // Non-PIT specs report no PIT knobs.
        let seq = scheme(trap, 64).build().unwrap();
        assert!(!seq.pit());
        assert_eq!(seq.sweeps_max(), None);
        assert_eq!(seq.pit_tol(), None);
    }

    #[test]
    fn pit_combinations_are_rejected_typed() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        // PIT knobs without pit.
        let e = scheme(trap, 64).sweeps_max(Some(8)).build().unwrap_err();
        assert_eq!(e.code(), "knob_needs_pit");
        assert!(format!("{e}").contains("pit"));
        let e = scheme(trap, 64).tol(Some(0.1)).build().unwrap_err();
        assert_eq!(e.code(), "knob_needs_pit");
        // PIT + exact.
        let e = scheme(Solver::Exact, 16).pit(true).build().unwrap_err();
        assert_eq!(e.code(), "pit_needs_scheme");
        assert!(format!("{e}").contains("grid"));
        // PIT + non-uniform schedules.
        for (sched, name) in [
            (ScheduleSpec::Log, "log"),
            (ScheduleSpec::Tuned { steps: 8 }, "tuned"),
            (ScheduleSpec::Adaptive { tol: 1e-3 }, "adaptive"),
        ] {
            let e = scheme(trap, 64).pit(true).schedule(sched).build().unwrap_err();
            assert_eq!(e.code(), "pit_needs_uniform", "{name}");
            assert!(format!("{e}").contains(name));
        }
        // PIT + nfe_budget.
        let e = scheme(trap, 64).pit(true).nfe_budget(Some(32)).build().unwrap_err();
        assert_eq!(e.code(), "pit_budget_unsupported");
        assert!(format!("{e}").contains("sweeps_max"));
        // Exact-only knobs on a PIT spec.
        let e = scheme(trap, 64).pit(true).slack(Some(2.0)).build().unwrap_err();
        assert_eq!(e.code(), "knob_needs_exact");
        // Degenerate sweep cap / tolerance.
        let e = scheme(trap, 64).pit(true).sweeps_max(Some(0)).build().unwrap_err();
        assert_eq!(e.code(), "sweeps_max_zero");
        for tol in [-1.0, f64::NAN, f64::INFINITY] {
            let e = scheme(trap, 64).pit(true).tol(Some(tol)).build().unwrap_err();
            assert_eq!(e.code(), "pit_tol_invalid", "tol={tol}");
        }
    }

    #[test]
    fn midpoint_theta_validated() {
        for theta in [0.0, -0.25, 1.5, f64::NAN] {
            let e = scheme(Solver::Midpoint { theta }, 16).build().unwrap_err();
            assert_eq!(e.code(), "theta_out_of_range", "theta={theta}");
            assert!(format!("{e}").contains("midpoint"));
        }
        // θ = 1 (full-window leap) is the inclusive edge.
        assert!(scheme(Solver::Midpoint { theta: 1.0 }, 16).build().is_ok());
        // Midpoint is two-stage, so adaptive schedules accept it.
        assert!(scheme(Solver::Midpoint { theta: 0.5 }, 16)
            .schedule(ScheduleSpec::Adaptive { tol: 1e-3 })
            .build()
            .is_ok());
    }

    #[test]
    fn progress_is_qos_only() {
        let off = SamplingSpec::builder().build().unwrap();
        assert!(!off.progress());
        let on = SamplingSpec::builder().progress(true).build().unwrap();
        assert!(on.progress());
        // Progress never changes the execution identity.
        assert_eq!(off.plan(), on.plan());
    }

    #[test]
    fn no_degrade_is_qos_only() {
        let off = SamplingSpec::builder().build().unwrap();
        assert!(!off.no_degrade());
        let on = SamplingSpec::builder().no_degrade(true).build().unwrap();
        assert!(on.no_degrade());
        // Opting out never changes the execution identity.
        assert_eq!(off.plan(), on.plan());
    }

    #[test]
    fn degrade_walks_the_ladder_and_preserves_validity() {
        let trap = Solver::Trapezoidal { theta: 0.5 };
        // Rung 1: PIT falls back to the sequential uniform scheme.
        let pit = scheme(trap, 64).pit(true).build().unwrap();
        let (d, r) = pit.degrade(1).unwrap();
        assert_eq!(r, 1);
        assert!(!d.pit());
        assert_eq!(d.plan(), ExecPlan::Uniform { steps: 32 });
        // Rung 2: non-uniform schedules drop to uniform.
        let tuned = scheme(trap, 64).schedule(ScheduleSpec::Tuned { steps: 16 }).build().unwrap();
        let (d, r) = tuned.degrade(2).unwrap();
        assert_eq!(r, 2);
        assert_eq!(d.plan(), ExecPlan::Uniform { steps: 32 });
        // Rung 2 on a PIT spec applies rung 1 only (already uniform after).
        let (d, r) = pit.degrade(2).unwrap();
        assert_eq!(r, 1);
        assert_eq!(d.plan(), ExecPlan::Uniform { steps: 32 });
        // Rung 3: NFE clamps to the floor; the result is what a direct
        // build at the floor produces, so degraded specs co-batch with
        // native floor-NFE requests.
        let big = scheme(trap, 256).build().unwrap();
        let (d, r) = big.degrade(3).unwrap();
        assert_eq!(r, 3);
        assert_eq!(d.nfe(), DEGRADE_NFE_FLOOR);
        assert_eq!(d, scheme(trap, DEGRADE_NFE_FLOOR).build().unwrap());
        // Already at/below the floor: rung 3 is a no-op, rung 2 fires.
        let small = scheme(trap, 8).schedule(ScheduleSpec::Log).build().unwrap();
        let (d, r) = small.degrade(3).unwrap();
        assert_eq!(r, 2);
        assert_eq!(d.schedule(), ScheduleSpec::Uniform);
        // Nothing left to trade: no rung applies.
        assert!(scheme(trap, 8).build().unwrap().degrade(3).is_none());
        // Exact never degrades (no quality knob on the ladder).
        assert!(scheme(Solver::Exact, 16).build().unwrap().degrade(3).is_none());
        // QoS fields survive degradation untouched.
        let q = scheme(trap, 256).deadline_ms(Some(500)).priority(2).build().unwrap();
        let (d, _) = q.degrade(3).unwrap();
        assert_eq!(d.deadline_ms(), Some(500));
        assert_eq!(d.priority(), 2);
    }

    #[test]
    fn lane_seeds_match_historic_stride() {
        let s = SamplingSpec::builder().seed(99).build().unwrap();
        assert_eq!(s.lane_seed(0), 99);
        assert_eq!(s.lane_seed(3), 99u64.wrapping_add(3u64.wrapping_mul(LANE_SEED_STRIDE)));
    }
}
