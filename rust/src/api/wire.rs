//! Wire-protocol (de)serialization of [`SamplingSpec`]: the versioned v2
//! envelope plus the v1 compatibility shim.
//!
//! ## v2 (structured)
//!
//! ```json
//! {"v": 2, "cmd": "generate", "spec": {
//!    "family": "markov", "n_samples": 2, "seed": 7,
//!    "solver": {"type": "scheme", "solver": "trapezoidal:0.5",
//!               "schedule": {"kind": "adaptive", "tol": 0.001},
//!               "nfe": 64, "nfe_budget": 48}}}
//! {"v": 2, "cmd": "generate", "spec": {
//!    "family": "markov", "seed": 9,
//!    "solver": {"type": "exact", "window_ratio": 0.6, "slack": 3.0,
//!               "max_events": 500}}}
//! {"v": 2, "cmd": "generate", "request_key": "job-7f3a",
//!  "spec": {
//!    "family": "markov", "seed": 4, "progress": true,
//!    "solver": {"type": "pit", "solver": "trapezoidal:0.5",
//!               "nfe": 64, "sweeps_max": 8, "tol": 0.0}}}
//! ```
//!
//! `spec_to_json` always writes the *resolved* spec (defaults filled), so a
//! response echo shows exactly what ran; `spec_from_json` routes every
//! field through [`SpecBuilder`], so malformed or invalid requests die at
//! the wire boundary with a typed [`SpecError`] (stable `code` string).
//!
//! ## v1 (legacy flat) — auto-upgrade shim
//!
//! Any request without `"v": 2` is interpreted as the historical flat form
//! (`solver`/`nfe`/`n_samples`/`seed`/`family`/`schedule`/`nfe_budget`/
//! `window_ratio`/`slack`/`deadline_ms`/`priority` at top level) and
//! upgraded through the same builder.  [`V1Echo`] preserves which optional
//! fields the request actually carried so the server can reproduce the
//! legacy response echo byte for byte.
//!
//! ## QoS fields
//!
//! `deadline_ms` and `priority` ride at the top level of the v2 `"spec"`
//! object (and flat in v1).  The writer emits `deadline_ms` only when set
//! and `priority` only when it differs from the default, so pre-QoS specs
//! serialize byte-identically to before and the v1 compat corpus is
//! untouched.  `progress` (v2 only) opts a `generate_stream` into
//! `{"stream": "progress", ...}` heartbeat frames; like the other QoS
//! fields it never affects execution identity, and the writer emits it
//! only when true.  `no_degrade` (v2 only, emitted only when true) opts
//! the request out of the brownout degradation ladder: an overloaded
//! server sheds it typed `overloaded` instead of degrading its plan.
//! Degraded v2 responses carry a `degraded` field (the ladder rung
//! applied, 1..=3) next to `partial`; undegraded responses omit it, so
//! pre-brownout traffic serializes byte-identically to before.
//!
//! ## Idempotency (`request_key`, v2 only)
//!
//! A v2 envelope may carry a top-level `"request_key"` string (1–128
//! chars).  The server echoes it on the response, and a second request
//! with the same key while the first is still in flight is rejected typed
//! (`duplicate_request`) with the original job id — clients can retry
//! submissions over a flaky link without double-spending compute.
//!
//! ## Error codes
//!
//! Every error frame carries a stable machine-readable `"code"`.  Spec
//! validation codes come from [`SpecError::code`]:
//!
//! | code | meaning |
//! |------|---------|
//! | `theta_out_of_range` | θ outside the scheme's second-order range |
//! | `knob_needs_exact` | exact-only knob on a grid scheme |
//! | `budget_on_exact` | `nfe_budget` on exact simulation |
//! | `window_ratio_out_of_range` | window_ratio outside (0, 1) |
//! | `slack_out_of_range` | slack not finite or below 1 |
//! | `slack_below_floor` | slack below the drift floor for the ratio |
//! | `max_events_zero` | `max_events` given as 0 |
//! | `nfe_below_one_step` | nfe below one solver step |
//! | `budget_below_minimum` | budget below one step + terminal denoise |
//! | `tuned_steps_too_large` | tuned step count above the cap |
//! | `needs_two_stage` | adaptive/tuned on a one-stage scheme |
//! | `adaptive_tol_invalid` | adaptive tol not finite or negative |
//! | `knob_needs_pit` | `sweeps_max`/`tol` without a pit solver |
//! | `pit_needs_scheme` | pit on exact simulation (no grid to iterate) |
//! | `pit_needs_uniform` | pit with a non-uniform schedule (v1 limitation) |
//! | `pit_budget_unsupported` | `nfe_budget` on a pit spec |
//! | `sweeps_max_zero` | `sweeps_max` given as 0 |
//! | `pit_tol_invalid` | pit tol not finite or negative |
//! | `no_samples` | n_samples given as 0 |
//! | `deadline_zero` | `deadline_ms` given as 0 |
//! | `priority_out_of_range` | priority above the maximum |
//! | `parse_error` | a field failed to parse |
//! | `missing_field` | a required field is missing |
//!
//! Runtime (post-admission) codes come from `coordinator::codes`:
//!
//! | code | meaning |
//! |------|---------|
//! | `lane_failed` | a panic inside this request's own lane(s); siblings unaffected |
//! | `batch_failed` | the backend reported a batch-level execution error |
//! | `overloaded` | shed at intake (queue/in-flight caps, or the server's connection cap) |
//! | `deadline_infeasible` | rejected at intake: planned NFE cannot fit the deadline |
//! | `duplicate_request` | a request with this `request_key` is already in flight |
//! | `coordinator_restarted` | in-flight when the supervisor restarted the scheduler loop |
//! | `shutdown` | in-flight at coordinator shutdown |
//! | `backend_unavailable` | the score backend's circuit breaker is open, or a stalled/transiently-failing eval exhausted its retry budget |
//!
//! ## Artifact-registry verbs
//!
//! Servers started with `--registry-dir` additionally answer the
//! content-addressed registry verbs (see [`crate::registry`]); blobs
//! travel hex-encoded on the wire:
//!
//! | verb | request | reply |
//! |------|---------|-------|
//! | `registry_put`  | `{"cmd","manifest":{kind,name,...},"blobs":[hex,...]}` | `{"ok":true,"digest"}` |
//! | `registry_get`  | `{"cmd","digest"}` | `{"ok":true,"digest","manifest","blobs":[hex,...]}` |
//! | `registry_stat` | `{"cmd","digest"}` | `{"ok":true,"digest","manifest","blobs":[{digest,size}]}` |
//! | `registry_list` | `{"cmd"[,"kind"][,"family"]}` | `{"ok":true,"artifacts":[{digest,manifest}]}` |
//!
//! Their typed error codes come from `registry::RegistryError::code`:
//!
//! | code | meaning |
//! |------|---------|
//! | `not_found` | no artifact/blob with that digest |
//! | `integrity_failure` | stored bytes no longer hash to their digest — never served |
//! | `invalid_digest` | digest is not 64 lowercase hex chars |
//! | `bad_manifest` | manifest malformed (unknown kind/schema, missing field) |
//! | `registry_disabled` | server was started without `--registry-dir` |

use crate::api::spec::{SamplingSpec, SolverCfg, SpecError, DEFAULT_PRIORITY};
use crate::schedule::ScheduleSpec;
use crate::solvers::Solver;
use crate::util::json::Json;

/// Current protocol version.
pub const PROTOCOL_VERSION: u64 = 2;

/// The optional fields a legacy v1 request actually carried, exactly as
/// parsed — the server's v1 response echo is derived from this (NOT from
/// the resolved spec, which fills defaults v1 never echoed).
#[derive(Clone, Debug, Default)]
pub struct V1Echo {
    pub schedule: ScheduleSpec,
    pub nfe_budget: Option<usize>,
    pub window_ratio: Option<f64>,
    pub slack: Option<f64>,
    pub deadline_ms: Option<u64>,
    pub priority: Option<u8>,
}

/// Maximum accepted `request_key` length (keys live in a coordinator-side
/// registry until their job finishes, so they must stay small).
pub const MAX_REQUEST_KEY_LEN: usize = 128;

/// A parsed request: the validated spec plus, for legacy requests, the v1
/// echo view.  `v1.is_some()` ⇔ the request arrived in the flat v1 form.
#[derive(Clone, Debug)]
pub struct ParsedRequest {
    pub spec: SamplingSpec,
    pub v1: Option<V1Echo>,
    /// Client-supplied idempotency key (v2 envelopes only; see module
    /// docs).  Echoed on responses and deduplicated while in flight.
    pub request_key: Option<String>,
}

fn missing(field: &'static str) -> impl FnOnce(anyhow::Error) -> SpecError {
    move |e| SpecError::MissingField { field, message: format!("{e:#}") }
}

fn parse_err(field: &'static str) -> impl FnOnce(anyhow::Error) -> SpecError {
    move |e| SpecError::Parse { field, message: format!("{e:#}") }
}

/// Parse a request object of either protocol version (see module docs).
pub fn request_from_json(j: &Json) -> Result<ParsedRequest, SpecError> {
    let version = match j.opt("v") {
        Some(v) => v.as_u64().map_err(parse_err("v"))?,
        None => 1,
    };
    match version {
        1 => {
            let (spec, echo) = v1_from_json(j)?;
            Ok(ParsedRequest { spec, v1: Some(echo), request_key: None })
        }
        2 => {
            let request_key = match j.opt("request_key") {
                Some(k) => {
                    let k = k.as_str().map_err(parse_err("request_key"))?;
                    if k.is_empty() || k.len() > MAX_REQUEST_KEY_LEN {
                        return Err(SpecError::Parse {
                            field: "request_key",
                            message: format!(
                                "request_key length {} outside 1..={MAX_REQUEST_KEY_LEN}",
                                k.len()
                            ),
                        });
                    }
                    Some(k.to_string())
                }
                None => None,
            };
            let spec_obj = j.get("spec").map_err(missing("spec"))?;
            Ok(ParsedRequest { spec: spec_from_json(spec_obj)?, v1: None, request_key })
        }
        other => Err(SpecError::Parse {
            field: "v",
            message: format!("unsupported protocol version {other} (this server speaks 1 and 2)"),
        }),
    }
}

/// Upgrade a legacy flat request (the pre-v2 protocol) into a validated
/// spec, preserving the raw optional fields for the legacy echo.
fn v1_from_json(j: &Json) -> Result<(SamplingSpec, V1Echo), SpecError> {
    let solver_str = j
        .get("solver")
        .and_then(|s| s.as_str())
        .map_err(missing("solver"))?;
    let solver = Solver::parse(solver_str).map_err(parse_err("solver"))?;
    let nfe = j
        .get("nfe")
        .and_then(|v| v.as_usize())
        .map_err(missing("nfe"))?;
    let schedule = match j.opt("schedule") {
        Some(s) => {
            let text = s.as_str().map_err(parse_err("schedule"))?;
            ScheduleSpec::parse(text).map_err(parse_err("schedule"))?
        }
        None => ScheduleSpec::default(),
    };
    let mut b = SamplingSpec::builder().solver(solver).nfe(nfe).schedule(schedule);
    if let Some(f) = j.opt("family") {
        b = b.family(f.as_str().map_err(parse_err("family"))?);
    }
    if let Some(n) = j.opt("n_samples") {
        b = b.n_samples(n.as_usize().map_err(parse_err("n_samples"))?);
    }
    if let Some(s) = j.opt("seed") {
        // Lossless: 64-bit seeds above 2^53 survive (util::json::Json::Int).
        b = b.seed(s.as_u64().map_err(parse_err("seed"))?);
    }
    let nfe_budget = j
        .opt("nfe_budget")
        .map(|v| v.as_usize().map_err(parse_err("nfe_budget")))
        .transpose()?;
    let window_ratio = j
        .opt("window_ratio")
        .map(|v| v.as_f64().map_err(parse_err("window_ratio")))
        .transpose()?;
    let slack = j
        .opt("slack")
        .map(|v| v.as_f64().map_err(parse_err("slack")))
        .transpose()?;
    let deadline_ms = j
        .opt("deadline_ms")
        .map(|v| v.as_u64().map_err(parse_err("deadline_ms")))
        .transpose()?;
    let priority = j
        .opt("priority")
        .map(|v| {
            let p = v.as_u64().map_err(parse_err("priority"))?;
            u8::try_from(p).map_err(|_| SpecError::Parse {
                field: "priority",
                message: format!("priority {p} does not fit in a byte"),
            })
        })
        .transpose()?;
    let spec = b
        .nfe_budget(nfe_budget)
        .window_ratio(window_ratio)
        .slack(slack)
        .deadline_ms(deadline_ms)
        .priority(priority.unwrap_or(DEFAULT_PRIORITY))
        .build()?;
    Ok((spec, V1Echo { schedule, nfe_budget, window_ratio, slack, deadline_ms, priority }))
}

/// Parse the v2 `"spec"` object through the validating builder.
pub fn spec_from_json(j: &Json) -> Result<SamplingSpec, SpecError> {
    let mut b = SamplingSpec::builder();
    if let Some(f) = j.opt("family") {
        b = b.family(f.as_str().map_err(parse_err("family"))?);
    }
    if let Some(n) = j.opt("n_samples") {
        b = b.n_samples(n.as_usize().map_err(parse_err("n_samples"))?);
    }
    if let Some(s) = j.opt("seed") {
        b = b.seed(s.as_u64().map_err(parse_err("seed"))?);
    }
    if let Some(d) = j.opt("deadline_ms") {
        b = b.deadline_ms(Some(d.as_u64().map_err(parse_err("deadline_ms"))?));
    }
    if let Some(p) = j.opt("priority") {
        let p = p.as_u64().map_err(parse_err("priority"))?;
        b = b.priority(u8::try_from(p).map_err(|_| SpecError::Parse {
            field: "priority",
            message: format!("priority {p} does not fit in a byte"),
        })?);
    }
    if let Some(p) = j.opt("progress") {
        b = b.progress(p.as_bool().map_err(parse_err("progress"))?);
    }
    if let Some(n) = j.opt("no_degrade") {
        b = b.no_degrade(n.as_bool().map_err(parse_err("no_degrade"))?);
    }
    let sol = j.get("solver").map_err(missing("solver"))?;
    let ty = sol
        .get("type")
        .and_then(|t| t.as_str())
        .map_err(missing("solver.type"))?;
    match ty {
        "scheme" => {
            let name = sol
                .get("solver")
                .and_then(|s| s.as_str())
                .map_err(missing("solver.solver"))?;
            let solver = Solver::parse(name).map_err(parse_err("solver.solver"))?;
            b = b.solver(solver);
            b = b.nfe(
                sol.get("nfe")
                    .and_then(|v| v.as_usize())
                    .map_err(missing("solver.nfe"))?,
            );
            if let Some(s) = sol.opt("schedule") {
                b = b.schedule(ScheduleSpec::from_json(s).map_err(parse_err("solver.schedule"))?);
            }
            if let Some(v) = sol.opt("nfe_budget") {
                b = b.nfe_budget(Some(v.as_usize().map_err(parse_err("solver.nfe_budget"))?));
            }
        }
        "pit" => {
            let name = sol
                .get("solver")
                .and_then(|s| s.as_str())
                .map_err(missing("solver.solver"))?;
            let solver = Solver::parse(name).map_err(parse_err("solver.solver"))?;
            b = b.solver(solver).pit(true);
            b = b.nfe(
                sol.get("nfe")
                    .and_then(|v| v.as_usize())
                    .map_err(missing("solver.nfe"))?,
            );
            if let Some(v) = sol.opt("sweeps_max") {
                b = b.sweeps_max(Some(v.as_usize().map_err(parse_err("solver.sweeps_max"))?));
            }
            if let Some(v) = sol.opt("tol") {
                b = b.tol(Some(v.as_f64().map_err(parse_err("solver.tol"))?));
            }
        }
        "exact" => {
            b = b.solver(Solver::Exact);
            if let Some(v) = sol.opt("window_ratio") {
                b = b.window_ratio(Some(v.as_f64().map_err(parse_err("solver.window_ratio"))?));
            }
            if let Some(v) = sol.opt("slack") {
                b = b.slack(Some(v.as_f64().map_err(parse_err("solver.slack"))?));
            }
            if let Some(v) = sol.opt("max_events") {
                b = b.max_events(Some(v.as_usize().map_err(parse_err("solver.max_events"))?));
            }
        }
        other => {
            return Err(SpecError::Parse {
                field: "solver.type",
                message: format!("unknown solver type {other:?} (scheme|pit|exact)"),
            });
        }
    }
    b.build()
}

/// Serialize the (resolved) spec as the structured v2 `"spec"` object.
/// Round-trips bit-exactly: `spec_from_json(spec_to_json(s)) == s`.
pub fn spec_to_json(spec: &SamplingSpec) -> Json {
    let solver = match spec.cfg() {
        SolverCfg::Scheme { solver, schedule, nfe, nfe_budget } => {
            let mut fields = vec![
                ("type", Json::from("scheme")),
                ("solver", Json::from(solver.spec_string())),
                ("schedule", schedule.to_json()),
                ("nfe", Json::from(*nfe)),
            ];
            if let Some(b) = nfe_budget {
                fields.push(("nfe_budget", Json::from(*b)));
            }
            Json::obj(fields)
        }
        SolverCfg::Pit { solver, nfe, sweeps_max, tol } => Json::obj(vec![
            ("type", Json::from("pit")),
            ("solver", Json::from(solver.spec_string())),
            ("nfe", Json::from(*nfe)),
            // Resolved knobs are always written (same policy as exact).
            ("sweeps_max", Json::from(*sweeps_max)),
            ("tol", Json::Num(*tol)),
        ]),
        SolverCfg::Exact { window_ratio, slack, max_events } => {
            let mut fields = vec![
                ("type", Json::from("exact")),
                ("window_ratio", Json::Num(*window_ratio)),
                ("slack", Json::Num(*slack)),
            ];
            if let Some(m) = max_events {
                fields.push(("max_events", Json::from(*m)));
            }
            Json::obj(fields)
        }
    };
    let mut fields = vec![
        ("family", Json::from(spec.family())),
        ("n_samples", Json::from(spec.n_samples())),
        ("seed", Json::from(spec.seed())),
    ];
    // QoS knobs only when set, so pre-QoS specs serialize byte-identically
    // to before (keeps the round-trip bit-exact and v1 echoes untouched).
    if let Some(d) = spec.deadline_ms() {
        fields.push(("deadline_ms", Json::from(d)));
    }
    if spec.priority() != DEFAULT_PRIORITY {
        fields.push(("priority", Json::from(spec.priority() as u64)));
    }
    if spec.progress() {
        fields.push(("progress", Json::Bool(true)));
    }
    if spec.no_degrade() {
        fields.push(("no_degrade", Json::Bool(true)));
    }
    fields.push(("solver", solver));
    Json::obj(fields)
}

/// Full v2 request envelope for a verb (`generate` / `generate_stream`).
pub fn request_to_json(cmd: &str, spec: &SamplingSpec) -> Json {
    request_to_json_with_key(cmd, spec, None)
}

/// As [`request_to_json`], with an optional idempotency `request_key`.
pub fn request_to_json_with_key(
    cmd: &str,
    spec: &SamplingSpec,
    request_key: Option<&str>,
) -> Json {
    let mut fields = vec![
        ("v", Json::from(PROTOCOL_VERSION)),
        ("cmd", Json::from(cmd)),
    ];
    if let Some(k) = request_key {
        fields.push(("request_key", Json::from(k)));
    }
    fields.push(("spec", spec_to_json(spec)));
    Json::obj(fields)
}

/// Error response body for a typed spec error (v1 clients ignore the extra
/// `code` field; v2 clients can dispatch on it).
pub fn spec_error_json(e: &SpecError) -> Json {
    Json::obj(vec![
        ("ok", Json::Bool(false)),
        ("error", Json::from(format!("{e}"))),
        ("code", Json::from(e.code())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v2_round_trip_bit_exact() {
        let specs = vec![
            SamplingSpec::builder().build().unwrap(),
            SamplingSpec::builder()
                .family("toy")
                .n_samples(3)
                .seed(u64::MAX - 7)
                .solver(Solver::Trapezoidal { theta: 0.37 })
                .nfe(64)
                .schedule(ScheduleSpec::Adaptive { tol: 1.7e-3 })
                .nfe_budget(Some(48))
                .build()
                .unwrap(),
            SamplingSpec::builder()
                .solver(Solver::Exact)
                .window_ratio(Some(0.61))
                .slack(Some(3.3))
                .max_events(Some(1000))
                .build()
                .unwrap(),
            SamplingSpec::builder()
                .solver(Solver::Trapezoidal { theta: 0.5 })
                .nfe(64)
                .pit(true)
                .sweeps_max(Some(6))
                .tol(Some(0.125))
                .progress(true)
                .build()
                .unwrap(),
            SamplingSpec::builder()
                .solver(Solver::Midpoint { theta: 0.75 })
                .nfe(32)
                .pit(true)
                .build()
                .unwrap(),
        ];
        for spec in specs {
            let j = spec_to_json(&spec);
            let back = spec_from_json(&j).unwrap();
            assert_eq!(back, spec, "{}", j.to_string());
            // Through text (the actual wire) too.
            let re = Json::parse(&j.to_string()).unwrap();
            assert_eq!(spec_from_json(&re).unwrap(), spec);
        }
    }

    #[test]
    fn v1_upgrade_shim_matches_flat_fields() {
        let j = Json::parse(
            r#"{"cmd": "generate", "solver": "trapezoidal:0.5", "nfe": 64,
                "schedule": "adaptive:tol=1e-3", "nfe_budget": 48,
                "n_samples": 2, "seed": 7, "family": "markov"}"#,
        )
        .unwrap();
        let p = request_from_json(&j).unwrap();
        let echo = p.v1.expect("flat requests are v1");
        assert_eq!(p.spec.solver(), Solver::Trapezoidal { theta: 0.5 });
        assert_eq!(p.spec.nfe(), 64);
        assert_eq!(p.spec.n_samples(), 2);
        assert_eq!(p.spec.seed(), 7);
        assert_eq!(p.spec.schedule(), ScheduleSpec::Adaptive { tol: 1e-3 });
        assert_eq!(p.spec.nfe_budget(), Some(48));
        assert_eq!(echo.schedule, ScheduleSpec::Adaptive { tol: 1e-3 });
        assert_eq!(echo.nfe_budget, Some(48));
        assert_eq!(echo.window_ratio, None);

        // v2 envelope of the upgraded spec parses to the same spec.
        let v2 = request_to_json("generate", &p.spec);
        let p2 = request_from_json(&Json::parse(&v2.to_string()).unwrap()).unwrap();
        assert!(p2.v1.is_none());
        assert_eq!(p2.spec, p.spec);
    }

    #[test]
    fn invalid_requests_die_typed_at_the_boundary() {
        // Knob mismatch via v1.
        let j = Json::parse(r#"{"solver": "tau", "nfe": 8, "slack": 2.0}"#).unwrap();
        let e = request_from_json(&j).unwrap_err();
        assert_eq!(e.code(), "knob_needs_exact");
        // "exact" routed through the scheme arm still builds an Exact spec
        // (the builder owns the routing) ...
        let j = Json::parse(
            r#"{"v": 2, "spec": {"solver": {"type": "scheme", "solver": "exact", "nfe": 8}}}"#,
        )
        .unwrap();
        let p = request_from_json(&j).unwrap();
        assert_eq!(p.spec.solver(), Solver::Exact);
        // ... but a budget on it is not representable.
        let j = Json::parse(
            r#"{"v": 2, "spec": {"solver": {"type": "scheme", "solver": "exact",
                "nfe": 8, "nfe_budget": 4}}}"#,
        )
        .unwrap();
        let e = request_from_json(&j).unwrap_err();
        assert_eq!(e.code(), "budget_on_exact");
        // θ range via v1 string.
        let j = Json::parse(r#"{"solver": "rk2:0.8", "nfe": 16}"#).unwrap();
        let e = request_from_json(&j).unwrap_err();
        assert_eq!(e.code(), "parse_error");
        assert!(format!("{e}").contains("theta"));
        // Unknown version.
        let j = Json::parse(r#"{"v": 3, "spec": {}}"#).unwrap();
        assert!(request_from_json(&j).is_err());
        // Missing required fields.
        let j = Json::parse(r#"{"v": 2, "spec": {"solver": {"type": "scheme"}}}"#).unwrap();
        assert!(request_from_json(&j).is_err());
    }

    #[test]
    fn qos_fields_round_trip_and_stay_silent_by_default() {
        // Defaults: the writer emits NO QoS field.
        let plain = SamplingSpec::builder().build().unwrap();
        let j = spec_to_json(&plain);
        let text = j.to_string();
        assert!(!text.contains("deadline_ms") && !text.contains("priority"), "{text}");
        assert!(!text.contains("no_degrade"), "{text}");
        assert_eq!(spec_from_json(&j).unwrap(), plain);

        // no_degrade round-trips bit-exactly and is emitted only when true.
        let nd = SamplingSpec::builder().no_degrade(true).build().unwrap();
        let j = Json::parse(&spec_to_json(&nd).to_string()).unwrap();
        let back = spec_from_json(&j).unwrap();
        assert_eq!(back, nd);
        assert!(back.no_degrade());

        // Set: both round-trip bit-exactly through v2.
        let qos = SamplingSpec::builder()
            .deadline_ms(Some(750))
            .priority(3)
            .build()
            .unwrap();
        let j = Json::parse(&spec_to_json(&qos).to_string()).unwrap();
        let back = spec_from_json(&j).unwrap();
        assert_eq!(back, qos);
        assert_eq!(back.deadline_ms(), Some(750));
        assert_eq!(back.priority(), 3);

        // v1 flat form carries them too, and the echo records presence.
        let j = Json::parse(
            r#"{"solver": "tau", "nfe": 8, "deadline_ms": 100, "priority": 2}"#,
        )
        .unwrap();
        let p = request_from_json(&j).unwrap();
        assert_eq!(p.spec.deadline_ms(), Some(100));
        assert_eq!(p.spec.priority(), 2);
        let echo = p.v1.unwrap();
        assert_eq!(echo.deadline_ms, Some(100));
        assert_eq!(echo.priority, Some(2));
        // A v1 request without them leaves the echo empty.
        let j = Json::parse(r#"{"solver": "tau", "nfe": 8}"#).unwrap();
        let echo = request_from_json(&j).unwrap().v1.unwrap();
        assert_eq!(echo.deadline_ms, None);
        assert_eq!(echo.priority, None);

        // Typed rejections at the boundary.
        let j = Json::parse(r#"{"solver": "tau", "nfe": 8, "deadline_ms": 0}"#).unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "deadline_zero");
        let j = Json::parse(r#"{"solver": "tau", "nfe": 8, "priority": 9}"#).unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "priority_out_of_range");
        let j = Json::parse(r#"{"solver": "tau", "nfe": 8, "priority": 300}"#).unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "parse_error");
        let j = Json::parse(
            r#"{"v": 2, "spec": {"deadline_ms": -5,
                "solver": {"type": "scheme", "solver": "tau", "nfe": 8}}}"#,
        )
        .unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "parse_error");
    }

    #[test]
    fn pit_specs_cross_the_boundary_typed() {
        // A fully explicit pit spec parses with resolved getters.
        let j = Json::parse(
            r#"{"v": 2, "spec": {"seed": 4, "progress": true,
                "solver": {"type": "pit", "solver": "trapezoidal:0.5",
                           "nfe": 64, "sweeps_max": 8, "tol": 0.5}}}"#,
        )
        .unwrap();
        let p = request_from_json(&j).unwrap();
        assert!(p.spec.pit());
        assert!(p.spec.progress());
        assert_eq!(p.spec.sweeps_max(), Some(8));
        assert_eq!(p.spec.pit_tol(), Some(0.5));
        // Knob-free pit resolves defaults (sweep cap = step count, tol 0)
        // and the writer echoes the RESOLVED values.
        let j = Json::parse(
            r#"{"v": 2, "spec": {"solver": {"type": "pit", "solver": "tau", "nfe": 16}}}"#,
        )
        .unwrap();
        let p = request_from_json(&j).unwrap();
        assert_eq!(p.spec.sweeps_max(), Some(16));
        assert_eq!(p.spec.pit_tol(), Some(0.0));
        let echo = spec_to_json(&p.spec).to_string();
        assert!(echo.contains("\"sweeps_max\""), "{echo}");
        assert!(!echo.contains("\"progress\""), "progress stays silent off: {echo}");
        // Invalid combinations die typed at the boundary.
        let j = Json::parse(
            r#"{"v": 2, "spec": {"solver": {"type": "pit", "solver": "exact", "nfe": 16}}}"#,
        )
        .unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "pit_needs_scheme");
        let j = Json::parse(
            r#"{"v": 2, "spec": {"solver": {"type": "pit", "solver": "tau",
                "nfe": 16, "sweeps_max": 0}}}"#,
        )
        .unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "sweeps_max_zero");
        let j = Json::parse(
            r#"{"v": 2, "spec": {"solver": {"type": "pit", "solver": "tau",
                "nfe": 16, "tol": -0.5}}}"#,
        )
        .unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "pit_tol_invalid");
        // The scheme arm ignores unknown fields, so pit-only knobs cannot
        // sneak through the wire without pit — but the builder-level guard
        // still exists for direct (CLI) callers; pin its code here.
        let e = SamplingSpec::builder()
            .solver(Solver::TauLeaping)
            .nfe(16)
            .sweeps_max(Some(4))
            .build()
            .unwrap_err();
        assert_eq!(e.code(), "knob_needs_pit");
    }

    #[test]
    fn request_keys_parse_and_validate() {
        let spec = SamplingSpec::builder().build().unwrap();
        // Writer emits the key; parser returns it.
        let j = request_to_json_with_key("generate", &spec, Some("job-7f3a"));
        let p = request_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(p.request_key.as_deref(), Some("job-7f3a"));
        assert_eq!(p.spec, spec);
        // Keyless envelopes parse with no key (and serialize without one).
        let j = request_to_json("generate", &spec);
        assert!(!j.to_string().contains("request_key"));
        let p = request_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(p.request_key, None);
        // Degenerate keys are rejected typed.
        let j = Json::parse(
            r#"{"v": 2, "request_key": "", "spec": {
                "solver": {"type": "scheme", "solver": "tau", "nfe": 8}}}"#,
        )
        .unwrap();
        let e = request_from_json(&j).unwrap_err();
        assert_eq!(e.code(), "parse_error");
        assert!(format!("{e}").contains("request_key"));
        let long = "k".repeat(MAX_REQUEST_KEY_LEN + 1);
        let j = Json::parse(&format!(
            r#"{{"v": 2, "request_key": "{long}", "spec": {{
                "solver": {{"type": "scheme", "solver": "tau", "nfe": 8}}}}}}"#
        ))
        .unwrap();
        assert_eq!(request_from_json(&j).unwrap_err().code(), "parse_error");
        // v1 flat requests never carry keys.
        let j = Json::parse(r#"{"solver": "tau", "nfe": 8, "request_key": "x"}"#).unwrap();
        assert_eq!(request_from_json(&j).unwrap().request_key, None);
    }

    #[test]
    fn seed_and_id_survive_above_2_53() {
        let big = (1u64 << 53) + 12345;
        let j = Json::parse(&format!(r#"{{"solver": "tau", "nfe": 8, "seed": {big}}}"#)).unwrap();
        let p = request_from_json(&j).unwrap();
        assert_eq!(p.spec.seed(), big);
        // And back out through the v2 writer.
        let re = Json::parse(&spec_to_json(&p.spec).to_string()).unwrap();
        assert_eq!(re.get("seed").unwrap().as_u64().unwrap(), big);

        // Malformed seeds are rejected instead of silently coerced to a
        // DIFFERENT stream (the old f64 path sampled "seed": -1 as 0 and
        // 1.5 as 1); integral floats still pass for legacy clients.
        for bad in [r#"{"solver": "tau", "nfe": 8, "seed": -1}"#,
                    r#"{"solver": "tau", "nfe": 8, "seed": 1.5}"#] {
            let e = request_from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(e.code(), "parse_error", "{bad}");
        }
        let j = Json::parse(r#"{"solver": "tau", "nfe": 8, "seed": 7.0}"#).unwrap();
        assert_eq!(request_from_json(&j).unwrap().spec.seed(), 7);
    }
}
