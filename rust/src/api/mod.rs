//! The public request API: one typed spec, one validating builder, one
//! mechanically derived batch key, one versioned wire format.
//!
//! Before this module existed, every new sampler knob was a flat optional
//! field threaded by hand through ~7 surfaces (request parse → serialize →
//! batch key → scheduler → server echo → client opts → CLI), with
//! validation split between parse time and coordinator intake.  Now:
//!
//! - [`SamplingSpec`] ([`spec`]) is the single validated value object; its
//!   [`SolverCfg`] enum makes illegal knob combinations unrepresentable
//!   (no `nfe_budget` on exact, no `window_ratio` on grid schemes), and
//!   [`SpecBuilder`] is the only constructor — a spec in hand is proof of
//!   validity, so the scheduler re-validates nothing.
//! - [`BatchKey::of`] ([`key`]) hashes the spec's resolved execution plan,
//!   so co-batching is correct by construction.
//! - [`wire`] owns the versioned envelope: the structured v2 form plus the
//!   v1 auto-upgrade shim that keeps every legacy flat request serving
//!   bit-identical responses.
//! - [`CancelToken`]/[`StopCtl`] (re-exported from [`crate::util::cancel`])
//!   are the cooperative cancellation handles the driver and the exact
//!   simulators poll, powering the server's `cancel` verb and the
//!   `max_events` guard.

pub mod key;
pub mod spec;
pub mod wire;

pub use crate::util::cancel::{CancelToken, StopCtl};
pub use key::BatchKey;
pub use spec::{ExecPlan, SamplingSpec, SolverCfg, SpecBuilder, SpecError};
