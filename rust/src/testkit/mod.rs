//! Property-testing mini-framework (proptest is not vendored in this image).
//!
//! `check(name, cases, |g| ...)` runs the closure against `cases` seeded
//! generators; on failure it re-runs a deterministic shrink ladder (halving
//! sizes produced by the generator where possible is the caller's job — the
//! framework guarantees the failing *seed* is printed so any failure is
//! exactly reproducible with `FASTDDS_PT_SEED`).

pub mod fault;

use crate::util::rng::{Rng, Xoshiro256};

/// Generator handle passed to properties: seeded, with convenience draws.
pub struct Gen {
    pub rng: Xoshiro256,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Self { rng: Xoshiro256::seed_from_u64(seed), seed }
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        lo + self.rng.gen_usize(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.gen_f64() * (hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.gen_bool(p)
    }

    pub fn vec_f64(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn vec_usize(&mut self, len: usize, lo: usize, hi: usize) -> Vec<usize> {
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// A random probability vector (normalised positive entries).
    pub fn simplex(&mut self, len: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..len).map(|_| -self.rng.gen_f64().ln()).collect();
        let tot: f64 = v.iter().sum();
        for x in v.iter_mut() {
            *x /= tot;
        }
        v
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.gen_usize(xs.len())]
    }
}

/// Run a property over `cases` random seeds. Panics with the failing seed.
pub fn check<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base = std::env::var("FASTDDS_PT_SEED")
        .ok()
        .and_then(|s| s.parse().ok());
    if let Some(seed) = base {
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!("property {name} failed (replay seed {seed}): {msg}");
        }
        return;
    }
    for i in 0..cases {
        // Derive deterministic-but-spread seeds from the property name.
        let seed = fnv1a(name).wrapping_add(i.wrapping_mul(0x9E3779B97F4A7C15));
        let mut g = Gen::new(seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property {name} failed on case {i} \
                 (replay with FASTDDS_PT_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assertion helpers returning Result<(), String> for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !($cond) {
            return Err(format!($($fmt)*));
        }
    };
}

pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Approximate float comparison for properties.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 20, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!((0.0..=1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "replay with FASTDDS_PT_SEED=")]
    fn check_reports_seed_on_failure() {
        check("always_fails", 3, |_| Err("boom".to_string()));
    }

    #[test]
    fn simplex_sums_to_one() {
        check("simplex", 50, |g| {
            let n = g.usize_in(1, 40);
            let v = g.simplex(n);
            let s: f64 = v.iter().sum();
            prop_assert!(close(s, 1.0, 1e-12, 1e-12), "sum={s}");
            prop_assert!(v.iter().all(|&x| x > 0.0), "non-positive entry");
            Ok(())
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut a = Gen::new(9);
        let mut b = Gen::new(9);
        for _ in 0..10 {
            assert_eq!(a.usize_in(0, 100), b.usize_in(0, 100));
        }
    }
}
