//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] schedules faults by **score-evaluation tick**: the
//! wrapped [`FaultyScore`] counts every score call it forwards (one tick
//! per batched call, not per lane) and fires the planned fault — a panic,
//! a *transient* (retryable) error, or a stall — when its tick comes up.
//! Because the coordinator's dispatch order is deterministic for a fixed
//! request sequence, a plan keyed on ticks reproduces the same failure in
//! the same place on every run: the chaos suite (`tests/chaos.rs`) pins
//! recovery behavior against it, bit for bit where the contract promises
//! it.
//!
//! [`FaultKind::Err`] ([`FaultPlan::err_at`]) models a *recoverable*
//! backend fault — unlike [`FaultKind::Panic`], its payload carries the
//! `[transient]` marker ([`crate::coordinator::health::TRANSIENT`]), so
//! the coordinator retries it under the health layer's backoff budget
//! instead of isolating the lane as a bug.
//!
//! Injected panics carry the [`INJECTED`] marker so
//! [`silence_injected_panics`] can keep expected unwinds out of the test
//! output while real panics still print.  Probabilistic injection
//! ([`FaultPlan::random_panics`] for panics, [`FaultPlan::flaky`] for
//! latency jitter — used by the fault-injection and stalled-backend bench
//! rows) hashes `(seed, tick)` — deterministic for a fixed seed, no
//! shared RNG.  [`FaultyScore::set_plan`] swaps the plan mid-flight so a
//! test can warm up clean, then arm faults at a known tick
//! ([`FaultyScore::calls`]).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::coordinator::health::TRANSIENT;
use crate::ctmc::uniformization::{ExactCfg, ExactStats};
use crate::score::{ScoreSource, Tok};
use crate::util::cancel::StopCtl;
use crate::util::rng::Xoshiro256;

/// Marker embedded in every injected panic payload.
pub const INJECTED: &str = "[injected fault]";

/// One scheduled fault.
#[derive(Clone, Copy, Debug)]
pub enum FaultKind {
    /// Panic inside the score call (exercises `catch_unwind` isolation).
    Panic,
    /// A *transient* (recoverable) backend fault: panics with the
    /// `[transient]` marker, so the coordinator's health layer retries it
    /// within the budget instead of failing the lane as a bug.
    Err,
    /// Sleep before evaluating (a stalled/slow lane: deadlines keep
    /// ticking, the solver polls its stop token at the next window).
    Stall(Duration),
}

/// Deterministic fault schedule keyed on score-evaluation ticks.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    at: BTreeMap<u64, FaultKind>,
    /// Optional (seed, per-tick probability) for hash-based injection.
    random_panic: Option<(u64, f64)>,
    /// Optional (seed, per-tick probability, stall duration) latency
    /// jitter — a hash-deterministic "flaky backend".
    flaky: Option<(u64, f64, Duration)>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Panic on tick `tick` (0 = the first score call after wrapping).
    pub fn panic_at(mut self, tick: u64) -> Self {
        self.at.insert(tick, FaultKind::Panic);
        self
    }

    /// Stall for `dur` on tick `tick`, then evaluate normally.
    pub fn stall_at(mut self, tick: u64, dur: Duration) -> Self {
        self.at.insert(tick, FaultKind::Stall(dur));
        self
    }

    /// Fail tick `tick` with a *transient* (retryable) fault: the panic
    /// payload carries the `[transient]` marker, so the health layer
    /// retries instead of isolating the lane.
    pub fn err_at(mut self, tick: u64) -> Self {
        self.at.insert(tick, FaultKind::Err);
        self
    }

    /// Panic on each tick independently with probability `p`, decided by
    /// hashing `(seed, tick)`: deterministic for a fixed seed, and ticks
    /// pinned by `panic_at`/`stall_at` take precedence.
    pub fn random_panics(mut self, seed: u64, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "probability {p} outside [0, 1]");
        self.random_panic = Some((seed, p));
        self
    }

    /// Stall each tick independently for `dur` with probability `p`
    /// (hash-deterministic latency jitter: a flaky, occasionally-slow
    /// backend).  Ticks pinned by `panic_at`/`stall_at`/`err_at` and
    /// `random_panics` hits take precedence.
    pub fn flaky(mut self, seed: u64, p: f64, dur: Duration) -> Self {
        assert!((0.0..=1.0).contains(&p), "probability {p} outside [0, 1]");
        self.flaky = Some((seed, p, dur));
        self
    }

    pub fn fault_for(&self, tick: u64) -> Option<FaultKind> {
        if let Some(&f) = self.at.get(&tick) {
            return Some(f);
        }
        if let Some((seed, p)) = self.random_panic {
            if hash_unit(seed, tick) < p {
                return Some(FaultKind::Panic);
            }
        }
        let (seed, p, dur) = self.flaky?;
        // Decorrelated from `random_panics` under a shared seed.
        (hash_unit(seed ^ 0xA5A5_A5A5_A5A5_A5A5, tick) < p)
            .then_some(FaultKind::Stall(dur))
    }
}

/// splitmix64-style mix of (seed, tick) into [0, 1).
fn hash_unit(seed: u64, tick: u64) -> f64 {
    let mut z = seed ^ tick.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// A [`ScoreSource`] wrapper that applies a [`FaultPlan`], forwarding
/// every call to the inner source.  Each forwarded score evaluation —
/// dense, sparse, batched (one tick for the whole batch) or exact — first
/// advances the tick counter and fires any fault scheduled for it.
pub struct FaultyScore<S: ScoreSource> {
    inner: S,
    /// Swappable mid-flight ([`Self::set_plan`]): tests warm up clean,
    /// then arm faults at a known tick.
    plan: Mutex<FaultPlan>,
    calls: AtomicU64,
}

impl<S: ScoreSource> FaultyScore<S> {
    pub fn new(inner: S, plan: FaultPlan) -> Self {
        Self { inner, plan: Mutex::new(plan), calls: AtomicU64::new(0) }
    }

    /// Score calls forwarded so far (= the next tick to fire).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Replace the fault schedule (the tick counter keeps running): combine
    /// with [`Self::calls`] to plan faults relative to "now".
    pub fn set_plan(&self, plan: FaultPlan) {
        *self.plan.lock().unwrap_or_else(|e| e.into_inner()) = plan;
    }

    fn tick(&self) {
        let t = self.calls.fetch_add(1, Ordering::Relaxed);
        let fault = self.plan.lock().unwrap_or_else(|e| e.into_inner()).fault_for(t);
        match fault {
            None => {}
            Some(FaultKind::Panic) => {
                std::panic::panic_any(format!("{INJECTED} score call {t}"))
            }
            Some(FaultKind::Err) => {
                std::panic::panic_any(format!("{INJECTED}{TRANSIENT} score call {t}"))
            }
            Some(FaultKind::Stall(d)) => std::thread::sleep(d),
        }
    }
}

impl<S: ScoreSource> ScoreSource for FaultyScore<S> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn mask_id(&self) -> Tok {
        self.inner.mask_id()
    }

    fn probs_into(&self, tokens: &[Tok], t: f64, out: &mut [f64]) {
        self.tick();
        self.inner.probs_into(tokens, t, out);
    }

    fn probs_masked_into(
        &self,
        tokens: &[Tok],
        masked_idx: &[usize],
        t: f64,
        out: &mut [f64],
    ) {
        self.tick();
        self.inner.probs_masked_into(tokens, masked_idx, t, out);
    }

    // One tick per batched call, NOT per lane: the default implementation
    // would fan out through `probs_masked_into` and double-count (and
    // panic per lane instead of per dispatch).
    fn probs_masked_batch(
        &self,
        reqs: &[(&[Tok], &[usize])],
        t: f64,
        outs: &mut [&mut [f64]],
    ) {
        self.tick();
        self.inner.probs_masked_batch(reqs, t, outs);
    }

    // Same rule for the PIT sweep evaluation: one tick per batched
    // slice dispatch (the default would fan out through
    // `probs_masked_into` and tick per slice).
    fn probs_masked_slices(&self, reqs: &[(&[Tok], &[usize], f64)], outs: &mut [&mut [f64]]) {
        self.tick();
        self.inner.probs_masked_slices(reqs, outs);
    }

    fn exact_uniform(
        &self,
        delta: f64,
        cfg: &ExactCfg,
        rng: &mut Xoshiro256,
    ) -> Option<(Vec<Tok>, ExactStats)> {
        self.tick();
        self.inner.exact_uniform(delta, cfg, rng)
    }

    fn exact_uniform_ctl(
        &self,
        delta: f64,
        cfg: &ExactCfg,
        stop: &StopCtl,
        rng: &mut Xoshiro256,
    ) -> Option<(Vec<Tok>, ExactStats, bool)> {
        self.tick();
        self.inner.exact_uniform_ctl(delta, cfg, stop, rng)
    }
}

/// Install a process-wide panic hook that suppresses backtrace noise for
/// panics carrying the [`INJECTED`] marker (including supervisor drills
/// whose reason embeds it) while real panics still print.  Idempotent.
pub fn silence_injected_panics() {
    static SILENCE: std::sync::Once = std::sync::Once::new();
    SILENCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&'static str>().copied());
            if msg.is_some_and(|m| m.contains(INJECTED)) {
                return;
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::markov::{MarkovChain, MarkovOracle};

    fn oracle() -> MarkovOracle {
        let mut rng = Xoshiro256::seed_from_u64(23);
        MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 8)
    }

    #[test]
    fn plan_fires_on_its_tick_only() {
        let plan = FaultPlan::new().panic_at(2);
        let fs = FaultyScore::new(oracle(), plan);
        let toks = crate::score::all_masked(8, fs.mask_id());
        let mut out = vec![0.0; 8 * 5];
        fs.probs_into(&toks, 0.5, &mut out); // tick 0
        fs.probs_into(&toks, 0.5, &mut out); // tick 1
        assert_eq!(fs.calls(), 2);
        let fs = std::sync::Arc::new(fs);
        let fs2 = std::sync::Arc::clone(&fs);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let mut out = vec![0.0; 8 * 5];
            let toks = crate::score::all_masked(8, fs2.mask_id());
            fs2.probs_into(&toks, 0.5, &mut out); // tick 2: boom
        }));
        let payload = caught.expect_err("tick 2 must panic");
        let msg = payload.downcast_ref::<String>().unwrap();
        assert!(msg.contains(INJECTED), "{msg}");
    }

    #[test]
    fn wrapped_scores_are_bit_identical_when_no_fault_fires() {
        let base = oracle();
        let fs = FaultyScore::new(oracle(), FaultPlan::new());
        let toks = crate::score::all_masked(8, base.mask_id());
        let mut a = vec![0.0; 8 * 5];
        let mut b = vec![0.0; 8 * 5];
        base.probs_into(&toks, 0.3, &mut a);
        fs.probs_into(&toks, 0.3, &mut b);
        assert_eq!(a, b, "a quiet wrapper must be invisible");
    }

    #[test]
    fn batched_call_costs_one_tick() {
        let fs = FaultyScore::new(oracle(), FaultPlan::new());
        let toks = crate::score::all_masked(8, fs.mask_id());
        let idx: Vec<usize> = (0..8).collect();
        let reqs: Vec<(&[Tok], &[usize])> =
            vec![(&toks, &idx), (&toks, &idx), (&toks, &idx)];
        let mut bufs: Vec<Vec<f64>> = vec![vec![0.0; 8 * 5]; 3];
        let mut outs: Vec<&mut [f64]> =
            bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        fs.probs_masked_batch(&reqs, 0.5, &mut outs);
        assert_eq!(fs.calls(), 1, "3 lanes, one dispatch, one tick");
    }

    #[test]
    fn err_fault_is_marked_transient_but_panic_is_not() {
        let fs = FaultyScore::new(oracle(), FaultPlan::new().err_at(0).panic_at(1));
        let fs = std::sync::Arc::new(fs);
        let toks = crate::score::all_masked(8, fs.mask_id());
        let f = std::sync::Arc::clone(&fs);
        let t = toks.clone();
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let mut out = vec![0.0; 8 * 5];
            f.probs_into(&t, 0.5, &mut out); // tick 0: transient err
        }))
        .expect_err("tick 0 must fail");
        assert!(crate::coordinator::health::is_transient(payload.as_ref()));
        let msg = payload.downcast_ref::<String>().unwrap();
        assert!(msg.contains(INJECTED), "still silenceable: {msg}");
        let f = std::sync::Arc::clone(&fs);
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let mut out = vec![0.0; 8 * 5];
            f.probs_into(&toks, 0.5, &mut out); // tick 1: plain panic
        }))
        .expect_err("tick 1 must panic");
        assert!(
            !crate::coordinator::health::is_transient(payload.as_ref()),
            "a plain panic must NOT read as transient"
        );
    }

    #[test]
    fn set_plan_swaps_faults_mid_flight() {
        let fs = FaultyScore::new(oracle(), FaultPlan::new());
        let toks = crate::score::all_masked(8, fs.mask_id());
        let mut out = vec![0.0; 8 * 5];
        fs.probs_into(&toks, 0.5, &mut out); // tick 0, clean
        assert_eq!(fs.calls(), 1);
        fs.set_plan(FaultPlan::new().err_at(fs.calls()));
        let fs = std::sync::Arc::new(fs);
        let f = std::sync::Arc::clone(&fs);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let toks = crate::score::all_masked(8, f.mask_id());
            let mut out = vec![0.0; 8 * 5];
            f.probs_into(&toks, 0.5, &mut out); // tick 1: armed by set_plan
        }));
        assert!(caught.is_err(), "the swapped-in plan must fire");
        // Disarm again: later ticks are clean.
        fs.set_plan(FaultPlan::new());
        let toks = crate::score::all_masked(8, fs.mask_id());
        fs.probs_into(&toks, 0.5, &mut out);
        assert_eq!(fs.calls(), 3);
    }

    #[test]
    fn flaky_jitter_is_deterministic_and_pinned_ticks_win() {
        let dur = Duration::from_millis(1);
        let plan = FaultPlan::new().panic_at(4).flaky(7, 0.2, dur);
        let fired: Vec<(u64, bool)> = (0..500)
            .filter_map(|t| {
                plan.fault_for(t).map(|f| (t, matches!(f, FaultKind::Stall(_))))
            })
            .collect();
        let again: Vec<(u64, bool)> = (0..500)
            .filter_map(|t| {
                plan.fault_for(t).map(|f| (t, matches!(f, FaultKind::Stall(_))))
            })
            .collect();
        assert_eq!(fired, again, "same seed, same jitter schedule");
        let stalls = fired.iter().filter(|(_, s)| *s).count();
        assert!(
            stalls > 50 && stalls < 180,
            "p=0.2 over 500 ticks stalled {stalls} times"
        );
        assert!(
            matches!(plan.fault_for(4), Some(FaultKind::Panic)),
            "pinned ticks take precedence over jitter"
        );
        // All stalls carry the configured duration.
        for (t, _) in fired.iter().filter(|(_, s)| *s) {
            assert!(matches!(plan.fault_for(*t), Some(FaultKind::Stall(d)) if d == dur));
        }
    }

    #[test]
    fn random_panics_are_deterministic_and_roughly_calibrated() {
        let plan = FaultPlan::new().random_panics(7, 0.1);
        let fired: Vec<u64> =
            (0..1000).filter(|&t| plan.fault_for(t).is_some()).collect();
        let again: Vec<u64> =
            (0..1000).filter(|&t| plan.fault_for(t).is_some()).collect();
        assert_eq!(fired, again, "same seed, same schedule");
        assert!(
            fired.len() > 50 && fired.len() < 200,
            "p=0.1 over 1000 ticks fired {} times",
            fired.len()
        );
        let other = FaultPlan::new().random_panics(8, 0.1);
        let other_fired: Vec<u64> =
            (0..1000).filter(|&t| other.fault_for(t).is_some()).collect();
        assert_ne!(fired, other_fired, "different seeds, different schedule");
    }
}
