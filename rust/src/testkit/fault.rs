//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] schedules faults by **score-evaluation tick**: the
//! wrapped [`FaultyScore`] counts every score call it forwards (one tick
//! per batched call, not per lane) and fires the planned fault — a panic
//! or a stall — when its tick comes up.  Because the coordinator's
//! dispatch order is deterministic for a fixed request sequence, a plan
//! keyed on ticks reproduces the same failure in the same place on every
//! run: the chaos suite (`tests/chaos.rs`) pins recovery behavior against
//! it, bit for bit where the contract promises it.
//!
//! Injected panics carry the [`INJECTED`] marker so
//! [`silence_injected_panics`] can keep expected unwinds out of the test
//! output while real panics still print.  Probabilistic injection
//! ([`FaultPlan::random_panics`], used by the fault-injection bench row)
//! hashes `(seed, tick)` — deterministic for a fixed seed, no shared RNG.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::ctmc::uniformization::{ExactCfg, ExactStats};
use crate::score::{ScoreSource, Tok};
use crate::util::cancel::StopCtl;
use crate::util::rng::Xoshiro256;

/// Marker embedded in every injected panic payload.
pub const INJECTED: &str = "[injected fault]";

/// One scheduled fault.
#[derive(Clone, Copy, Debug)]
pub enum FaultKind {
    /// Panic inside the score call (exercises `catch_unwind` isolation).
    Panic,
    /// Sleep before evaluating (a stalled/slow lane: deadlines keep
    /// ticking, the solver polls its stop token at the next window).
    Stall(Duration),
}

/// Deterministic fault schedule keyed on score-evaluation ticks.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    at: BTreeMap<u64, FaultKind>,
    /// Optional (seed, per-tick probability) for hash-based injection.
    random_panic: Option<(u64, f64)>,
}

impl FaultPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Panic on tick `tick` (0 = the first score call after wrapping).
    pub fn panic_at(mut self, tick: u64) -> Self {
        self.at.insert(tick, FaultKind::Panic);
        self
    }

    /// Stall for `dur` on tick `tick`, then evaluate normally.
    pub fn stall_at(mut self, tick: u64, dur: Duration) -> Self {
        self.at.insert(tick, FaultKind::Stall(dur));
        self
    }

    /// Panic on each tick independently with probability `p`, decided by
    /// hashing `(seed, tick)`: deterministic for a fixed seed, and ticks
    /// pinned by `panic_at`/`stall_at` take precedence.
    pub fn random_panics(mut self, seed: u64, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p), "probability {p} outside [0, 1]");
        self.random_panic = Some((seed, p));
        self
    }

    pub fn fault_for(&self, tick: u64) -> Option<FaultKind> {
        if let Some(&f) = self.at.get(&tick) {
            return Some(f);
        }
        let (seed, p) = self.random_panic?;
        (hash_unit(seed, tick) < p).then_some(FaultKind::Panic)
    }
}

/// splitmix64-style mix of (seed, tick) into [0, 1).
fn hash_unit(seed: u64, tick: u64) -> f64 {
    let mut z = seed ^ tick.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^= z >> 31;
    (z >> 11) as f64 / (1u64 << 53) as f64
}

/// A [`ScoreSource`] wrapper that applies a [`FaultPlan`], forwarding
/// every call to the inner source.  Each forwarded score evaluation —
/// dense, sparse, batched (one tick for the whole batch) or exact — first
/// advances the tick counter and fires any fault scheduled for it.
pub struct FaultyScore<S: ScoreSource> {
    inner: S,
    plan: FaultPlan,
    calls: AtomicU64,
}

impl<S: ScoreSource> FaultyScore<S> {
    pub fn new(inner: S, plan: FaultPlan) -> Self {
        Self { inner, plan, calls: AtomicU64::new(0) }
    }

    /// Score calls forwarded so far (= the next tick to fire).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    fn tick(&self) {
        let t = self.calls.fetch_add(1, Ordering::Relaxed);
        match self.plan.fault_for(t) {
            None => {}
            Some(FaultKind::Panic) => {
                std::panic::panic_any(format!("{INJECTED} score call {t}"))
            }
            Some(FaultKind::Stall(d)) => std::thread::sleep(d),
        }
    }
}

impl<S: ScoreSource> ScoreSource for FaultyScore<S> {
    fn vocab(&self) -> usize {
        self.inner.vocab()
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn mask_id(&self) -> Tok {
        self.inner.mask_id()
    }

    fn probs_into(&self, tokens: &[Tok], t: f64, out: &mut [f64]) {
        self.tick();
        self.inner.probs_into(tokens, t, out);
    }

    fn probs_masked_into(
        &self,
        tokens: &[Tok],
        masked_idx: &[usize],
        t: f64,
        out: &mut [f64],
    ) {
        self.tick();
        self.inner.probs_masked_into(tokens, masked_idx, t, out);
    }

    // One tick per batched call, NOT per lane: the default implementation
    // would fan out through `probs_masked_into` and double-count (and
    // panic per lane instead of per dispatch).
    fn probs_masked_batch(
        &self,
        reqs: &[(&[Tok], &[usize])],
        t: f64,
        outs: &mut [&mut [f64]],
    ) {
        self.tick();
        self.inner.probs_masked_batch(reqs, t, outs);
    }

    // Same rule for the PIT sweep evaluation: one tick per batched
    // slice dispatch (the default would fan out through
    // `probs_masked_into` and tick per slice).
    fn probs_masked_slices(&self, reqs: &[(&[Tok], &[usize], f64)], outs: &mut [&mut [f64]]) {
        self.tick();
        self.inner.probs_masked_slices(reqs, outs);
    }

    fn exact_uniform(
        &self,
        delta: f64,
        cfg: &ExactCfg,
        rng: &mut Xoshiro256,
    ) -> Option<(Vec<Tok>, ExactStats)> {
        self.tick();
        self.inner.exact_uniform(delta, cfg, rng)
    }

    fn exact_uniform_ctl(
        &self,
        delta: f64,
        cfg: &ExactCfg,
        stop: &StopCtl,
        rng: &mut Xoshiro256,
    ) -> Option<(Vec<Tok>, ExactStats, bool)> {
        self.tick();
        self.inner.exact_uniform_ctl(delta, cfg, stop, rng)
    }
}

/// Install a process-wide panic hook that suppresses backtrace noise for
/// panics carrying the [`INJECTED`] marker (including supervisor drills
/// whose reason embeds it) while real panics still print.  Idempotent.
pub fn silence_injected_panics() {
    static SILENCE: std::sync::Once = std::sync::Once::new();
    SILENCE.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            let payload = info.payload();
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&'static str>().copied());
            if msg.is_some_and(|m| m.contains(INJECTED)) {
                return;
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::markov::{MarkovChain, MarkovOracle};

    fn oracle() -> MarkovOracle {
        let mut rng = Xoshiro256::seed_from_u64(23);
        MarkovOracle::new(MarkovChain::generate(&mut rng, 5, 0.5), 8)
    }

    #[test]
    fn plan_fires_on_its_tick_only() {
        let plan = FaultPlan::new().panic_at(2);
        let fs = FaultyScore::new(oracle(), plan);
        let toks = crate::score::all_masked(8, fs.mask_id());
        let mut out = vec![0.0; 8 * 5];
        fs.probs_into(&toks, 0.5, &mut out); // tick 0
        fs.probs_into(&toks, 0.5, &mut out); // tick 1
        assert_eq!(fs.calls(), 2);
        let fs = std::sync::Arc::new(fs);
        let fs2 = std::sync::Arc::clone(&fs);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let mut out = vec![0.0; 8 * 5];
            let toks = crate::score::all_masked(8, fs2.mask_id());
            fs2.probs_into(&toks, 0.5, &mut out); // tick 2: boom
        }));
        let payload = caught.expect_err("tick 2 must panic");
        let msg = payload.downcast_ref::<String>().unwrap();
        assert!(msg.contains(INJECTED), "{msg}");
    }

    #[test]
    fn wrapped_scores_are_bit_identical_when_no_fault_fires() {
        let base = oracle();
        let fs = FaultyScore::new(oracle(), FaultPlan::new());
        let toks = crate::score::all_masked(8, base.mask_id());
        let mut a = vec![0.0; 8 * 5];
        let mut b = vec![0.0; 8 * 5];
        base.probs_into(&toks, 0.3, &mut a);
        fs.probs_into(&toks, 0.3, &mut b);
        assert_eq!(a, b, "a quiet wrapper must be invisible");
    }

    #[test]
    fn batched_call_costs_one_tick() {
        let fs = FaultyScore::new(oracle(), FaultPlan::new());
        let toks = crate::score::all_masked(8, fs.mask_id());
        let idx: Vec<usize> = (0..8).collect();
        let reqs: Vec<(&[Tok], &[usize])> =
            vec![(&toks, &idx), (&toks, &idx), (&toks, &idx)];
        let mut bufs: Vec<Vec<f64>> = vec![vec![0.0; 8 * 5]; 3];
        let mut outs: Vec<&mut [f64]> =
            bufs.iter_mut().map(|b| b.as_mut_slice()).collect();
        fs.probs_masked_batch(&reqs, 0.5, &mut outs);
        assert_eq!(fs.calls(), 1, "3 lanes, one dispatch, one tick");
    }

    #[test]
    fn random_panics_are_deterministic_and_roughly_calibrated() {
        let plan = FaultPlan::new().random_panics(7, 0.1);
        let fired: Vec<u64> =
            (0..1000).filter(|&t| plan.fault_for(t).is_some()).collect();
        let again: Vec<u64> =
            (0..1000).filter(|&t| plan.fault_for(t).is_some()).collect();
        assert_eq!(fired, again, "same seed, same schedule");
        assert!(
            fired.len() > 50 && fired.len() < 200,
            "p=0.1 over 1000 ticks fired {} times",
            fired.len()
        );
        let other = FaultPlan::new().random_panics(8, 0.1);
        let other_fired: Vec<u64> =
            (0..1000).filter(|&t| other.fault_for(t).is_some()).collect();
        assert_ne!(fired, other_fired, "different seeds, different schedule");
    }
}
