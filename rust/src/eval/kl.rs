//! Empirical KL divergence with bootstrap confidence intervals — the Fig. 2
//! metric, following App. D.2 exactly: generate samples, `bincount` them,
//! compute KL(p0 || q_hat), and bootstrap the samples 1000 times for a 95%
//! interval.

use crate::util::rng::{Rng, Xoshiro256};
use crate::util::stats::quantile_sorted;

/// KL(p || q) for discrete distributions (natural log).  q entries are
/// floored to avoid infinite divergence from empty empirical bins.
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    p.iter()
        .zip(q)
        .map(|(&pi, &qi)| {
            if pi <= 0.0 {
                0.0
            } else {
                pi * (pi / qi.max(1e-12)).ln()
            }
        })
        .sum()
}

/// Result of the Fig. 2 estimator on one configuration.
#[derive(Clone, Debug)]
pub struct KlEstimate {
    pub kl: f64,
    pub ci_lo: f64,
    pub ci_hi: f64,
}

/// KL(p0 || empirical) with a bootstrap CI over categorical samples.
///
/// `counts[x]` are the sample counts per category. Resampling uses the
/// multinomial bootstrap (equivalent to resampling the raw samples but
/// O(categories) per replicate instead of O(n)).
pub fn kl_with_bootstrap(
    p0: &[f64],
    counts: &[u64],
    n_boot: usize,
    level: f64,
    seed: u64,
) -> KlEstimate {
    let n: u64 = counts.iter().sum();
    assert!(n > 0);
    let q: Vec<f64> = counts.iter().map(|&c| c as f64 / n as f64).collect();
    let kl = kl_divergence(p0, &q);

    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut vals = Vec::with_capacity(n_boot);
    let mut resampled = vec![0u64; counts.len()];
    for _ in 0..n_boot {
        multinomial_resample(&mut rng, &q, n, &mut resampled);
        let qb: Vec<f64> = resampled.iter().map(|&c| c as f64 / n as f64).collect();
        vals.push(kl_divergence(p0, &qb));
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = (1.0 - level) / 2.0;
    KlEstimate {
        kl,
        ci_lo: quantile_sorted(&vals, alpha),
        ci_hi: quantile_sorted(&vals, 1.0 - alpha),
    }
}

/// Draw Multinomial(n, q) by sequential binomial splitting (exact).
fn multinomial_resample<R: Rng>(rng: &mut R, q: &[f64], n: u64, out: &mut [u64]) {
    let mut remaining_n = n;
    let mut remaining_p = 1.0;
    for (i, &qi) in q.iter().enumerate() {
        if remaining_n == 0 || remaining_p <= 0.0 {
            out[i] = 0;
            continue;
        }
        let p = (qi / remaining_p).clamp(0.0, 1.0);
        let draw = if i + 1 == q.len() {
            remaining_n
        } else {
            crate::util::dist::binomial(rng, remaining_n, p)
        };
        out[i] = draw;
        remaining_n -= draw;
        remaining_p -= qi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kl_properties() {
        let p = [0.2, 0.3, 0.5];
        assert!(kl_divergence(&p, &p).abs() < 1e-12);
        let q = [0.4, 0.3, 0.3];
        assert!(kl_divergence(&p, &q) > 0.0);
    }

    #[test]
    fn kl_handles_empty_bins() {
        let p = [0.5, 0.5, 0.0];
        let q = [1.0, 0.0, 0.0];
        let kl = kl_divergence(&p, &q);
        assert!(kl.is_finite() && kl > 0.0);
    }

    #[test]
    fn bootstrap_ci_brackets_point_estimate() {
        let p0 = [0.1, 0.2, 0.3, 0.4];
        let counts = [1100u64, 1900, 3100, 3900];
        let e = kl_with_bootstrap(&p0, &counts, 500, 0.95, 7);
        assert!(e.ci_lo <= e.kl + 1e-9, "{e:?}");
        assert!(e.kl <= e.ci_hi + 1e-9, "{e:?}");
        assert!(e.ci_hi - e.ci_lo < 0.05, "{e:?}");
    }

    #[test]
    fn multinomial_resample_preserves_total() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let q = [0.25, 0.25, 0.25, 0.25];
        let mut out = [0u64; 4];
        for _ in 0..100 {
            multinomial_resample(&mut rng, &q, 1000, &mut out);
            assert_eq!(out.iter().sum::<u64>(), 1000);
        }
    }

    #[test]
    fn ci_shrinks_with_samples() {
        let p0 = [0.3, 0.7];
        let small = kl_with_bootstrap(&p0, &[30, 70], 400, 0.95, 2);
        let large = kl_with_bootstrap(&p0, &[30_000, 70_000], 400, 0.95, 2);
        assert!(
            large.ci_hi - large.ci_lo < small.ci_hi - small.ci_lo,
            "small={small:?} large={large:?}"
        );
    }
}
