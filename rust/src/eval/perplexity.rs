//! Generative perplexity under the exact synthetic data law.
//!
//! The paper scores text samples with a GPT-2 judge; our substitution
//! (DESIGN.md) evaluates the *true* log-likelihood of each generated
//! sequence under the Markov chain the oracle score was derived from —
//! the same monotone functional of sample quality, exact instead of judged.

use crate::score::markov::MarkovChain;
use crate::score::Tok;

/// Per-token perplexity of one sequence: exp(-log p(seq) / len).
pub fn sequence_perplexity(chain: &MarkovChain, seq: &[Tok]) -> f64 {
    assert!(!seq.is_empty());
    (-chain.log_prob(seq) / seq.len() as f64).exp()
}

/// Mean per-token perplexity over a batch (the Tab. 1/2 statistic).
pub fn batch_perplexity(chain: &MarkovChain, seqs: &[Vec<Tok>]) -> f64 {
    assert!(!seqs.is_empty());
    let tot: f64 = seqs.iter().map(|s| sequence_perplexity(chain, s)).sum();
    tot / seqs.len() as f64
}

/// Perplexity of sequences drawn from the chain itself — the floor any
/// sampler is compared against (an ideal sampler matches it in expectation).
pub fn reference_perplexity<R: crate::util::rng::Rng>(
    chain: &MarkovChain,
    seq_len: usize,
    n: usize,
    rng: &mut R,
) -> f64 {
    let seqs: Vec<Vec<Tok>> = (0..n).map(|_| chain.sample(rng, seq_len)).collect();
    batch_perplexity(chain, &seqs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    fn chain() -> MarkovChain {
        let mut rng = Xoshiro256::seed_from_u64(11);
        MarkovChain::generate(&mut rng, 8, 0.4)
    }

    #[test]
    fn perplexity_bounded_by_vocab() {
        let c = chain();
        let mut rng = Xoshiro256::seed_from_u64(0);
        for _ in 0..50 {
            let seq = c.sample(&mut rng, 32);
            let p = sequence_perplexity(&c, &seq);
            assert!(p >= 1.0 && p.is_finite(), "ppl={p}");
        }
    }

    #[test]
    fn true_samples_beat_uniform_noise() {
        let c = chain();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let real = reference_perplexity(&c, 64, 200, &mut rng);
        let noise: Vec<Vec<Tok>> = (0..200)
            .map(|_| (0..64).map(|_| rng.gen_usize(8) as Tok).collect())
            .collect();
        let noisy = batch_perplexity(&c, &noise);
        assert!(real < noisy, "real={real} noisy={noisy}");
    }

    #[test]
    fn deterministic_sequence_matches_manual() {
        let c = chain();
        let seq = vec![0 as Tok, 1, 2];
        let lp = c.pi[0].ln() + c.at(0, 1).ln() + c.at(1, 2).ln();
        let want = (-lp / 3.0).exp();
        assert!((sequence_perplexity(&c, &seq) - want).abs() < 1e-9);
    }

    #[test]
    fn batch_is_mean_of_sequences() {
        let c = chain();
        let mut rng = Xoshiro256::seed_from_u64(2);
        let seqs: Vec<Vec<Tok>> = (0..10).map(|_| c.sample(&mut rng, 16)).collect();
        let batch = batch_perplexity(&c, &seqs);
        let manual: f64 =
            seqs.iter().map(|s| sequence_perplexity(&c, s)).sum::<f64>() / 10.0;
        assert!((batch - manual).abs() < 1e-12);
    }
}
