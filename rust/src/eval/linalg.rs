//! Dense symmetric linear algebra for the FID computation: matrix products,
//! cyclic Jacobi eigendecomposition, and PSD matrix square roots.
//! From scratch — no BLAS/LAPACK is available in this image.
//!
//! Every primitive has an `_into` form writing into caller-owned buffers
//! (matrices re-dimension in place, reusing their allocation), and the
//! Jacobi sweeps run entirely inside an [`EigenWorkspace`] — the FID hot
//! loop (`eval::fid::frechet_distance_with`) performs zero allocations
//! once warm.  Matrix products are k-blocked so the B-operand rows stay in
//! cache across output rows, and the row updates run through the shared
//! blocked primitives in [`crate::score::kernels`] (same per-element op
//! order — results are unchanged bit for bit).

/// Row-major square matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub n: usize,
    pub data: Vec<f64>,
}

/// Cache block: rows of the right operand touched per pass of the blocked
/// product (64 × 64 × 8 B = 32 KiB, comfortably L1/L2-resident).
const BLOCK: usize = 64;

impl Mat {
    pub fn zeros(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n = rows.len();
        let mut m = Self::zeros(n);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), n);
            m.data[i * n..(i + 1) * n].copy_from_slice(r);
        }
        m
    }

    /// Re-dimension to n × n and zero, reusing the allocation.
    pub fn reset(&mut self, n: usize) {
        self.n = n;
        self.data.clear();
        self.data.resize(n * n, 0.0);
    }

    /// Re-dimension to the n × n identity, reusing the allocation.
    pub fn reset_eye(&mut self, n: usize) {
        self.reset(n);
        for i in 0..n {
            self.data[i * n + i] = 1.0;
        }
    }

    /// Become a copy of `other`, reusing the allocation.
    pub fn copy_from(&mut self, other: &Mat) {
        self.n = other.n;
        self.data.clear();
        self.data.extend_from_slice(&other.data);
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.n);
        self.matmul_into(other, &mut out);
        out
    }

    /// `out = self * other`, blocked over k so each pass streams a small
    /// band of `other` (in cache) across all output rows.  For every
    /// output element the k-accumulation order is ascending — bitwise
    /// identical to the naive triple loop.  `out` is re-dimensioned in
    /// place; no allocation once its capacity suffices.
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        assert_eq!(self.n, other.n);
        let n = self.n;
        out.reset(n);
        for k0 in (0..n).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(n);
            for i in 0..n {
                let arow = &self.data[i * n..(i + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for k in k0..k1 {
                    let a = arow[k];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[k * n..(k + 1) * n];
                    crate::score::kernels::axpy(orow, a, brow);
                }
            }
        }
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self[(i, i)]).sum()
    }

    pub fn symmetrize(&mut self) {
        let n = self.n;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    pub fn max_offdiag_abs(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j {
                    m = m.max(self[(i, j)].abs());
                }
            }
        }
        m
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.n + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.n + j]
    }
}

/// Reusable buffers of the eigen/sqrt pipeline: the rotating copy the
/// Jacobi sweeps run in, the accumulated eigenvectors, the eigenvalues,
/// and a contiguous column scratch for the PSD-sqrt rank-one updates.
#[derive(Default)]
pub struct EigenWorkspace {
    pub work: Mat,
    pub vecs: Mat,
    pub eigvals: Vec<f64>,
    col: Vec<f64>,
}

impl Default for Mat {
    fn default() -> Self {
        Mat::zeros(0)
    }
}

impl EigenWorkspace {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix, entirely inside
/// the workspace: `ws.eigvals` / `ws.vecs` (eigenvectors as columns)
/// satisfy A = V diag(w) V^T on return.  The sweeps rotate `ws.work` in
/// place — zero allocations once the workspace is warm.
pub fn jacobi_eigen_into(a: &Mat, max_sweeps: usize, tol: f64, ws: &mut EigenWorkspace) {
    let n = a.n;
    ws.work.copy_from(a);
    ws.work.symmetrize();
    ws.vecs.reset_eye(n);
    let aw = &mut ws.work;
    let v = &mut ws.vecs;
    for _ in 0..max_sweeps {
        if aw.max_offdiag_abs() < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = aw[(p, q)];
                if apq.abs() < tol * 1e-3 {
                    continue;
                }
                let app = aw[(p, p)];
                let aqq = aw[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of A.
                for k in 0..n {
                    let akp = aw[(k, p)];
                    let akq = aw[(k, q)];
                    aw[(k, p)] = c * akp - s * akq;
                    aw[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = aw[(p, k)];
                    let aqk = aw[(q, k)];
                    aw[(p, k)] = c * apk - s * aqk;
                    aw[(q, k)] = s * apk + c * aqk;
                }
                // Accumulate rotations.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    ws.eigvals.clear();
    ws.eigvals.extend((0..n).map(|i| ws.work[(i, i)]));
}

/// Allocating wrapper over [`jacobi_eigen_into`].
/// Returns (eigenvalues, eigenvectors-as-columns) with A = V diag(w) V^T.
pub fn jacobi_eigen(a: &Mat, max_sweeps: usize, tol: f64) -> (Vec<f64>, Mat) {
    let mut ws = EigenWorkspace::new();
    jacobi_eigen_into(a, max_sweeps, tol, &mut ws);
    (ws.eigvals, ws.vecs)
}

/// Symmetric PSD square root via eigendecomposition (negative eigenvalues
/// from numerical noise are clamped to zero), written into `out` with all
/// temporaries in `ws`.  Each eigenvector is gathered once into a
/// contiguous column so the rank-one accumulation is stride-1.
pub fn sqrt_psd_into(a: &Mat, out: &mut Mat, ws: &mut EigenWorkspace) {
    jacobi_eigen_into(a, 50, 1e-11, ws);
    let n = a.n;
    out.reset(n);
    for k in 0..n {
        let s = ws.eigvals[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        ws.col.clear();
        ws.col.extend((0..n).map(|i| ws.vecs[(i, k)]));
        for i in 0..n {
            let vik = ws.col[i] * s;
            if vik == 0.0 {
                continue;
            }
            let orow = &mut out.data[i * n..(i + 1) * n];
            crate::score::kernels::axpy(orow, vik, &ws.col);
        }
    }
}

/// Allocating wrapper over [`sqrt_psd_into`].
pub fn sqrt_psd(a: &Mat) -> Mat {
    let mut out = Mat::zeros(a.n);
    let mut ws = EigenWorkspace::new();
    sqrt_psd_into(a, &mut out, &mut ws);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    fn random_psd(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut b = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.gen_f64() - 0.5;
            }
        }
        // A = B B^T + small ridge: symmetric PSD.
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += 0.01;
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let a = random_psd(6, 1);
        let i6 = Mat::eye(6);
        assert_eq!(a.matmul(&i6).data, a.data);
    }

    #[test]
    fn jacobi_reconstructs_matrix() {
        let a = random_psd(8, 2);
        let (w, v) = jacobi_eigen(&a, 50, 1e-12);
        // Reconstruct V diag(w) V^T.
        let mut d = Mat::zeros(8);
        for i in 0..8 {
            d[(i, i)] = w[i];
        }
        let rec = v.matmul(&d).matmul(&v.transpose());
        for i in 0..64 {
            assert!(
                (rec.data[i] - a.data[i]).abs() < 1e-8,
                "entry {i}: {} vs {}",
                rec.data[i],
                a.data[i]
            );
        }
    }

    #[test]
    fn jacobi_eigenvalues_of_diag() {
        let mut a = Mat::zeros(3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let (mut w, _) = jacobi_eigen(&a, 10, 1e-14);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sqrt_psd_squares_back() {
        let a = random_psd(10, 3);
        let r = sqrt_psd(&a);
        let sq = r.matmul(&r);
        for i in 0..100 {
            assert!(
                (sq.data[i] - a.data[i]).abs() < 1e-7,
                "entry {i}: {} vs {}",
                sq.data[i],
                a.data[i]
            );
        }
    }

    #[test]
    fn blocked_matmul_matches_naive_triple_loop() {
        // Also exercises n > BLOCK so the k-tiling actually splits.
        for &n in &[7usize, 65, 130] {
            let a = random_psd(n, 10 + n as u64);
            let b = random_psd(n, 20 + n as u64);
            let got = a.matmul(&b);
            let mut want = Mat::zeros(n);
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0;
                    for k in 0..n {
                        acc += a[(i, k)] * b[(k, j)];
                    }
                    want[(i, j)] = acc;
                }
            }
            for i in 0..n * n {
                assert!(
                    (got.data[i] - want.data[i]).abs() <= 1e-9 * want.data[i].abs().max(1.0),
                    "n={n} entry {i}: {} vs {}",
                    got.data[i],
                    want.data[i]
                );
            }
        }
    }

    #[test]
    fn into_forms_match_allocating_and_reuse_buffers() {
        let a = random_psd(9, 4);
        let b = random_psd(9, 5);
        // matmul_into into a dirty, differently-sized buffer.
        let mut out = Mat::zeros(3);
        out.data.iter_mut().for_each(|x| *x = 7.0);
        a.matmul_into(&b, &mut out);
        assert_eq!(out, a.matmul(&b));
        // Eigen + sqrt workspace reused across different sizes.
        let mut ws = EigenWorkspace::new();
        let mut sq = Mat::zeros(0);
        for &n in &[6usize, 10, 4] {
            let m = random_psd(n, 40 + n as u64);
            sqrt_psd_into(&m, &mut sq, &mut ws);
            assert_eq!(sq, sqrt_psd(&m), "n={n}");
            jacobi_eigen_into(&m, 50, 1e-12, &mut ws);
            let (w, v) = jacobi_eigen(&m, 50, 1e-12);
            assert_eq!(ws.eigvals, w, "n={n}");
            assert_eq!(ws.vecs, v, "n={n}");
        }
    }

    #[test]
    fn trace_and_transpose() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.trace(), 5.0);
        let t = a.transpose();
        assert_eq!(t[(0, 1)], 3.0);
        assert_eq!(t[(1, 0)], 2.0);
    }
}
