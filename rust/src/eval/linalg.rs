//! Dense symmetric linear algebra for the FID computation: matrix products,
//! cyclic Jacobi eigendecomposition, and PSD matrix square roots.
//! From scratch — no BLAS/LAPACK is available in this image.

/// Row-major square matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub n: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(n: usize) -> Self {
        Self { n, data: vec![0.0; n * n] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n = rows.len();
        let mut m = Self::zeros(n);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), n);
            m.data[i * n..(i + 1) * n].copy_from_slice(r);
        }
        m
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let a = self.data[i * n + k];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[k * n..(k + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let n = self.n;
        let mut out = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn trace(&self) -> f64 {
        (0..self.n).map(|i| self[(i, i)]).sum()
    }

    pub fn symmetrize(&mut self) {
        let n = self.n;
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    pub fn max_offdiag_abs(&self) -> f64 {
        let mut m = 0.0f64;
        for i in 0..self.n {
            for j in 0..self.n {
                if i != j {
                    m = m.max(self[(i, j)].abs());
                }
            }
        }
        m
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.n + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.n + j]
    }
}

/// Cyclic Jacobi eigendecomposition of a symmetric matrix.
/// Returns (eigenvalues, eigenvectors-as-columns) with A = V diag(w) V^T.
pub fn jacobi_eigen(a: &Mat, max_sweeps: usize, tol: f64) -> (Vec<f64>, Mat) {
    let n = a.n;
    let mut a = a.clone();
    a.symmetrize();
    let mut v = Mat::eye(n);
    for _ in 0..max_sweeps {
        if a.max_offdiag_abs() < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                if apq.abs() < tol * 1e-3 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p, q of A.
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
                // Accumulate rotations.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    let w = (0..n).map(|i| a[(i, i)]).collect();
    (w, v)
}

/// Symmetric PSD square root via eigendecomposition (negative eigenvalues
/// from numerical noise are clamped to zero).
pub fn sqrt_psd(a: &Mat) -> Mat {
    let (w, v) = jacobi_eigen(a, 50, 1e-11);
    let n = a.n;
    let mut out = Mat::zeros(n);
    for k in 0..n {
        let s = w[k].max(0.0).sqrt();
        if s == 0.0 {
            continue;
        }
        for i in 0..n {
            let vik = v[(i, k)] * s;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += vik * v[(j, k)];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    fn random_psd(n: usize, seed: u64) -> Mat {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut b = Mat::zeros(n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.gen_f64() - 0.5;
            }
        }
        // A = B B^T + small ridge: symmetric PSD.
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += 0.01;
        }
        a
    }

    #[test]
    fn matmul_identity() {
        let a = random_psd(6, 1);
        let i6 = Mat::eye(6);
        assert_eq!(a.matmul(&i6).data, a.data);
    }

    #[test]
    fn jacobi_reconstructs_matrix() {
        let a = random_psd(8, 2);
        let (w, v) = jacobi_eigen(&a, 50, 1e-12);
        // Reconstruct V diag(w) V^T.
        let mut d = Mat::zeros(8);
        for i in 0..8 {
            d[(i, i)] = w[i];
        }
        let rec = v.matmul(&d).matmul(&v.transpose());
        for i in 0..64 {
            assert!(
                (rec.data[i] - a.data[i]).abs() < 1e-8,
                "entry {i}: {} vs {}",
                rec.data[i],
                a.data[i]
            );
        }
    }

    #[test]
    fn jacobi_eigenvalues_of_diag() {
        let mut a = Mat::zeros(3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let (mut w, _) = jacobi_eigen(&a, 10, 1e-14);
        w.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert!((w[0] - 1.0).abs() < 1e-12);
        assert!((w[1] - 2.0).abs() < 1e-12);
        assert!((w[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sqrt_psd_squares_back() {
        let a = random_psd(10, 3);
        let r = sqrt_psd(&a);
        let sq = r.matmul(&r);
        for i in 0..100 {
            assert!(
                (sq.data[i] - a.data[i]).abs() < 1e-7,
                "entry {i}: {} vs {}",
                sq.data[i],
                a.data[i]
            );
        }
    }

    #[test]
    fn trace_and_transpose() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(a.trace(), 5.0);
        let t = a.transpose();
        assert_eq!(t[(0, 1)], 3.0);
        assert_eq!(t[(1, 0)], 2.0);
    }
}
