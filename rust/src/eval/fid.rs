//! Fréchet Inception Distance, exactly — over explicit feature vectors.
//!
//! The paper computes FID of 50k generated images against the ImageNet
//! validation split through InceptionV3 features.  Our substitution
//! (DESIGN.md) keeps the *metric* identical — the Fréchet distance between
//! Gaussian moment matchings,
//!
//! ```text
//!     d^2 = |m1 - m2|^2 + tr(C1 + C2 - 2 (C1^{1/2} C2 C1^{1/2})^{1/2})
//! ```
//!
//! — but feeds it hand-rolled token-grid features (unigram + neighbour
//! co-occurrence histograms, `crate::data::images`) instead of Inception
//! activations, since sampler-induced distribution error shows up directly
//! in those sufficient statistics for the synthetic data law.

use crate::eval::linalg::{sqrt_psd_into, EigenWorkspace, Mat};

/// Covariance block: rows centered and transposed per pass (so the
/// O(n d²) accumulation runs over contiguous columns).
const COV_BLOCK: usize = 64;

/// Mean vector and covariance matrix of a feature sample set.
#[derive(Clone, Debug)]
pub struct Moments {
    pub mean: Vec<f64>,
    pub cov: Mat,
    pub n: usize,
}

/// Reusable buffer of the blocked covariance accumulation.
#[derive(Default)]
pub struct MomentsScratch {
    /// Centered block, transposed: blockt[i * COV_BLOCK + r] = f_r[i] - mean[i].
    blockt: Vec<f64>,
}

/// Accumulate moments from rows of features (each row one sample), with a
/// fresh scratch ([`moments_with`] reuses one across calls).
pub fn moments(features: &[Vec<f64>]) -> Moments {
    moments_with(features, &mut MomentsScratch::default())
}

/// As [`moments`], reusing the caller's scratch.  The covariance runs in
/// centered-block-transposed form: each block of rows is centered into a
/// (d × block) scratch once, then every upper-triangle entry accumulates
/// as one contiguous dot product — no per-element branch, no per-sample
/// strided access.
pub fn moments_with(features: &[Vec<f64>], ws: &mut MomentsScratch) -> Moments {
    assert!(features.len() >= 2, "need >= 2 samples for a covariance");
    let d = features[0].len();
    let n = features.len();
    let mut mean = vec![0.0; d];
    for f in features {
        assert_eq!(f.len(), d);
        for (m, &x) in mean.iter_mut().zip(f) {
            *m += x;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut cov = Mat::zeros(d);
    ws.blockt.clear();
    ws.blockt.resize(d * COV_BLOCK, 0.0);
    for block in features.chunks(COV_BLOCK) {
        let b = block.len();
        for (r, f) in block.iter().enumerate() {
            for i in 0..d {
                ws.blockt[i * COV_BLOCK + r] = f[i] - mean[i];
            }
        }
        for i in 0..d {
            let ci = &ws.blockt[i * COV_BLOCK..i * COV_BLOCK + b];
            for j in i..d {
                let cj = &ws.blockt[j * COV_BLOCK..j * COV_BLOCK + b];
                let mut acc = 0.0;
                for (&x, &y) in ci.iter().zip(cj) {
                    acc += x * y;
                }
                cov[(i, j)] += acc;
            }
        }
    }
    for i in 0..d {
        for j in i..d {
            let v = cov[(i, j)] / (n - 1) as f64;
            cov[(i, j)] = v;
            cov[(j, i)] = v;
        }
    }
    Moments { mean, cov, n }
}

/// Temporaries of one Fréchet-distance evaluation, reusable across calls —
/// [`frechet_distance_with`] performs zero allocations once this is warm,
/// which is what makes per-PR FID tracking cheap.
#[derive(Default)]
pub struct FidScratch {
    s1: Mat,
    prod: Mat,
    inner: Mat,
    sq: Mat,
    eig: EigenWorkspace,
}

impl FidScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Fréchet distance squared between two moment sets.
pub fn frechet_distance(a: &Moments, b: &Moments) -> f64 {
    frechet_distance_with(a, b, &mut FidScratch::default())
}

/// As [`frechet_distance`], with every matrix temporary (two PSD square
/// roots, two products, the Jacobi sweeps) in the caller's scratch.
pub fn frechet_distance_with(a: &Moments, b: &Moments, ws: &mut FidScratch) -> f64 {
    assert_eq!(a.mean.len(), b.mean.len());
    let mean_term: f64 = a
        .mean
        .iter()
        .zip(&b.mean)
        .map(|(&x, &y)| (x - y) * (x - y))
        .sum();
    // tr((C1^{1/2} C2 C1^{1/2})^{1/2}) — symmetric form of tr((C1 C2)^{1/2}).
    sqrt_psd_into(&a.cov, &mut ws.s1, &mut ws.eig);
    ws.s1.matmul_into(&b.cov, &mut ws.prod);
    ws.prod.matmul_into(&ws.s1, &mut ws.inner);
    ws.inner.symmetrize();
    sqrt_psd_into(&ws.inner, &mut ws.sq, &mut ws.eig);
    let cross = ws.sq.trace();
    let d2 = mean_term + a.cov.trace() + b.cov.trace() - 2.0 * cross;
    d2.max(0.0)
}

/// Convenience: FID between two raw feature sets.
pub fn fid(features_a: &[Vec<f64>], features_b: &[Vec<f64>]) -> f64 {
    let mut ms = MomentsScratch::default();
    let mut fs = FidScratch::default();
    frechet_distance_with(
        &moments_with(features_a, &mut ms),
        &moments_with(features_b, &mut ms),
        &mut fs,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    fn gaussian_cloud(n: usize, d: usize, shift: f64, scale: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        // Box-Muller standard normal.
                        let (u1, u2) = (rng.gen_f64(), rng.gen_f64());
                        let z = (-2.0 * u1.ln()).sqrt()
                            * (2.0 * std::f64::consts::PI * u2).cos();
                        shift + scale * z
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn identical_sets_give_near_zero() {
        let a = gaussian_cloud(2000, 6, 0.0, 1.0, 1);
        let d = fid(&a, &a);
        assert!(d.abs() < 1e-9, "fid={d}");
    }

    #[test]
    fn same_distribution_small_fid() {
        let a = gaussian_cloud(4000, 5, 0.0, 1.0, 1);
        let b = gaussian_cloud(4000, 5, 0.0, 1.0, 2);
        let d = fid(&a, &b);
        assert!(d < 0.05, "fid={d}");
    }

    #[test]
    fn mean_shift_matches_analytic() {
        // For equal covariances, FID = |m1 - m2|^2 = d * shift^2.
        let a = gaussian_cloud(20_000, 4, 0.0, 1.0, 3);
        let b = gaussian_cloud(20_000, 4, 0.5, 1.0, 4);
        let d = fid(&a, &b);
        let want = 4.0 * 0.25;
        assert!((d - want).abs() < 0.15, "fid={d} want={want}");
    }

    #[test]
    fn scale_change_matches_analytic() {
        // Equal means, isotropic: FID = d (s1 - s2)^2.
        let a = gaussian_cloud(20_000, 3, 0.0, 1.0, 5);
        let b = gaussian_cloud(20_000, 3, 0.0, 2.0, 6);
        let d = fid(&a, &b);
        let want = 3.0 * (2.0 - 1.0) * (2.0 - 1.0);
        assert!((d - want).abs() < 0.2, "fid={d} want={want}");
    }

    #[test]
    fn scratch_reuse_matches_fresh_computation() {
        let a = gaussian_cloud(500, 6, 0.0, 1.0, 11);
        let b = gaussian_cloud(500, 6, 0.3, 1.2, 12);
        let ma = moments(&a);
        let mb = moments(&b);
        let want = frechet_distance(&ma, &mb);
        // Same scratch across repeated and differently-sized evaluations.
        let mut ms = MomentsScratch::default();
        let mut fs = FidScratch::new();
        let ma2 = moments_with(&a, &mut ms);
        let mb2 = moments_with(&b, &mut ms);
        assert_eq!(ma2.cov, ma.cov);
        assert_eq!(ma2.mean, ma.mean);
        for _ in 0..3 {
            assert_eq!(frechet_distance_with(&ma2, &mb2, &mut fs), want);
        }
        let small_a = gaussian_cloud(300, 3, 0.0, 1.0, 13);
        let small_b = gaussian_cloud(300, 3, 0.5, 1.0, 14);
        let d_small = frechet_distance_with(
            &moments_with(&small_a, &mut ms),
            &moments_with(&small_b, &mut ms),
            &mut fs,
        );
        assert_eq!(d_small, fid(&small_a, &small_b));
    }

    #[test]
    fn fid_monotone_in_shift() {
        let a = gaussian_cloud(3000, 4, 0.0, 1.0, 7);
        let b1 = gaussian_cloud(3000, 4, 0.2, 1.0, 8);
        let b2 = gaussian_cloud(3000, 4, 0.8, 1.0, 9);
        assert!(fid(&a, &b1) < fid(&a, &b2));
    }
}
