//! Fréchet Inception Distance, exactly — over explicit feature vectors.
//!
//! The paper computes FID of 50k generated images against the ImageNet
//! validation split through InceptionV3 features.  Our substitution
//! (DESIGN.md) keeps the *metric* identical — the Fréchet distance between
//! Gaussian moment matchings,
//!
//! ```text
//!     d^2 = |m1 - m2|^2 + tr(C1 + C2 - 2 (C1^{1/2} C2 C1^{1/2})^{1/2})
//! ```
//!
//! — but feeds it hand-rolled token-grid features (unigram + neighbour
//! co-occurrence histograms, `crate::data::images`) instead of Inception
//! activations, since sampler-induced distribution error shows up directly
//! in those sufficient statistics for the synthetic data law.

use crate::eval::linalg::{sqrt_psd, Mat};

/// Mean vector and covariance matrix of a feature sample set.
#[derive(Clone, Debug)]
pub struct Moments {
    pub mean: Vec<f64>,
    pub cov: Mat,
    pub n: usize,
}

/// Accumulate moments from rows of features (each row one sample).
pub fn moments(features: &[Vec<f64>]) -> Moments {
    assert!(features.len() >= 2, "need >= 2 samples for a covariance");
    let d = features[0].len();
    let n = features.len();
    let mut mean = vec![0.0; d];
    for f in features {
        assert_eq!(f.len(), d);
        for (m, &x) in mean.iter_mut().zip(f) {
            *m += x;
        }
    }
    for m in mean.iter_mut() {
        *m /= n as f64;
    }
    let mut cov = Mat::zeros(d);
    for f in features {
        for i in 0..d {
            let di = f[i] - mean[i];
            if di == 0.0 {
                continue;
            }
            for j in i..d {
                cov[(i, j)] += di * (f[j] - mean[j]);
            }
        }
    }
    for i in 0..d {
        for j in i..d {
            let v = cov[(i, j)] / (n - 1) as f64;
            cov[(i, j)] = v;
            cov[(j, i)] = v;
        }
    }
    Moments { mean, cov, n }
}

/// Fréchet distance squared between two moment sets.
pub fn frechet_distance(a: &Moments, b: &Moments) -> f64 {
    assert_eq!(a.mean.len(), b.mean.len());
    let mean_term: f64 = a
        .mean
        .iter()
        .zip(&b.mean)
        .map(|(&x, &y)| (x - y) * (x - y))
        .sum();
    // tr((C1^{1/2} C2 C1^{1/2})^{1/2}) — symmetric form of tr((C1 C2)^{1/2}).
    let s1 = sqrt_psd(&a.cov);
    let mut inner = s1.matmul(&b.cov).matmul(&s1);
    inner.symmetrize();
    let cross = sqrt_psd(&inner).trace();
    let d2 = mean_term + a.cov.trace() + b.cov.trace() - 2.0 * cross;
    d2.max(0.0)
}

/// Convenience: FID between two raw feature sets.
pub fn fid(features_a: &[Vec<f64>], features_b: &[Vec<f64>]) -> f64 {
    frechet_distance(&moments(features_a), &moments(features_b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, Xoshiro256};

    fn gaussian_cloud(n: usize, d: usize, shift: f64, scale: f64, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                (0..d)
                    .map(|_| {
                        // Box-Muller standard normal.
                        let (u1, u2) = (rng.gen_f64(), rng.gen_f64());
                        let z = (-2.0 * u1.ln()).sqrt()
                            * (2.0 * std::f64::consts::PI * u2).cos();
                        shift + scale * z
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn identical_sets_give_near_zero() {
        let a = gaussian_cloud(2000, 6, 0.0, 1.0, 1);
        let d = fid(&a, &a);
        assert!(d.abs() < 1e-9, "fid={d}");
    }

    #[test]
    fn same_distribution_small_fid() {
        let a = gaussian_cloud(4000, 5, 0.0, 1.0, 1);
        let b = gaussian_cloud(4000, 5, 0.0, 1.0, 2);
        let d = fid(&a, &b);
        assert!(d < 0.05, "fid={d}");
    }

    #[test]
    fn mean_shift_matches_analytic() {
        // For equal covariances, FID = |m1 - m2|^2 = d * shift^2.
        let a = gaussian_cloud(20_000, 4, 0.0, 1.0, 3);
        let b = gaussian_cloud(20_000, 4, 0.5, 1.0, 4);
        let d = fid(&a, &b);
        let want = 4.0 * 0.25;
        assert!((d - want).abs() < 0.15, "fid={d} want={want}");
    }

    #[test]
    fn scale_change_matches_analytic() {
        // Equal means, isotropic: FID = d (s1 - s2)^2.
        let a = gaussian_cloud(20_000, 3, 0.0, 1.0, 5);
        let b = gaussian_cloud(20_000, 3, 0.0, 2.0, 6);
        let d = fid(&a, &b);
        let want = 3.0 * (2.0 - 1.0) * (2.0 - 1.0);
        assert!((d - want).abs() < 0.2, "fid={d} want={want}");
    }

    #[test]
    fn fid_monotone_in_shift() {
        let a = gaussian_cloud(3000, 4, 0.0, 1.0, 7);
        let b1 = gaussian_cloud(3000, 4, 0.2, 1.0, 8);
        let b2 = gaussian_cloud(3000, 4, 0.8, 1.0, 9);
        assert!(fid(&a, &b1) < fid(&a, &b2));
    }
}
