//! Evaluation metrics for every experiment in the paper:
//! KL divergence with bootstrap CIs (Fig. 2), generative perplexity
//! (Tabs. 1/2, Fig. 1), Fréchet distance / FID (Figs. 3/4/6) and the
//! dense linear algebra it needs ([`linalg`]).

pub mod kl;
pub mod perplexity;
pub mod fid;
pub mod linalg;
