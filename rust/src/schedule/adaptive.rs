//! Online error-controlled step sizing for the θ-solvers.
//!
//! ## Embedded error estimator
//!
//! Both θ-schemes evaluate the score twice per step (at t and at the
//! θ-section point ρ = t - θΔ).  Those two evaluations embed a *free*
//! first-order predictor: the one-stage Euler/τ-leap gate uses only the
//! time-t rates, while the scheme's composite two-stage gate folds in the
//! extrapolated rates.
//! The per-dimension discrepancy between the two jump probabilities —
//! [`trap_gate_discrepancy`] / [`rk2_gate_discrepancy`] — is an O(Δ²) local
//! error proxy that costs **zero extra NFE** and draws **no randomness**
//! (it reads the already-computed score rows, never the samples), so the
//! adaptive drivers consume exactly the same RNG stream as the fixed-grid
//! solver over the same realized grid.  That is what makes the
//! "adaptive run ≡ fixed-grid run over the realized grid, bit for bit"
//! property tests possible.
//!
//! ## PI controller
//!
//! [`StepController`] is a standard accept-always PI step controller:
//! after each step with estimated error `e`,
//!
//! ```text
//!     dt ← dt · clamp(safety · (tol/e)^k_i · (e_prev/e)^k_p, shrink, grow)
//! ```
//!
//! clamped to `[min_dt, max_dt]` and to the remaining span.  Accept-always
//! (no step rejection) keeps RNG consumption deterministic; the tolerance
//! bounds the *next* step instead of retrying the last one, which for
//! second-order schemes costs one step of lag and no NFE.
//!
//! ## NFE budget pinning
//!
//! With a hard per-request budget, [`StepController::propose_dt`] also
//! enforces `dt ≥ remaining_span / affordable_steps` (reserving one
//! evaluation for the terminal denoise), so a run can never overdraw: when
//! the estimator wants many small steps the floor rises as the budget
//! drains, concentrating the available NFE where the estimated error was
//! largest and finishing with one long jump if necessary.

/// Default tolerance for `"adaptive"` without an explicit `tol=`.
pub const DEFAULT_TOL: f64 = 1e-3;

/// Configuration of the PI step-size controller.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AdaptiveController {
    /// Target local error (jump-probability discrepancy) per step.
    pub tol: f64,
    /// Safety factor applied to every resize (< 1).
    pub safety: f64,
    /// Hard step-size bounds.
    pub min_dt: f64,
    pub max_dt: f64,
    /// Per-step growth/shrink clamps on the resize factor.
    pub grow: f64,
    pub shrink: f64,
    /// PI gains (integral / proportional).
    pub k_i: f64,
    pub k_p: f64,
}

impl AdaptiveController {
    /// Sensible defaults for a backward pass over `[t_lo, t_hi]`: step
    /// bounds relative to the span (at most 4096 steps, at least 2).
    pub fn for_span(tol: f64, t_hi: f64, t_lo: f64) -> Self {
        assert!(t_hi > t_lo && t_lo > 0.0, "need t_hi > t_lo > 0");
        assert!(tol.is_finite() && tol >= 0.0, "tol must be finite and >= 0");
        let span = t_hi - t_lo;
        AdaptiveController {
            tol,
            safety: 0.9,
            min_dt: span / 4096.0,
            max_dt: span / 2.0,
            grow: 4.0,
            shrink: 0.2,
            k_i: 0.3,
            k_p: 0.1,
        }
    }

    pub fn with_bounds(mut self, min_dt: f64, max_dt: f64) -> Self {
        assert!(min_dt > 0.0 && min_dt <= max_dt);
        self.min_dt = min_dt;
        self.max_dt = max_dt;
        self
    }
}

/// Hard per-request NFE budget (property: never exceeded).
#[derive(Clone, Copy, Debug)]
pub struct NfeBudget {
    /// Total score evaluations the run may spend, including the terminal
    /// denoise.
    pub total: usize,
    /// Evaluations per solver step (2 for the θ-schemes).
    pub nfe_per_step: usize,
    /// Evaluations held back for the terminal denoise.
    pub reserve: usize,
}

/// Runtime state of the accept-always PI controller.
#[derive(Clone, Debug)]
pub struct StepController {
    pub cfg: AdaptiveController,
    dt: f64,
    prev_err: Option<f64>,
    budget: Option<NfeBudget>,
}

impl StepController {
    pub fn new(cfg: AdaptiveController, dt0: f64) -> Self {
        let dt = dt0.clamp(cfg.min_dt, cfg.max_dt);
        StepController { cfg, dt, prev_err: None, budget: None }
    }

    pub fn with_budget(mut self, budget: NfeBudget) -> Self {
        assert!(budget.nfe_per_step >= 1);
        self.budget = Some(budget);
        self
    }

    pub fn budget(&self) -> Option<NfeBudget> {
        self.budget
    }

    /// Step size for the next step from forward time `t` down to at most
    /// `t_end`, given `spent` evaluations so far.  Returns `None` when the
    /// pass is complete (`t <= t_end`).  Does not mutate the controller.
    ///
    /// Guarantees: the returned dt lands in `(0, t - t_end]`; under a
    /// budget the remaining span is always coverable by the affordable
    /// steps (so the budget can never be exceeded); a final sliver shorter
    /// than half the minimum step is absorbed into the last step.
    pub fn propose_dt(&self, t: f64, t_end: f64, spent: usize) -> Option<f64> {
        let span = t - t_end;
        if span <= 0.0 {
            return None;
        }
        let mut dt = self.dt.clamp(self.cfg.min_dt, self.cfg.max_dt);
        if let Some(b) = self.budget {
            let left = b.total.saturating_sub(spent).saturating_sub(b.reserve);
            let affordable = left / b.nfe_per_step;
            if affordable <= 1 {
                // Last affordable step: jump straight to the end.
                return Some(span);
            }
            // Floor: never take a step so small that the remaining budget
            // cannot reach t_end.
            dt = dt.max(span / affordable as f64);
        }
        if dt >= span || span - dt < 0.5 * self.cfg.min_dt {
            // Absorb the terminal sliver.
            return Some(span);
        }
        Some(dt)
    }

    /// Record the estimated local error of the step just taken and resize.
    pub fn observe(&mut self, err: f64) {
        let cfg = self.cfg;
        let e = if err.is_finite() { err.max(0.0) } else { f64::INFINITY };
        let tiny = 1e-300;
        // (tol/e)^k_i: tol = 0 forces maximal shrink; e = 0 maximal growth.
        let ratio_i = if e <= tiny {
            if cfg.tol <= tiny { 0.0 } else { f64::INFINITY }
        } else {
            cfg.tol / e
        };
        let ratio_p = match self.prev_err {
            Some(pe) if e > tiny => (pe.max(tiny) / e).powf(cfg.k_p),
            _ => 1.0,
        };
        let factor = (cfg.safety * ratio_i.powf(cfg.k_i) * ratio_p)
            .clamp(cfg.shrink, cfg.grow);
        self.dt = (self.dt * factor).clamp(cfg.min_dt, cfg.max_dt);
        self.prev_err = Some(e);
    }

    /// Current (already clamped) step size — for traces and tests.
    pub fn dt(&self) -> f64 {
        self.dt
    }
}

/// Jump-probability discrepancy of one θ-trapezoidal step (Alg. 2) against
/// its embedded first-order predictor, for a dimension with total time-t
/// intensity `tot_mu` and combined stage-2 intensity `tot_comb` (the
/// (α₁μ*−α₂μ)₊ row sum).
///
/// The predictor is the one-stage first-order gate built from the time-t
/// rates alone, in the same exponential (τ-leap) form as the scheme's own
/// stages: p₁ₛₜ = 1 − e^{−μΔ}.  That choice isolates exactly the
/// second-order correction: with time-constant rates (tot_comb == tot_mu)
/// the composite gate collapses to the predictor and the discrepancy is
/// identically zero, so the controller grows dt wherever the score is
/// frozen and refines only where the extrapolated rates actually move —
/// |p_trap − p₁ₛₜ| ≈ the predictor's own O(Δ²) local error, the standard
/// embedded-pair estimate (control the low-order error, step with the
/// high-order scheme).
#[inline]
pub fn trap_gate_discrepancy(theta: f64, dt: f64, tot_mu: f64, tot_comb: f64) -> f64 {
    let p1 = 1.0 - (-tot_mu * theta * dt).exp();
    let p2 = 1.0 - (-tot_comb * (1.0 - theta) * dt).exp();
    let p_trap = 1.0 - (1.0 - p1) * (1.0 - p2);
    let p_first = 1.0 - (-tot_mu * dt).exp();
    (p_trap - p_first).abs()
}

/// Same for θ-RK-2 (Alg. 4), whose stage 2 restarts from y_s with the
/// blended rates over the full step: |e^{−tot_mu·Δ} − e^{−tot_comb·Δ}|.
#[inline]
pub fn rk2_gate_discrepancy(dt: f64, tot_mu: f64, tot_comb: f64) -> f64 {
    let p_rk2 = 1.0 - (-tot_comb * dt).exp();
    let p_first = 1.0 - (-tot_mu * dt).exp();
    (p_rk2 - p_first).abs()
}

/// Realized outcome of one adaptive pass: the grid the controller actually
/// took plus the per-step error estimates (aligned with `grid.windows(2)`).
/// The grid is a valid fixed grid — replaying the same solver over it
/// reproduces the adaptive run bit for bit, and the tuner consumes the
/// (time, error) pairs as its error-density evidence.
#[derive(Clone, Debug, Default)]
pub struct AdaptiveTrace {
    pub grid: Vec<f64>,
    pub errors: Vec<f64>,
}

impl AdaptiveTrace {
    /// (forward time of the step start, error per unit time) samples for
    /// [`crate::schedule::grid::from_error_density`].
    pub fn density_samples(&self) -> Vec<(f64, f64)> {
        self.grid
            .windows(2)
            .zip(&self.errors)
            .map(|(w, &e)| (0.5 * (w[0] + w[1]), e / (w[0] - w[1]).max(1e-300)))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(tol: f64) -> AdaptiveController {
        AdaptiveController::for_span(tol, 1.0, 1e-3)
    }

    #[test]
    fn zero_tol_pins_to_min_dt() {
        let c = cfg(0.0).with_bounds(0.03125, 0.03125);
        let mut s = StepController::new(c, 0.25);
        // dt0 clamps straight to the fixed bound.
        assert_eq!(s.propose_dt(1.0, 0.5, 0).unwrap(), 0.03125);
        s.observe(1.0);
        assert_eq!(s.dt(), 0.03125);
        s.observe(0.0);
        assert_eq!(s.dt(), 0.03125);
    }

    #[test]
    fn small_error_grows_large_error_shrinks() {
        let c = cfg(1e-2);
        let mut s = StepController::new(c, 0.01);
        let d0 = s.dt();
        s.observe(1e-6);
        assert!(s.dt() > d0, "tiny error must grow dt");
        let d1 = s.dt();
        s.observe(10.0);
        assert!(s.dt() < d1, "huge error must shrink dt");
    }

    #[test]
    fn growth_and_shrink_clamped() {
        let c = cfg(1e-2);
        let mut s = StepController::new(c, 0.01);
        let d0 = s.dt();
        s.observe(0.0); // infinite ratio
        assert!(s.dt() <= d0 * c.grow + 1e-15);
        let mut s = StepController::new(c, 0.01);
        let d0 = s.dt();
        s.observe(f64::INFINITY);
        assert!(s.dt() >= d0 * c.shrink - 1e-15);
    }

    #[test]
    fn proposals_cover_span_and_absorb_sliver() {
        let c = cfg(1e-2).with_bounds(0.1, 0.4);
        let s = StepController::new(c, 0.4);
        // Sliver absorption: span barely above dt -> one final step.
        assert_eq!(s.propose_dt(0.5, 0.09, 0).unwrap(), 0.5 - 0.09);
        assert!(s.propose_dt(0.09, 0.09, 0).is_none());
        let dt = s.propose_dt(1.0, 0.0011, 0).unwrap();
        assert!(dt > 0.0 && dt <= 1.0 - 0.0011);
    }

    #[test]
    fn budget_floor_prevents_overdraw() {
        // 10 NFE total, 2/step, 1 reserved -> at most 4 steps whatever the
        // controller wants.
        let c = cfg(1e-9).with_bounds(1e-6, 1.0);
        let mut s = StepController::new(c, 1e-6)
            .with_budget(NfeBudget { total: 10, nfe_per_step: 2, reserve: 1 });
        let (mut t, t_end) = (1.0, 0.001);
        let mut spent = 0usize;
        let mut steps = 0usize;
        while let Some(dt) = s.propose_dt(t, t_end, spent) {
            t -= dt;
            spent += 2;
            steps += 1;
            s.observe(1.0); // always "too big": wants minimal steps
            assert!(steps <= 4, "budget must cap steps");
        }
        assert!((t - t_end).abs() < 1e-12, "must land on t_end, got {t}");
        assert!(spent + 1 <= 10);
    }

    #[test]
    fn last_affordable_step_jumps_to_end() {
        let c = cfg(1e-3);
        let s = StepController::new(c, 0.001)
            .with_budget(NfeBudget { total: 3, nfe_per_step: 2, reserve: 1 });
        // 3 - 1 reserve = 2 left = 1 affordable step -> full span.
        assert_eq!(s.propose_dt(0.8, 0.1, 0).unwrap(), 0.8 - 0.1);
    }

    #[test]
    fn gate_discrepancy_zero_for_frozen_rates() {
        // Time-constant rates: the composite gate IS the first-order gate,
        // the proxy must read zero so dt can grow through dead zones.
        for &(theta, mu, dt) in
            &[(0.5, 1.3, 0.2), (0.3, 0.9, 1.5), (0.5, 0.05, 6.0)]
        {
            assert!(
                trap_gate_discrepancy(theta, dt, mu, mu).abs() < 1e-15,
                "theta={theta}"
            );
            assert_eq!(rk2_gate_discrepancy(dt, mu, mu), 0.0);
        }
    }

    #[test]
    fn gate_discrepancies_shrink_with_dt() {
        let (theta, mu, comb) = (0.5, 1.3, 1.7);
        let e1 = trap_gate_discrepancy(theta, 0.02, mu, comb);
        let e2 = trap_gate_discrepancy(theta, 0.01, mu, comb);
        assert!(e2 > 0.0 && e2 < e1, "e1={e1} e2={e2}");
        let r1 = rk2_gate_discrepancy(0.02, mu, comb);
        let r2 = rk2_gate_discrepancy(0.01, mu, comb);
        assert!(r2 > 0.0 && r2 < r1, "r1={r1} r2={r2}");
        assert!(trap_gate_discrepancy(theta, 0.0, mu, comb) == 0.0);
    }

    #[test]
    fn trace_density_samples_align() {
        let tr = AdaptiveTrace { grid: vec![1.0, 0.6, 0.1], errors: vec![1e-3, 4e-3] };
        let s = tr.density_samples();
        assert_eq!(s.len(), 2);
        assert!((s[0].0 - 0.8).abs() < 1e-12);
        assert!((s[0].1 - 1e-3 / 0.4).abs() < 1e-12);
    }
}
