//! Time-discretisation subsystem: the schedule is a first-class, controlled
//! resource rather than a hardcoded input.
//!
//! The paper's headline claim is that the second-order θ-schemes "enable
//! larger step sizes while reducing error" — which only pays off if step
//! sizes are actually *chosen* somewhere.  This module owns that choice
//! end-to-end:
//!
//! - [`grid`]: the fixed grids (uniform / log-spaced / arithmetic toy grid),
//!   migrated here from `solvers/grid.rs` (the old path re-exports them);
//! - [`adaptive`]: an embedded, RNG-free local error estimator (one
//!   θ-trapezoidal stage against its first-order Euler predictor, compared
//!   through per-dimension jump probabilities) driving a PI step-size
//!   controller ([`adaptive::AdaptiveController`]) that grows/shrinks dt
//!   online and can be pinned to a hard per-request NFE budget;
//! - [`tuner`]: an offline [`tuner::ScheduleTuner`] that fits a reusable
//!   non-uniform grid from the error traces of a few pilot runs,
//!   serialises it to JSON, and a [`tuner::ScheduleCache`] the coordinator
//!   uses to reuse tuned grids per (family, vocab, seq_len, solver).
//!
//! [`ScheduleSpec`] is the request-level selector the serving stack parses
//! (`"uniform"`, `"log"`, `"adaptive:tol=1e-3"`, `"tuned"`, or
//! `"tuned:steps=24"`); `solvers::masked::generate_adaptive` /
//! `generate_batch_adaptive` and `solvers::toy::generate_adaptive` are the
//! drivers that consume the controller.

pub mod adaptive;
pub mod grid;
pub mod tuner;

pub use adaptive::{AdaptiveController, StepController};
pub use tuner::{ScheduleCache, ScheduleTuner, TuneKey, TunedSchedule};

use anyhow::{bail, Result};

/// Request-level schedule selection, shared by the CLI, the JSON-lines
/// protocol, the coordinator and the experiment harnesses.
///
/// For the fixed variants the request's `nfe` decides the step count as
/// before; for `Adaptive` the controller picks steps online (`nfe` seeds
/// the initial dt, the optional `nfe_budget` pins a hard cap); `Tuned`
/// resolves to a cached non-uniform grid fitted from pilot error traces.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScheduleSpec {
    /// Uniform grid on (δ, 1] (the paper's App. D.3 default).
    Uniform,
    /// Log-spaced (geometric) grid on (δ, 1].
    Log,
    /// Online error-controlled steps at the given tolerance.
    Adaptive { tol: f64 },
    /// Offline-tuned non-uniform grid; `steps = 0` means "derive the step
    /// count from the request NFE" (same accounting as the fixed grids).
    Tuned { steps: usize },
}

impl Default for ScheduleSpec {
    fn default() -> Self {
        ScheduleSpec::Uniform
    }
}

impl ScheduleSpec {
    /// Parse e.g. "uniform", "log", "adaptive:tol=1e-3", "adaptive",
    /// "tuned", "tuned:steps=24".
    pub fn parse(s: &str) -> Result<ScheduleSpec> {
        let (name, arg) = match s.split_once(':') {
            Some((n, a)) => (n, Some(a)),
            None => (s, None),
        };
        let kv = |a: &str, key: &str| -> Result<f64> {
            match a.split_once('=') {
                Some((k, v)) if k == key => Ok(v.parse::<f64>()?),
                _ => bail!("expected {key}=<value>, got {a:?}"),
            }
        };
        Ok(match name {
            "uniform" => {
                if arg.is_some() {
                    bail!("uniform takes no arguments");
                }
                ScheduleSpec::Uniform
            }
            "log" => {
                if arg.is_some() {
                    bail!("log takes no arguments");
                }
                ScheduleSpec::Log
            }
            "adaptive" => {
                let tol = match arg {
                    Some(a) => kv(a, "tol")?,
                    None => adaptive::DEFAULT_TOL,
                };
                if !(tol.is_finite() && tol >= 0.0) {
                    bail!("adaptive tol {tol} must be finite and >= 0");
                }
                ScheduleSpec::Adaptive { tol }
            }
            "tuned" => {
                let steps = match arg {
                    Some(a) => {
                        let v = kv(a, "steps")?;
                        if v < 1.0 || v.fract() != 0.0 {
                            bail!("tuned steps must be a positive integer");
                        }
                        v as usize
                    }
                    None => 0,
                };
                ScheduleSpec::Tuned { steps }
            }
            _ => bail!("unknown schedule {s:?} (uniform|log|adaptive[:tol=..]|tuned[:steps=..])"),
        })
    }

    /// Canonical string form (round-trips through [`ScheduleSpec::parse`]).
    pub fn to_string_spec(&self) -> String {
        match self {
            ScheduleSpec::Uniform => "uniform".into(),
            ScheduleSpec::Log => "log".into(),
            ScheduleSpec::Adaptive { tol } => format!("adaptive:tol={tol}"),
            ScheduleSpec::Tuned { steps: 0 } => "tuned".into(),
            ScheduleSpec::Tuned { steps } => format!("tuned:steps={steps}"),
        }
    }

    /// Structured JSON form used by the v2 wire protocol: a `{"kind": ...}`
    /// object with the variant's parameters as typed fields (the string
    /// form remains for v1 and the CLI).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        match self {
            ScheduleSpec::Uniform => Json::obj(vec![("kind", Json::from("uniform"))]),
            ScheduleSpec::Log => Json::obj(vec![("kind", Json::from("log"))]),
            ScheduleSpec::Adaptive { tol } => Json::obj(vec![
                ("kind", Json::from("adaptive")),
                ("tol", Json::Num(*tol)),
            ]),
            ScheduleSpec::Tuned { steps } => Json::obj(vec![
                ("kind", Json::from("tuned")),
                ("steps", Json::from(*steps)),
            ]),
        }
    }

    /// Parse the structured JSON form ([`ScheduleSpec::to_json`]); a bare
    /// JSON string falls back to [`ScheduleSpec::parse`] so clients can use
    /// either.
    pub fn from_json(j: &crate::util::json::Json) -> Result<ScheduleSpec> {
        use crate::util::json::Json;
        if let Json::Str(s) = j {
            return ScheduleSpec::parse(s);
        }
        let kind = j.get("kind")?.as_str()?;
        Ok(match kind {
            "uniform" => ScheduleSpec::Uniform,
            "log" => ScheduleSpec::Log,
            "adaptive" => {
                let tol = match j.opt("tol") {
                    Some(v) => v.as_f64()?,
                    None => adaptive::DEFAULT_TOL,
                };
                ScheduleSpec::Adaptive { tol }
            }
            "tuned" => {
                let steps = match j.opt("steps") {
                    Some(v) => v.as_usize()?,
                    None => 0,
                };
                ScheduleSpec::Tuned { steps }
            }
            _ => bail!("unknown schedule kind {kind:?}"),
        })
    }

    /// Stable 64-bit identity for batch-compatibility keys: two requests may
    /// co-batch only when they run the same schedule.
    pub fn key_bits(&self) -> (u8, u64) {
        match self {
            ScheduleSpec::Uniform => (0, 0),
            ScheduleSpec::Log => (1, 0),
            ScheduleSpec::Adaptive { tol } => (2, tol.to_bits()),
            ScheduleSpec::Tuned { steps } => (3, *steps as u64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_roundtrip() {
        for s in [
            ScheduleSpec::Uniform,
            ScheduleSpec::Log,
            ScheduleSpec::Adaptive { tol: 1e-3 },
            ScheduleSpec::Adaptive { tol: 0.0 },
            ScheduleSpec::Tuned { steps: 0 },
            ScheduleSpec::Tuned { steps: 24 },
        ] {
            let text = s.to_string_spec();
            assert_eq!(ScheduleSpec::parse(&text).unwrap(), s, "{text}");
        }
    }

    #[test]
    fn spec_parse_defaults_and_errors() {
        assert_eq!(
            ScheduleSpec::parse("adaptive").unwrap(),
            ScheduleSpec::Adaptive { tol: adaptive::DEFAULT_TOL }
        );
        assert_eq!(ScheduleSpec::parse("tuned").unwrap(), ScheduleSpec::Tuned { steps: 0 });
        assert!(ScheduleSpec::parse("nope").is_err());
        assert!(ScheduleSpec::parse("adaptive:x=1").is_err());
        assert!(ScheduleSpec::parse("adaptive:tol=-1").is_err());
        assert!(ScheduleSpec::parse("adaptive:tol=nan").is_err());
        assert!(ScheduleSpec::parse("tuned:steps=0").is_err());
        assert!(ScheduleSpec::parse("uniform:x").is_err());
    }

    #[test]
    fn spec_json_roundtrip() {
        for s in [
            ScheduleSpec::Uniform,
            ScheduleSpec::Log,
            ScheduleSpec::Adaptive { tol: 1e-3 },
            ScheduleSpec::Tuned { steps: 0 },
            ScheduleSpec::Tuned { steps: 24 },
        ] {
            let j = s.to_json();
            assert_eq!(ScheduleSpec::from_json(&j).unwrap(), s, "{j:?}");
            // Text round-trip too (the wire path).
            let re = crate::util::json::Json::parse(&j.to_string()).unwrap();
            assert_eq!(ScheduleSpec::from_json(&re).unwrap(), s);
        }
        // String fallback.
        let j = crate::util::json::Json::from("adaptive:tol=0.001");
        assert_eq!(
            ScheduleSpec::from_json(&j).unwrap(),
            ScheduleSpec::Adaptive { tol: 1e-3 }
        );
        assert!(ScheduleSpec::from_json(
            &crate::util::json::Json::parse(r#"{"kind": "warp"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn key_bits_distinguish_specs() {
        let a = ScheduleSpec::Adaptive { tol: 1e-3 }.key_bits();
        let b = ScheduleSpec::Adaptive { tol: 2e-3 }.key_bits();
        let u = ScheduleSpec::Uniform.key_bits();
        assert_ne!(a, b);
        assert_ne!(a, u);
        assert_ne!(
            ScheduleSpec::Tuned { steps: 8 }.key_bits(),
            ScheduleSpec::Tuned { steps: 16 }.key_bits()
        );
    }
}
