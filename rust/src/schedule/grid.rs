//! Fixed time discretisations (migrated from `solvers/grid.rs`).
//!
//! The paper uses a uniform discretisation of (δ, 1] for the masked text and
//! image experiments (App. D.3/D.4) and an arithmetic sequence on [0, T - δ]
//! for the toy model (App. D.2).  Grids here are vectors of *forward* times,
//! strictly decreasing — the backward process consumes them left to right.
//! θ-section points ρ_n = t_n - θ Δ_n are computed inside the steps.
//!
//! Non-uniform grids come from two places: online, the
//! [`crate::schedule::adaptive`] controller realises one per run; offline,
//! the [`crate::schedule::tuner`] fits a reusable grid from pilot error
//! traces (see [`from_error_density`]).

/// Uniform grid on (δ, 1] for the masked process: n_steps + 1 forward times
/// from 1.0 down to δ.
pub fn masked_uniform(n_steps: usize, delta: f64) -> Vec<f64> {
    assert!(n_steps >= 1);
    assert!((0.0..1.0).contains(&delta));
    let h = (1.0 - delta) / n_steps as f64;
    let mut ts: Vec<f64> = (0..=n_steps).map(|i| 1.0 - h * i as f64).collect();
    *ts.last_mut().unwrap() = delta;
    ts
}

/// Arithmetic grid for the toy model: forward times from T down to δ.
pub fn toy_uniform(n_steps: usize, horizon: f64, delta: f64) -> Vec<f64> {
    assert!(n_steps >= 1);
    assert!(delta < horizon);
    let h = (horizon - delta) / n_steps as f64;
    let mut ts: Vec<f64> = (0..=n_steps).map(|i| horizon - h * i as f64).collect();
    *ts.last_mut().unwrap() = delta;
    ts
}

/// Log-spaced grid on (δ, 1] (geometric in t): the App. D-style alternative
/// used by the grid-placement ablation in DESIGN.md.
pub fn masked_log(n_steps: usize, delta: f64) -> Vec<f64> {
    assert!(n_steps >= 1);
    assert!(delta > 0.0 && delta < 1.0);
    let r = (delta.ln() / n_steps as f64).exp();
    let mut ts = Vec::with_capacity(n_steps + 1);
    let mut t = 1.0;
    for _ in 0..=n_steps {
        ts.push(t);
        t *= r;
    }
    *ts.last_mut().unwrap() = delta;
    ts
}

/// Validity check used by property tests and the coordinator.
pub fn is_valid_grid(ts: &[f64]) -> bool {
    ts.len() >= 2 && ts.windows(2).all(|w| w[0] > w[1]) && *ts.last().unwrap() > 0.0
}

/// Fit an `n_steps`-step grid on [t_lo, t_hi] that equidistributes an
/// empirical error density: `samples` are (forward time, local error per
/// unit time) observations, e.g. from adaptive pilot runs.  Grid points are
/// placed at equal quantiles of the cumulative error mass, so regions where
/// the estimated error is large get proportionally more (smaller) steps.
/// A uniform floor mixes in `floor_frac` of the total mass spread evenly,
/// keeping the grid valid where the pilots saw no error at all.
pub fn from_error_density(
    samples: &[(f64, f64)],
    n_steps: usize,
    t_hi: f64,
    t_lo: f64,
    floor_frac: f64,
) -> Vec<f64> {
    assert!(n_steps >= 1);
    assert!(t_hi > t_lo && t_lo > 0.0);
    assert!((0.0..=1.0).contains(&floor_frac));
    // Piecewise-constant density on a fine uniform lattice.
    let n_bins = (4 * n_steps).max(64);
    let w = (t_hi - t_lo) / n_bins as f64;
    let mut mass = vec![0.0f64; n_bins];
    for &(t, e) in samples {
        if !(e.is_finite() && e > 0.0) || !t.is_finite() {
            continue;
        }
        let b = (((t - t_lo) / w).floor() as isize).clamp(0, n_bins as isize - 1);
        mass[b as usize] += e;
    }
    let tot: f64 = mass.iter().sum();
    let floor = if tot > 0.0 {
        tot * floor_frac / n_bins as f64
    } else {
        1.0 // no evidence: pure floor = uniform grid
    };
    for m in mass.iter_mut() {
        *m += floor;
    }
    let tot: f64 = mass.iter().sum();

    // Walk the cumulative mass from the t_hi end (the backward process
    // consumes the grid left to right, i.e. decreasing t) and place an
    // interior grid point every `per` units of mass.
    let per = tot / n_steps as f64;
    let mut ts = Vec::with_capacity(n_steps + 1);
    ts.push(t_hi);
    let mut acc = 0.0;
    let mut next_cut = per;
    for b in (0..n_bins).rev() {
        let lo_edge = t_lo + b as f64 * w;
        let mut cur_hi = lo_edge + w;
        let mut seg_mass = mass[b];
        while acc + seg_mass >= next_cut && ts.len() < n_steps {
            // Linear interpolation inside the remaining [lo_edge, cur_hi]
            // segment (constant density within a bin).
            let need = next_cut - acc;
            let cut = cur_hi - (cur_hi - lo_edge) * (need / seg_mass);
            seg_mass -= need;
            acc = next_cut;
            next_cut += per;
            cur_hi = cut;
            let cut = cut.min(ts.last().unwrap() - 1e-12 * t_hi).max(t_lo);
            if cut < *ts.last().unwrap() && cut > t_lo {
                ts.push(cut);
            }
        }
        acc += seg_mass;
    }
    ts.push(t_lo);
    debug_assert!(is_valid_grid(&ts));
    ts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masked_uniform_endpoints_and_monotone() {
        let g = masked_uniform(10, 1e-3);
        assert_eq!(g.len(), 11);
        assert_eq!(g[0], 1.0);
        assert_eq!(*g.last().unwrap(), 1e-3);
        assert!(is_valid_grid(&g));
    }

    #[test]
    fn masked_uniform_equal_spacing() {
        let g = masked_uniform(4, 0.2);
        for w in g.windows(2) {
            assert!((w[0] - w[1] - 0.2).abs() < 1e-12);
        }
    }

    #[test]
    fn toy_uniform_endpoints() {
        let g = toy_uniform(16, 12.0, 1e-3);
        assert_eq!(g[0], 12.0);
        assert_eq!(*g.last().unwrap(), 1e-3);
        assert!(is_valid_grid(&g));
    }

    #[test]
    fn masked_log_is_geometric() {
        let g = masked_log(8, 1e-2);
        assert_eq!(g[0], 1.0);
        assert!((g.last().unwrap() - 1e-2).abs() < 1e-12);
        assert!(is_valid_grid(&g));
        let r0 = g[1] / g[0];
        for w in g.windows(2).take(7) {
            assert!((w[1] / w[0] - r0).abs() < 1e-9);
        }
    }

    #[test]
    fn single_step_grids() {
        assert_eq!(masked_uniform(1, 0.5), vec![1.0, 0.5]);
        assert!(is_valid_grid(&toy_uniform(1, 12.0, 0.1)));
    }

    #[test]
    fn error_density_uniform_when_flat() {
        // Flat density -> (approximately) uniform grid.
        let samples: Vec<(f64, f64)> =
            (0..200).map(|i| (0.01 + i as f64 * 0.005, 1.0)).collect();
        let g = from_error_density(&samples, 8, 1.0, 0.01, 0.0);
        assert_eq!(g.len(), 9);
        assert!(is_valid_grid(&g));
        let h0 = g[0] - g[1];
        for w in g.windows(2) {
            assert!((w[0] - w[1] - h0).abs() < 0.05, "{g:?}");
        }
    }

    #[test]
    fn error_density_concentrates_steps() {
        // All error mass near t_lo -> interior points crowd the low end.
        let samples: Vec<(f64, f64)> =
            (0..100).map(|i| (0.01 + i as f64 * 0.001, 5.0)).collect();
        let g = from_error_density(&samples, 8, 1.0, 0.005, 0.05);
        assert!(is_valid_grid(&g));
        assert_eq!(g.len(), 9);
        // More than half the interior points must sit below t = 0.3.
        let low = g[1..g.len() - 1].iter().filter(|&&t| t < 0.3).count();
        assert!(low >= 4, "{g:?}");
    }

    #[test]
    fn error_density_no_samples_is_uniformish() {
        let g = from_error_density(&[], 4, 1.0, 0.1, 0.1);
        assert!(is_valid_grid(&g));
        assert_eq!(g.len(), 5);
        assert_eq!(g[0], 1.0);
        assert_eq!(*g.last().unwrap(), 0.1);
    }
}
