//! Offline schedule tuning: turn a few adaptive pilot runs into a
//! reusable non-uniform grid.
//!
//! The online controller pays a one-step lag and re-estimates the error
//! for every request.  When the workload is stationary — same score
//! family, vocab, sequence length and solver — the error *profile* over
//! time is stable, so a grid fitted once from pilot error traces captures
//! most of the adaptive win at zero per-request overhead and with batch
//! co-scheduling for free (a tuned grid is just a fixed grid).
//!
//! [`ScheduleTuner`] runs the pilots and equidistributes their error mass
//! via [`grid::from_error_density`]; [`TunedSchedule`] serialises to JSON
//! so tuned grids survive across processes; [`ScheduleCache`] is the
//! coordinator-side memo keyed by (family, vocab, seq_len, solver, steps).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::ctmc::ToyModel;
use crate::schedule::adaptive::{AdaptiveController, StepController};
use crate::schedule::grid;
use crate::score::ScoreSource;
use crate::solvers::{masked, toy, Solver};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

/// Pilot-run configuration for fitting a tuned grid.
#[derive(Clone, Copy, Debug)]
pub struct ScheduleTuner {
    /// Number of pilot runs to trace.
    pub pilots: usize,
    /// Tolerance the pilots run at (finer than production: the fit wants a
    /// well-resolved error profile, not a fast run).
    pub tol: f64,
    /// Uniform mass floor mixed into the fitted density (keeps regions the
    /// pilots never flagged from collapsing to zero-width steps).
    pub floor_frac: f64,
    pub seed: u64,
}

impl Default for ScheduleTuner {
    fn default() -> Self {
        ScheduleTuner { pilots: 4, tol: 1e-4, floor_frac: 0.1, seed: 0x5EED }
    }
}

/// A fitted non-uniform grid plus the identity it was fitted for.
#[derive(Clone, Debug)]
pub struct TunedSchedule {
    pub family: String,
    pub vocab: usize,
    pub seq_len: usize,
    /// Canonical solver string ([`Solver::spec_string`]).
    pub solver: String,
    /// Strictly decreasing forward times (a valid fixed grid).
    pub grid: Vec<f64>,
    /// Mean NFE the pilots spent (diagnostic, not used at serve time).
    pub pilot_nfe: f64,
}

impl TunedSchedule {
    pub fn steps(&self) -> usize {
        self.grid.len() - 1
    }

    /// The cache key this schedule answers (steps is implied by the grid).
    pub fn key(&self) -> TuneKey {
        TuneKey {
            family: self.family.clone(),
            vocab: self.vocab,
            seq_len: self.seq_len,
            solver: self.solver.clone(),
            steps: self.steps(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("family", Json::from(self.family.as_str())),
            ("vocab", Json::from(self.vocab)),
            ("seq_len", Json::from(self.seq_len)),
            ("solver", Json::from(self.solver.as_str())),
            (
                "grid",
                Json::Arr(self.grid.iter().map(|&t| Json::Num(t)).collect()),
            ),
            ("pilot_nfe", Json::Num(self.pilot_nfe)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TunedSchedule> {
        let ts = TunedSchedule {
            family: j.get("family")?.as_str()?.to_string(),
            vocab: j.get("vocab")?.as_usize()?,
            seq_len: j.get("seq_len")?.as_usize()?,
            solver: j.get("solver")?.as_str()?.to_string(),
            grid: j.get("grid")?.as_f64_vec()?,
            pilot_nfe: j.opt("pilot_nfe").map(|v| v.as_f64()).transpose()?.unwrap_or(0.0),
        };
        if !grid::is_valid_grid(&ts.grid) {
            bail!("tuned schedule grid is not strictly decreasing/positive");
        }
        Solver::parse(&ts.solver)?;
        Ok(ts)
    }

    pub fn save(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())?;
        Ok(())
    }

    pub fn load(path: &str) -> Result<TunedSchedule> {
        TunedSchedule::from_json(&Json::parse(&std::fs::read_to_string(path)?)?)
    }
}

impl ScheduleTuner {
    fn pilot_controller(&self, t_hi: f64, t_lo: f64) -> StepController {
        let cfg = AdaptiveController::for_span(self.tol, t_hi, t_lo);
        StepController::new(cfg, (t_hi - t_lo) / 32.0)
    }

    /// Fit an `n_steps` grid for a masked score source by tracing
    /// `pilots` adaptive runs down to `delta`.
    pub fn fit_masked<S: ScoreSource + ?Sized>(
        &self,
        score: &S,
        solver: Solver,
        n_steps: usize,
        delta: f64,
        family: &str,
    ) -> TunedSchedule {
        assert!(n_steps >= 1 && self.pilots >= 1);
        let mut samples = Vec::new();
        let mut nfe = 0usize;
        for p in 0..self.pilots {
            let mut rng = Xoshiro256::seed_from_u64(
                self.seed ^ (p as u64).wrapping_mul(0x9E3779B97F4A7C15),
            );
            let ctl = self.pilot_controller(1.0, delta);
            let (_, stats, trace) =
                masked::generate_adaptive(score, solver, ctl, delta, &mut rng);
            samples.extend(trace.density_samples());
            nfe += stats.nfe;
        }
        TunedSchedule {
            family: family.to_string(),
            vocab: score.vocab(),
            seq_len: score.seq_len(),
            solver: solver.spec_string(),
            grid: grid::from_error_density(&samples, n_steps, 1.0, delta, self.floor_frac),
            pilot_nfe: nfe as f64 / self.pilots as f64,
        }
    }

    /// Fit an `n_steps` grid for the toy CTMC (family "toy", seq_len 1).
    pub fn fit_toy(
        &self,
        model: &ToyModel,
        solver: Solver,
        n_steps: usize,
        delta: f64,
    ) -> TunedSchedule {
        assert!(n_steps >= 1 && self.pilots >= 1);
        let mut samples = Vec::new();
        let mut nfe = 0usize;
        for p in 0..self.pilots {
            let mut rng = Xoshiro256::seed_from_u64(
                self.seed ^ (p as u64).wrapping_mul(0x9E3779B97F4A7C15),
            );
            let ctl = self.pilot_controller(model.horizon, delta);
            let (_, stats, trace) =
                toy::generate_adaptive(model, solver, ctl, delta, &mut rng);
            samples.extend(trace.density_samples());
            nfe += stats.nfe;
        }
        TunedSchedule {
            family: "toy".to_string(),
            vocab: model.n_states(),
            seq_len: 1,
            solver: solver.spec_string(),
            grid: grid::from_error_density(
                &samples,
                n_steps,
                model.horizon,
                delta,
                self.floor_frac,
            ),
            pilot_nfe: nfe as f64 / self.pilots as f64,
        }
    }
}

/// Identity a tuned grid is valid for.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct TuneKey {
    pub family: String,
    pub vocab: usize,
    pub seq_len: usize,
    pub solver: String,
    pub steps: usize,
}

impl TuneKey {
    pub fn new(family: &str, vocab: usize, seq_len: usize, solver: Solver, steps: usize) -> Self {
        TuneKey {
            family: family.to_string(),
            vocab,
            seq_len,
            solver: solver.spec_string(),
            steps,
        }
    }
}

/// Coordinator-side memo of tuned schedules: fit once per
/// (family, vocab, seq_len, solver, steps), reuse for every request.
/// Bounded: past [`ScheduleCache::MAX_ENTRIES`] distinct keys (solver θ
/// and step count are client-controlled), new fits are served without
/// being memoised instead of growing without bound.
///
/// With [`ScheduleCache::persistent`] the cache is disk-backed: every
/// insert flushes the fitted grid to `<dir>/<stem>.json` and a fresh cache
/// reloads the directory on construction, so tuned schedules survive
/// server restarts (a fit is paid once per key per *deployment*, not per
/// process).  Stems are digest-keyed (SHA-256 of the raw key), killing
/// the historical sanitized-stem collision hazard; reloading keys
/// entries by *content*, so files written under the old
/// sanitized+fnv1a stems keep loading forever (read compat).
///
/// With [`ScheduleCache::with_store`] the cache is additionally
/// registry-backed ([`crate::registry::ArtifactRegistry`]): a miss first
/// tries to pull a matching tuned grid by digest from the shared
/// registry, and a local fit is published back — across a fleet, the
/// first node to fit a key pays the pilot runs for everyone.
#[derive(Default)]
pub struct ScheduleCache {
    map: BTreeMap<TuneKey, Arc<TunedSchedule>>,
    /// Flush-on-insert directory; `None` = in-memory only.
    dir: Option<String>,
    /// Shared artifact registry; `None` = fit locally only.
    registry: Option<Arc<crate::registry::ArtifactRegistry>>,
}

impl ScheduleCache {
    pub const MAX_ENTRIES: usize = 256;

    pub fn new() -> Self {
        Self::default()
    }

    /// Disk-backed cache rooted at `dir` (created if missing): loads every
    /// `*.json` tuned schedule already there, flushes each new fit on
    /// insert.  Unreadable files are skipped with a warning — a corrupt
    /// entry must never take the coordinator down.
    pub fn persistent(dir: &str) -> Self {
        let mut cache = ScheduleCache {
            map: BTreeMap::new(),
            dir: Some(dir.to_string()),
            registry: None,
        };
        if let Err(e) = std::fs::create_dir_all(dir) {
            eprintln!("schedule cache: cannot create {dir:?}: {e}");
            return cache;
        }
        let entries = match std::fs::read_dir(dir) {
            Ok(entries) => entries,
            Err(e) => {
                eprintln!("schedule cache: cannot read {dir:?}: {e}");
                return cache;
            }
        };
        for entry in entries.flatten() {
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let Some(p) = path.to_str() else { continue };
            match TunedSchedule::load(p) {
                Ok(ts) => {
                    if cache.map.len() < Self::MAX_ENTRIES {
                        cache.map.insert(ts.key(), Arc::new(ts));
                    }
                }
                Err(e) => eprintln!("schedule cache: skipping {p:?}: {e:#}"),
            }
        }
        cache
    }

    /// `persistent(dir)` when a directory is configured, `new()` otherwise.
    pub fn with_dir(dir: Option<&str>) -> Self {
        match dir {
            Some(d) => Self::persistent(d),
            None => Self::new(),
        }
    }

    /// [`Self::with_dir`] plus an optional shared artifact registry: a
    /// cache miss then pulls matching tuned grids by digest before
    /// fitting, and local fits are published back (see
    /// [`Self::get_or_fit`]).
    pub fn with_store(
        dir: Option<&str>,
        registry: Option<Arc<crate::registry::ArtifactRegistry>>,
    ) -> Self {
        let mut cache = Self::with_dir(dir);
        cache.registry = registry;
        cache
    }

    pub fn get(&self, key: &TuneKey) -> Option<Arc<TunedSchedule>> {
        self.map.get(key).cloned()
    }

    /// Stable file stem for a key.  Both `family` and the solver spec are
    /// client-controlled strings, so every character outside
    /// `[A-Za-z0-9._-]` is replaced with '_' — in particular '/' (and
    /// therefore any `../` traversal) can never reach the filesystem path —
    /// and the stem is length-capped.  A SHA-256 digest of the RAW key is
    /// appended so distinct keys whose sanitized/truncated forms coincide
    /// (e.g. "a:b" vs "a_b") can never overwrite each other's file: unlike
    /// the 64-bit fnv1a suffix this replaced, a collision would need a
    /// SHA-256 collision.  Old fnv1a-suffixed files still load — reloading
    /// keys entries by parsed *content*, never by stem.
    fn file_stem(key: &TuneKey) -> String {
        let clean = |s: &str| -> String {
            s.chars()
                .take(64)
                .map(|c| {
                    if c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-') {
                        c
                    } else {
                        '_'
                    }
                })
                .collect()
        };
        let raw = format!(
            "{}\u{1f}{}\u{1f}{}\u{1f}{}\u{1f}{}",
            key.family, key.vocab, key.seq_len, key.solver, key.steps
        );
        let digest = crate::util::sha256::sha256_hex(raw.as_bytes());
        format!(
            "{}-v{}-l{}-{}-s{}-{}",
            clean(&key.family),
            key.vocab,
            key.seq_len,
            clean(&key.solver),
            key.steps,
            &digest[..32]
        )
    }

    pub fn insert(&mut self, key: TuneKey, sched: TunedSchedule) -> Arc<TunedSchedule> {
        // Flush to disk ONLY when the entry is also memoised: the
        // MAX_ENTRIES cap exists because solver θ / step counts are
        // client-controlled, and the on-disk footprint must obey the same
        // bound (otherwise a client looping over distinct θ values could
        // grow the directory without limit).
        if self.map.len() < Self::MAX_ENTRIES {
            if let Some(dir) = &self.dir {
                // Best effort — serving must not fail because the cache
                // directory is read-only or full.
                let path = format!("{dir}/{}.json", Self::file_stem(&key));
                if let Err(e) = sched.save(&path) {
                    eprintln!("schedule cache: cannot write {path:?}: {e:#}");
                }
            }
        }
        let arc = Arc::new(sched);
        if self.map.len() < Self::MAX_ENTRIES {
            self.map.insert(key, Arc::clone(&arc));
        }
        arc
    }

    /// Cached lookup; `fit` runs on miss and its result is memoised while
    /// the cache has room.
    ///
    /// Lookup order: memory (disk entries are loaded at construction) →
    /// shared registry by digest ([`ArtifactRegistry::find_tuned`]; the
    /// pulled grid is memoised + flushed locally but not re-published) →
    /// local fit, which is published back to the registry best-effort so
    /// the next node pulls instead of fitting.
    ///
    /// [`ArtifactRegistry::find_tuned`]: crate::registry::ArtifactRegistry::find_tuned
    pub fn get_or_fit(
        &mut self,
        key: TuneKey,
        fit: impl FnOnce() -> TunedSchedule,
    ) -> Arc<TunedSchedule> {
        if let Some(hit) = self.get(&key) {
            return hit;
        }
        if let Some(reg) = self.registry.clone() {
            if let Some(ts) = reg.find_tuned(&key) {
                return self.insert(key, (*ts).clone());
            }
            let fitted = fit();
            // Best effort: a read-only or full registry must not fail
            // serving — the fit is still memoised locally.
            if let Err(e) = reg.publish_tuned(&fitted, "schedule-cache") {
                eprintln!("schedule cache: cannot publish tuned grid: {e:#}");
            }
            return self.insert(key, fitted);
        }
        self.insert(key, fit())
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::markov::{MarkovChain, MarkovOracle};

    fn oracle() -> MarkovOracle {
        let mut rng = Xoshiro256::seed_from_u64(11);
        MarkovOracle::new(MarkovChain::generate(&mut rng, 6, 0.5), 12)
    }

    #[test]
    fn fit_masked_produces_valid_grid() {
        let o = oracle();
        let tuner = ScheduleTuner { pilots: 2, tol: 1e-3, ..Default::default() };
        let ts = tuner.fit_masked(&o, Solver::Trapezoidal { theta: 0.5 }, 12, 1e-3, "markov");
        assert_eq!(ts.steps(), 12);
        assert!(grid::is_valid_grid(&ts.grid));
        assert_eq!(ts.grid[0], 1.0);
        assert_eq!(*ts.grid.last().unwrap(), 1e-3);
        assert_eq!(ts.vocab, 6);
        assert_eq!(ts.seq_len, 12);
        assert!(ts.pilot_nfe > 0.0);
    }

    #[test]
    fn fit_toy_produces_valid_grid() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let model = ToyModel::paper_default(&mut rng);
        let tuner = ScheduleTuner { pilots: 3, ..Default::default() };
        let ts = tuner.fit_toy(&model, Solver::Trapezoidal { theta: 0.5 }, 16, 1e-3);
        assert_eq!(ts.steps(), 16);
        assert!(grid::is_valid_grid(&ts.grid));
        assert_eq!(ts.grid[0], model.horizon);
        assert_eq!(ts.family, "toy");
    }

    #[test]
    fn tuned_schedule_json_roundtrip() {
        let o = oracle();
        let tuner = ScheduleTuner { pilots: 1, ..Default::default() };
        let ts = tuner.fit_masked(&o, Solver::Rk2 { theta: 0.5 }, 8, 1e-3, "markov");
        let back = TunedSchedule::from_json(&ts.to_json()).unwrap();
        assert_eq!(back.grid, ts.grid);
        assert_eq!(back.solver, ts.solver);
        assert_eq!(back.vocab, ts.vocab);
    }

    #[test]
    fn from_json_rejects_bad_grid() {
        let j = Json::parse(
            r#"{"family":"markov","vocab":4,"seq_len":8,
                "solver":"trapezoidal:0.5","grid":[0.5, 0.5, 0.1]}"#,
        )
        .unwrap();
        assert!(TunedSchedule::from_json(&j).is_err());
        let j = Json::parse(
            r#"{"family":"markov","vocab":4,"seq_len":8,
                "solver":"nope","grid":[1.0, 0.1]}"#,
        )
        .unwrap();
        assert!(TunedSchedule::from_json(&j).is_err());
    }

    #[test]
    fn save_load_roundtrip() {
        let o = oracle();
        let tuner = ScheduleTuner { pilots: 1, ..Default::default() };
        let ts = tuner.fit_masked(&o, Solver::Trapezoidal { theta: 0.5 }, 6, 1e-3, "markov");
        let path = std::env::temp_dir().join("fastdds_tuned_schedule_test.json");
        let path = path.to_str().unwrap().to_string();
        ts.save(&path).unwrap();
        let back = TunedSchedule::load(&path).unwrap();
        assert_eq!(back.grid, ts.grid);
        assert_eq!(back.family, "markov");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persistent_file_stem_sanitizes_client_strings() {
        // `family` and the solver spec arrive verbatim from request JSON:
        // no separator may survive into the on-disk path.
        let key = TuneKey {
            family: "../../home/user/evil".into(),
            vocab: 4,
            seq_len: 8,
            solver: "trapezoidal:0.5".into(),
            steps: 4,
        };
        let stem = ScheduleCache::file_stem(&key);
        assert!(!stem.contains('/'), "{stem}");
        assert!(!stem.contains('\\'), "{stem}");
        assert!(!stem.contains(':'), "{stem}");

        // Distinct raw keys whose sanitized forms coincide must still get
        // distinct files (the appended raw-key digest disambiguates).
        let mut a = key.clone();
        a.family = "a:b".into();
        let mut b = key.clone();
        b.family = "a_b".into();
        assert_ne!(ScheduleCache::file_stem(&a), ScheduleCache::file_stem(&b));
    }

    #[test]
    fn colliding_specs_write_distinct_files_and_both_reload() {
        // Regression for the stem-collision hazard: two keys that sanitize
        // to the same readable prefix ("a:b" vs "a_b") must persist as two
        // files, and a restarted cache must serve both without refitting.
        let o = oracle();
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let dir = std::env::temp_dir().join(format!(
            "fastdds_sched_collide_{}",
            std::process::id()
        ));
        let dir = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);

        let tuner = ScheduleTuner { pilots: 1, ..Default::default() };
        let key_a = TuneKey::new("a:b", 6, 12, solver, 6);
        let key_b = TuneKey::new("a_b", 6, 12, solver, 8);
        {
            let mut cache = ScheduleCache::persistent(&dir);
            cache.get_or_fit(key_a.clone(), || {
                tuner.fit_masked(&o, solver, 6, 1e-3, "a:b")
            });
            cache.get_or_fit(key_b.clone(), || {
                tuner.fit_masked(&o, solver, 8, 1e-3, "a_b")
            });
        }
        let files: Vec<_> = std::fs::read_dir(&dir).unwrap().flatten().collect();
        assert_eq!(files.len(), 2, "colliding specs must not share a file");

        let mut cache = ScheduleCache::persistent(&dir);
        assert_eq!(cache.get_or_fit(key_a, || panic!("must not refit a:b")).steps(), 6);
        assert_eq!(cache.get_or_fit(key_b, || panic!("must not refit a_b")).steps(), 8);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_fnv1a_stem_files_still_load() {
        // Files flushed by older builds used a sanitized+fnv1a stem.
        // Reloading keys by parsed content, so any `*.json` stem — legacy
        // or digest-keyed — must keep serving its schedule.
        let o = oracle();
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let dir = std::env::temp_dir().join(format!(
            "fastdds_sched_legacy_{}",
            std::process::id()
        ));
        let dir = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();

        let ts = ScheduleTuner { pilots: 1, ..Default::default() }
            .fit_masked(&o, solver, 8, 1e-3, "markov");
        let key = ts.key();
        // The exact stem shape an old deployment left behind.
        ts.save(&format!("{dir}/markov-v6-l12-trapezoidal_0.5-s8-deadbeefcafef00d.json"))
            .unwrap();

        let mut cache = ScheduleCache::persistent(&dir);
        assert_eq!(cache.len(), 1, "legacy-stem file must load");
        let served = cache.get_or_fit(key, || panic!("legacy file must prevent a refit"));
        assert_eq!(served.grid, ts.grid);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn registry_backed_cache_pulls_instead_of_fitting() {
        let o = oracle();
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let root = std::env::temp_dir().join(format!(
            "fastdds_sched_registry_{}",
            std::process::id()
        ));
        let root = root.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&root);
        let reg = crate::registry::ArtifactRegistry::open(&root).unwrap();

        // Node A: misses everywhere, fits, publishes to the registry.
        let key = TuneKey::new("markov", 6, 12, solver, 8);
        let mut fits = 0usize;
        let first = {
            let mut cache = ScheduleCache::with_store(None, Some(Arc::clone(&reg)));
            let ts = cache.get_or_fit(key.clone(), || {
                fits += 1;
                ScheduleTuner { pilots: 1, ..Default::default() }
                    .fit_masked(&o, solver, 8, 1e-3, "markov")
            });
            ts.grid.clone()
        };
        assert_eq!(fits, 1);
        assert_eq!(reg.stats().puts, 1, "local fit must be published");

        // Node B: no schedule dir, fresh memory — the registry pull must
        // satisfy the miss without running the tuner.
        let mut cache = ScheduleCache::with_store(None, Some(Arc::clone(&reg)));
        let pulled = cache.get_or_fit(key.clone(), || panic!("registry hit must not refit"));
        assert_eq!(pulled.grid, first);
        // And the pull is memoised: a second lookup stays in memory.
        let again = cache.get_or_fit(key, || panic!("memoised"));
        assert_eq!(again.grid, first);
        assert_eq!(reg.stats().puts, 1, "a pulled grid must not be re-published");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn persistent_cache_survives_restart() {
        let o = oracle();
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let dir = std::env::temp_dir().join(format!(
            "fastdds_sched_cache_{}_{}",
            std::process::id(),
            7u32
        ));
        let dir = dir.to_str().unwrap().to_string();
        let _ = std::fs::remove_dir_all(&dir);

        // First process: fit once, flushed to disk on insert.
        let mut fits = 0usize;
        let key = TuneKey::new("markov", 6, 12, solver, 8);
        let first = {
            let mut cache = ScheduleCache::persistent(&dir);
            assert!(cache.is_empty(), "fresh dir must load empty");
            let ts = cache.get_or_fit(key.clone(), || {
                fits += 1;
                ScheduleTuner { pilots: 1, ..Default::default() }
                    .fit_masked(&o, solver, 8, 1e-3, "markov")
            });
            ts.grid.clone()
        };
        assert_eq!(fits, 1);

        // "Restart": a fresh cache over the same dir serves the fit from
        // disk without refitting.
        let mut cache = ScheduleCache::persistent(&dir);
        assert_eq!(cache.len(), 1, "tuned grid must reload from disk");
        let ts = cache.get_or_fit(key, || panic!("restart must not refit"));
        assert_eq!(ts.grid, first);
        assert_eq!(ts.steps(), 8);

        // Corrupt entries are skipped, never fatal.
        std::fs::write(format!("{dir}/garbage.json"), "{not json").unwrap();
        let cache = ScheduleCache::persistent(&dir);
        assert_eq!(cache.len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cache_fits_once() {
        let o = oracle();
        let solver = Solver::Trapezoidal { theta: 0.5 };
        let mut cache = ScheduleCache::new();
        let key = TuneKey::new("markov", 6, 12, solver, 8);
        let mut fits = 0usize;
        for _ in 0..3 {
            let _ = cache.get_or_fit(key.clone(), || {
                fits += 1;
                ScheduleTuner { pilots: 1, ..Default::default() }
                    .fit_masked(&o, solver, 8, 1e-3, "markov")
            });
        }
        assert_eq!(fits, 1);
        assert_eq!(cache.len(), 1);
        let other = TuneKey::new("markov", 6, 12, solver, 16);
        assert!(cache.get(&other).is_none());
    }
}
