//! Synthetic data substrates (DESIGN.md substitutions for OpenWebText /
//! ImageNet): Markov "language" corpora ([`corpus`]), token-grid "images"
//! ([`images`]) and serving workload traces ([`workload`]).

pub mod corpus;
pub mod images;
pub mod workload;
