//! Synthetic text corpus: sequences from the Markov data law, plus a token
//! decoder for human-readable sample dumps (Fig. 7-style visualisation).

use crate::score::markov::MarkovChain;
use crate::score::Tok;
use crate::util::rng::Xoshiro256;

/// A corpus of reference sequences from the true data law.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub seq_len: usize,
    pub sequences: Vec<Vec<Tok>>,
}

impl Corpus {
    /// Bulk generation goes through the prebuilt alias sampler: the O(V²)
    /// table build amortises over n·seq_len O(1) draws (vs an O(V) CDF
    /// scan per token), which dominates for every corpus size used here.
    pub fn sample(chain: &MarkovChain, seq_len: usize, n: usize, seed: u64) -> Self {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let sampler = chain.sampler();
        let sequences = (0..n).map(|_| sampler.sample(&mut rng, seq_len)).collect();
        Self { seq_len, sequences }
    }

    /// Unigram frequencies across the corpus (sanity statistics).
    pub fn unigram(&self, vocab: usize) -> Vec<f64> {
        let mut counts = vec![0usize; vocab];
        let mut tot = 0usize;
        for s in &self.sequences {
            for &t in s {
                counts[t as usize] += 1;
                tot += 1;
            }
        }
        counts.into_iter().map(|c| c as f64 / tot.max(1) as f64).collect()
    }

    /// Bigram frequencies (row-major vocab x vocab).
    pub fn bigram(&self, vocab: usize) -> Vec<f64> {
        let mut counts = vec![0usize; vocab * vocab];
        let mut tot = 0usize;
        for s in &self.sequences {
            for w in s.windows(2) {
                counts[w[0] as usize * vocab + w[1] as usize] += 1;
                tot += 1;
            }
        }
        counts.into_iter().map(|c| c as f64 / tot.max(1) as f64).collect()
    }
}

/// Render tokens as pseudo-text for sample dumps: each token maps to a
/// letter-like glyph so perplexity differences are eyeballable.
pub fn decode_pretty(seq: &[Tok], vocab: usize) -> String {
    const GLYPHS: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789_~";
    seq.iter()
        .map(|&t| {
            let idx = (t as usize).min(vocab.min(GLYPHS.len()) - 1);
            GLYPHS[idx] as char
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> MarkovChain {
        let mut rng = Xoshiro256::seed_from_u64(5);
        MarkovChain::generate(&mut rng, 6, 0.5)
    }

    #[test]
    fn corpus_shapes_and_range() {
        let c = Corpus::sample(&chain(), 24, 50, 1);
        assert_eq!(c.sequences.len(), 50);
        for s in &c.sequences {
            assert_eq!(s.len(), 24);
            assert!(s.iter().all(|&t| (t as usize) < 6));
        }
    }

    #[test]
    fn unigram_matches_stationary() {
        let ch = chain();
        let c = Corpus::sample(&ch, 64, 2000, 2);
        let uni = c.unigram(6);
        for v in 0..6 {
            assert!(
                (uni[v] - ch.pi[v]).abs() < 0.02,
                "tok {v}: {} vs {}",
                uni[v],
                ch.pi[v]
            );
        }
    }

    #[test]
    fn bigram_matches_chain() {
        let ch = chain();
        let c = Corpus::sample(&ch, 64, 4000, 3);
        let bi = c.bigram(6);
        for a in 0..6 {
            for b in 0..6 {
                let want = ch.pi[a] * ch.at(a, b);
                assert!(
                    (bi[a * 6 + b] - want).abs() < 0.02,
                    "({a},{b}): {} vs {want}",
                    bi[a * 6 + b]
                );
            }
        }
    }

    #[test]
    fn decode_pretty_stable() {
        assert_eq!(decode_pretty(&[0, 1, 2], 6), "abc");
        assert_eq!(decode_pretty(&[5, 5], 6), "ff");
        // Out-of-range tokens clamp rather than panic.
        assert_eq!(decode_pretty(&[99], 6), "f");
    }

    #[test]
    fn deterministic_by_seed() {
        let ch = chain();
        let a = Corpus::sample(&ch, 16, 5, 9);
        let b = Corpus::sample(&ch, 16, 5, 9);
        assert_eq!(a.sequences, b.sequences);
    }
}
