//! Synthetic "tokenized images": H x W grids of VQ-style tokens generated
//! row-major by the Markov data law (a simple MRF whose exact conditionals
//! the oracle already knows).  Substitutes MaskGIT's VQ-GAN ImageNet tokens
//! (DESIGN.md): the masked-diffusion sampler treats a grid exactly like a
//! sequence of length H*W, and FID is computed over the feature map below.

use crate::score::markov::MarkovChain;
use crate::score::Tok;

#[derive(Clone, Copy, Debug)]
pub struct GridSpec {
    pub h: usize,
    pub w: usize,
    pub vocab: usize,
}

impl GridSpec {
    pub fn seq_len(&self) -> usize {
        self.h * self.w
    }
}

/// Feature map for FID: unigram histogram (V) + horizontal-neighbour
/// co-occurrence histogram (V^2) + vertical-neighbour co-occurrence (V^2),
/// all normalised.  These are sufficient statistics for the row-major
/// Markov law, so any sampler-induced distribution error moves them.
pub fn features(spec: &GridSpec, grid: &[Tok]) -> Vec<f64> {
    let (h, w, v) = (spec.h, spec.w, spec.vocab);
    assert_eq!(grid.len(), h * w);
    let mut f = vec![0.0; v + 2 * v * v];
    let uni_n = (h * w) as f64;
    for &t in grid {
        f[t as usize] += 1.0 / uni_n;
    }
    let hor_n = (h * (w - 1)) as f64;
    for r in 0..h {
        for c in 0..w - 1 {
            let a = grid[r * w + c] as usize;
            let b = grid[r * w + c + 1] as usize;
            f[v + a * v + b] += 1.0 / hor_n;
        }
    }
    let ver_n = ((h - 1) * w) as f64;
    for r in 0..h - 1 {
        for c in 0..w {
            let a = grid[r * w + c] as usize;
            let b = grid[(r + 1) * w + c] as usize;
            f[v + v * v + a * v + b] += 1.0 / ver_n;
        }
    }
    f
}

/// Project full features to a lower dimension with a fixed seeded random
/// sign matrix (keeps the Jacobi eigendecompositions cheap at vocab 16+).
pub fn project_features(f: &[f64], out_dim: usize, seed: u64) -> Vec<f64> {
    use crate::util::rng::{Rng, Xoshiro256};
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let d = f.len();
    let mut out = vec![0.0; out_dim];
    // Column-major sign projection, one pass; scale by 1/sqrt(out_dim).
    let scale = 1.0 / (out_dim as f64).sqrt();
    for fi in f.iter().copied() {
        if fi == 0.0 {
            for _ in 0..out_dim {
                rng.gen_u64();
            }
            continue;
        }
        for o in out.iter_mut() {
            let sign = if rng.gen_u64() & 1 == 0 { 1.0 } else { -1.0 };
            *o += sign * fi * scale;
        }
    }
    debug_assert_eq!(d, f.len());
    out
}

/// Reference feature set from the true data law.
pub fn reference_features(
    chain: &MarkovChain,
    spec: &GridSpec,
    n: usize,
    seed: u64,
) -> Vec<Vec<f64>> {
    use crate::util::rng::Xoshiro256;
    let mut rng = Xoshiro256::seed_from_u64(seed);
    (0..n)
        .map(|_| {
            let grid = chain.sample(&mut rng, spec.seq_len());
            features(spec, &grid)
        })
        .collect()
}

/// Render a grid as ASCII art (Fig. 7-style dumps).
pub fn render_ascii(spec: &GridSpec, grid: &[Tok]) -> String {
    const SHADES: &[u8] = b" .:-=+*#%@&$OXoxKKWWMM88BBQQRRNNHHUUAAVVYYTTLLJJCCZZSSEEFFPPGGDD";
    let mut out = String::with_capacity((spec.w + 1) * spec.h);
    for r in 0..spec.h {
        for c in 0..spec.w {
            let t = grid[r * spec.w + c] as usize;
            out.push(SHADES[t.min(SHADES.len() - 1)] as char);
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn setup() -> (MarkovChain, GridSpec) {
        let mut rng = Xoshiro256::seed_from_u64(13);
        let chain = MarkovChain::generate(&mut rng, 8, 0.5);
        (chain, GridSpec { h: 8, w: 8, vocab: 8 })
    }

    #[test]
    fn features_normalised_blocks() {
        let (chain, spec) = setup();
        let mut rng = Xoshiro256::seed_from_u64(1);
        let grid = chain.sample(&mut rng, spec.seq_len());
        let f = features(&spec, &grid);
        let v = spec.vocab;
        assert_eq!(f.len(), v + 2 * v * v);
        let uni: f64 = f[..v].iter().sum();
        let hor: f64 = f[v..v + v * v].iter().sum();
        let ver: f64 = f[v + v * v..].iter().sum();
        assert!((uni - 1.0).abs() < 1e-9);
        assert!((hor - 1.0).abs() < 1e-9);
        assert!((ver - 1.0).abs() < 1e-9);
    }

    #[test]
    fn reference_fid_self_consistency() {
        // Two disjoint reference sets should have tiny FID.
        let (chain, spec) = setup();
        let a = reference_features(&chain, &spec, 600, 1);
        let b = reference_features(&chain, &spec, 600, 2);
        // Project to keep the test fast.
        let pa: Vec<Vec<f64>> = a.iter().map(|f| project_features(f, 24, 7)).collect();
        let pb: Vec<Vec<f64>> = b.iter().map(|f| project_features(f, 24, 7)).collect();
        let d = crate::eval::fid::fid(&pa, &pb);
        let noise: Vec<Vec<f64>> = {
            let mut rng = Xoshiro256::seed_from_u64(3);
            (0..600)
                .map(|_| {
                    let grid: Vec<Tok> = (0..spec.seq_len())
                        .map(|_| crate::util::rng::Rng::gen_usize(&mut rng, 8) as Tok)
                        .collect();
                    project_features(&features(&spec, &grid), 24, 7)
                })
                .collect()
        };
        let d_noise = crate::eval::fid::fid(&pa, &noise);
        assert!(d < d_noise, "self={d} noise={d_noise}");
    }

    #[test]
    fn projection_is_deterministic_and_linearish() {
        let f = vec![0.5, 0.25, 0.25, 0.0];
        let a = project_features(&f, 8, 1);
        let b = project_features(&f, 8, 1);
        assert_eq!(a, b);
        let scaled = project_features(&f.iter().map(|x| x * 2.0).collect::<Vec<_>>(), 8, 1);
        for i in 0..8 {
            assert!((scaled[i] - 2.0 * a[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn ascii_render_shape() {
        let (chain, spec) = setup();
        let mut rng = Xoshiro256::seed_from_u64(4);
        let grid = chain.sample(&mut rng, spec.seq_len());
        let art = render_ascii(&spec, &grid);
        assert_eq!(art.lines().count(), 8);
        assert!(art.lines().all(|l| l.chars().count() == 8));
    }
}
