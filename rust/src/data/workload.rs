//! Serving workload traces: Poisson request arrivals with a solver mix —
//! input to the coordinator benchmarks and the batching-policy ablation.

use crate::solvers::Solver;
use crate::util::dist::exponential;
use crate::util::rng::{Rng, Xoshiro256};

#[derive(Clone, Debug)]
pub struct TraceRequest {
    /// Arrival time in seconds from trace start.
    pub arrival: f64,
    pub solver: Solver,
    pub nfe: usize,
    pub n_samples: usize,
    pub seed: u64,
}

#[derive(Clone, Debug)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

#[derive(Clone, Debug)]
pub struct TraceSpec {
    /// Mean arrival rate (requests/second).
    pub rate: f64,
    pub n_requests: usize,
    /// (solver, weight) mix.
    pub mix: Vec<(Solver, f64)>,
    pub nfe_choices: Vec<usize>,
    pub max_samples: usize,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self {
            rate: 20.0,
            n_requests: 100,
            mix: vec![
                (Solver::TauLeaping, 0.3),
                (Solver::Trapezoidal { theta: 0.5 }, 0.5),
                (Solver::Euler, 0.2),
            ],
            nfe_choices: vec![16, 32, 64],
            max_samples: 8,
        }
    }
}

pub fn generate_trace(spec: &TraceSpec, seed: u64) -> Trace {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let weights: Vec<f64> = spec.mix.iter().map(|&(_, w)| w).collect();
    let mut t = 0.0;
    let mut requests = Vec::with_capacity(spec.n_requests);
    for i in 0..spec.n_requests {
        t += exponential(&mut rng, spec.rate);
        let solver = spec.mix[crate::util::dist::categorical_f64(&mut rng, &weights)].0;
        let nfe = spec.nfe_choices[rng.gen_usize(spec.nfe_choices.len())];
        requests.push(TraceRequest {
            arrival: t,
            solver,
            nfe,
            n_samples: 1 + rng.gen_usize(spec.max_samples),
            seed: seed.wrapping_add(i as u64),
        });
    }
    Trace { requests }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_sized() {
        let t = generate_trace(&TraceSpec::default(), 1);
        assert_eq!(t.requests.len(), 100);
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn arrival_rate_approximate() {
        let spec = TraceSpec { rate: 50.0, n_requests: 5000, ..Default::default() };
        let t = generate_trace(&spec, 2);
        let span = t.requests.last().unwrap().arrival;
        let rate = 5000.0 / span;
        assert!((rate - 50.0).abs() < 5.0, "rate={rate}");
    }

    #[test]
    fn solver_mix_respected() {
        let t = generate_trace(&TraceSpec::default(), 3);
        let trap = t
            .requests
            .iter()
            .filter(|r| matches!(r.solver, Solver::Trapezoidal { .. }))
            .count();
        assert!(trap > 30 && trap < 70, "trap count {trap}");
    }

    #[test]
    fn deterministic_by_seed() {
        let a = generate_trace(&TraceSpec::default(), 9);
        let b = generate_trace(&TraceSpec::default(), 9);
        assert_eq!(a.requests.len(), b.requests.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.nfe, y.nfe);
        }
    }
}
