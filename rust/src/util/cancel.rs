//! Cooperative cancellation + deadlines for long-running sampler jobs.
//!
//! A [`CancelToken`] is a cheaply clonable flag shared between the party
//! that may cancel (the server's `cancel` verb, a [`super::cli`] user
//! hitting ctrl-c, a test) and the party doing the work (the solver driver
//! loop, the exact-simulation window loop).  The worker polls
//! [`CancelToken::is_cancelled`] at its natural checkpoints — once per grid
//! window for the approximate schemes, once per uniformization window /
//! first-hitting event for exact simulation — and winds down returning
//! whatever partial state it has.  Polling never consumes randomness, so a
//! run that is *not* cancelled is bit-identical to one executed without any
//! token (pinned by `tests/golden_parity.rs`, deadlines included).
//!
//! An armed token can additionally carry a **deadline** (an absolute
//! [`Instant`]): once it passes, the token reads as cancelled at the very
//! same per-window checkpoints — deadline enforcement costs the worker
//! nothing beyond the poll it already does, and an expired run completes
//! with a partial response exactly like a cancelled one.
//! [`CancelToken::deadline_expired`] distinguishes the two after the fact
//! (the coordinator's `deadline_expiries` vs cancel accounting).
//!
//! The default token ([`CancelToken::never`]) carries no flag at all: hot
//! loops on the non-serving entry points pay a single `Option` branch.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

#[derive(Debug)]
struct Flag {
    fired: AtomicBool,
    /// Absolute wall deadline; `None` = no deadline.
    deadline: Option<Instant>,
}

/// Shared cancellation flag, optionally deadline-armed (see module docs).
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Option<Arc<Flag>>);

impl CancelToken {
    /// An armed token: [`CancelToken::cancel`] flips it for every clone.
    pub fn new() -> CancelToken {
        CancelToken::with_deadline(None)
    }

    /// An armed token that additionally reads as cancelled once `deadline`
    /// passes.  `None` is equivalent to [`CancelToken::new`].
    pub fn with_deadline(deadline: Option<Instant>) -> CancelToken {
        CancelToken(Some(Arc::new(Flag {
            fired: AtomicBool::new(false),
            deadline,
        })))
    }

    /// A token that can never fire (the default).
    pub fn never() -> CancelToken {
        CancelToken(None)
    }

    /// Request cancellation.  No-op on a never-token.
    pub fn cancel(&self) {
        if let Some(flag) = &self.0 {
            flag.fired.store(true, Ordering::Relaxed);
        }
    }

    pub fn is_cancelled(&self) -> bool {
        match &self.0 {
            Some(flag) => {
                flag.fired.load(Ordering::Relaxed)
                    || matches!(flag.deadline, Some(d) if Instant::now() >= d)
            }
            None => false,
        }
    }

    /// Whether the manual flag was fired (a deadline alone never sets it).
    pub fn fired(&self) -> bool {
        match &self.0 {
            Some(flag) => flag.fired.load(Ordering::Relaxed),
            None => false,
        }
    }

    /// Whether the token carries a deadline that has passed.
    pub fn deadline_expired(&self) -> bool {
        match &self.0 {
            Some(flag) => matches!(flag.deadline, Some(d) if Instant::now() >= d),
            None => false,
        }
    }

    /// Whether the token can ever fire (i.e. is not a never-token).
    pub fn can_fire(&self) -> bool {
        self.0.is_some()
    }

    /// Whether two tokens observe the same underlying flag.
    pub fn same(a: &CancelToken, b: &CancelToken) -> bool {
        match (&a.0, &b.0) {
            (Some(x), Some(y)) => Arc::ptr_eq(x, y),
            (None, None) => true,
            _ => false,
        }
    }
}

/// Early-stop control for exact simulation: the cancel token plus an
/// optional hard cap on *accepted* events (the `max_events` knob of
/// [`crate::api::SolverCfg::Exact`]).  Exact simulation cannot budget its
/// NFE a priori; `max_events` is the serving-side guard that bounds a
/// pathological run, marking the result partial instead of overrunning.
#[derive(Clone, Debug, Default)]
pub struct StopCtl {
    pub cancel: CancelToken,
    pub max_events: Option<usize>,
}

impl StopCtl {
    /// No cancellation, no event cap — the non-serving default.
    pub fn none() -> StopCtl {
        StopCtl::default()
    }

    pub fn cancelled(&self) -> bool {
        self.cancel.is_cancelled()
    }

    /// Whether `accepted` events exhaust the cap.
    pub fn events_exhausted(&self, accepted: usize) -> bool {
        match self.max_events {
            Some(m) => accepted >= m,
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn token_fires_across_clones() {
        let t = CancelToken::new();
        let c = t.clone();
        assert!(!t.is_cancelled() && !c.is_cancelled());
        c.cancel();
        assert!(t.is_cancelled() && c.is_cancelled());
        assert!(CancelToken::same(&t, &c));
        assert!(!CancelToken::same(&t, &CancelToken::new()));
    }

    #[test]
    fn never_token_never_fires() {
        let t = CancelToken::never();
        t.cancel();
        assert!(!t.is_cancelled());
        assert!(!t.can_fire());
        assert!(!t.deadline_expired());
        assert!(CancelToken::same(&t, &CancelToken::default()));
    }

    #[test]
    fn deadline_reads_as_cancelled_once_passed() {
        let far = Instant::now() + Duration::from_secs(3600);
        let t = CancelToken::with_deadline(Some(far));
        assert!(!t.is_cancelled() && !t.deadline_expired());

        let past = Instant::now() - Duration::from_millis(1);
        let t = CancelToken::with_deadline(Some(past));
        assert!(t.is_cancelled(), "passed deadline must read as cancelled");
        assert!(t.deadline_expired());
        assert!(!t.fired(), "a deadline alone must not set the manual flag");
        // Clones observe the same deadline.
        assert!(t.clone().is_cancelled());
    }

    #[test]
    fn manual_cancel_distinguishable_from_expiry() {
        let far = Instant::now() + Duration::from_secs(3600);
        let t = CancelToken::with_deadline(Some(far));
        t.cancel();
        assert!(t.is_cancelled() && t.fired());
        assert!(!t.deadline_expired());
    }

    #[test]
    fn stop_ctl_event_cap() {
        let s = StopCtl { cancel: CancelToken::never(), max_events: Some(3) };
        assert!(!s.events_exhausted(2));
        assert!(s.events_exhausted(3));
        assert!(!StopCtl::none().events_exhausted(usize::MAX - 1));
    }
}
