//! Sampling distributions built on [`crate::util::rng::Rng`].
//!
//! Everything the solvers need: exponential (exact-method waiting times),
//! Poisson (τ-leap jump counts), Bernoulli/binomial, categorical (linear CDF
//! and alias method), and Gumbel (parallel decoding confidence noise).

use super::rng::Rng;

/// Exp(rate) via inverse CDF.
#[inline]
pub fn exponential<R: Rng>(rng: &mut R, rate: f64) -> f64 {
    debug_assert!(rate > 0.0);
    -(rng.gen_f64().ln()) / rate
}

/// Poisson(mean). Knuth multiplication for small means, PA-normal
/// (Atkinson-style) rejection for large.
pub fn poisson<R: Rng>(rng: &mut R, mean: f64) -> u64 {
    debug_assert!(mean >= 0.0);
    if mean == 0.0 {
        return 0;
    }
    if mean < 30.0 {
        // Knuth: multiply uniforms until the product drops below e^-mean.
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.gen_f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }
    // Transformed rejection with squeeze (Hörmann's PTRS).
    let b = 0.931 + 2.53 * mean.sqrt();
    let a = -0.059 + 0.02483 * b;
    let inv_alpha = 1.1239 + 1.1328 / (b - 3.4);
    let v_r = 0.9277 - 3.6224 / (b - 2.0);
    loop {
        let u = rng.gen_f64() - 0.5;
        let v = rng.gen_f64();
        let us = 0.5 - u.abs();
        let k = ((2.0 * a / us + b) * u + mean + 0.43).floor();
        if us >= 0.07 && v <= v_r {
            return k as u64;
        }
        if k < 0.0 || (us < 0.013 && v > us) {
            continue;
        }
        let log_v = (v * inv_alpha / (a / (us * us) + b)).ln();
        let rhs = k * mean.ln() - mean - ln_factorial(k as u64);
        if log_v <= rhs {
            return k as u64;
        }
    }
}

/// ln(k!) via Stirling series for k > 20, table otherwise.
pub fn ln_factorial(k: u64) -> f64 {
    const TABLE: [f64; 21] = [
        0.0,
        0.0,
        0.6931471805599453,
        1.791759469228055,
        3.1780538303479458,
        4.787491742782046,
        6.579251212010101,
        8.525161361065415,
        10.60460290274525,
        12.801827480081469,
        15.104412573075516,
        17.502307845873887,
        19.987214495661885,
        22.552163853123425,
        25.19122118273868,
        27.89927138384089,
        30.671860106080672,
        33.50507345013689,
        36.39544520803305,
        39.339884187199495,
        42.335616460753485,
    ];
    if k <= 20 {
        return TABLE[k as usize];
    }
    let x = (k + 1) as f64;
    (x - 0.5) * x.ln() - x + 0.5 * (2.0 * std::f64::consts::PI).ln()
        + 1.0 / (12.0 * x)
        - 1.0 / (360.0 * x * x * x)
}

/// Binomial(n, p) by inversion for small n*p, sum of Bernoullis otherwise
/// for small n, normal-free (exact) throughout.
pub fn binomial<R: Rng>(rng: &mut R, n: u64, p: f64) -> u64 {
    debug_assert!((0.0..=1.0).contains(&p));
    if p == 0.0 || n == 0 {
        return 0;
    }
    if p >= 1.0 {
        return n;
    }
    if p > 0.5 {
        return n - binomial(rng, n, 1.0 - p);
    }
    if n <= 64 {
        let mut k = 0;
        for _ in 0..n {
            if rng.gen_f64() < p {
                k += 1;
            }
        }
        return k;
    }
    // Inversion by waiting times (geometric skips): O(np) expected.
    let lq = (1.0 - p).ln();
    let mut k: u64 = 0;
    let mut i: u64 = 0;
    loop {
        let g = (rng.gen_f64().ln() / lq).floor() as u64 + 1;
        i += g;
        if i > n {
            return k;
        }
        k += 1;
    }
}

/// Categorical draw from unnormalised non-negative weights (linear CDF scan).
/// Returns `None` when the total mass is zero.
pub fn categorical<R: Rng>(rng: &mut R, weights: &[f64]) -> Option<usize> {
    let tot: f64 = weights.iter().sum();
    if !(tot > 0.0) {
        return None;
    }
    let mut thresh = rng.gen_f64() * tot;
    for (i, &w) in weights.iter().enumerate() {
        thresh -= w;
        if thresh < 0.0 {
            return Some(i);
        }
    }
    // Floating-point slack: return the last positive-weight index.
    weights.iter().rposition(|&w| w > 0.0)
}

/// Categorical draw from weights with known-positive total mass.
#[inline]
pub fn categorical_f64<R: Rng>(rng: &mut R, weights: &[f64]) -> usize {
    categorical(rng, weights).expect("categorical_f64 requires positive mass")
}

/// Walker alias table for O(1) categorical sampling from a fixed law.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        let tot: f64 = weights.iter().sum();
        assert!(tot > 0.0, "alias table needs positive total mass");
        let mut prob: Vec<f64> = weights.iter().map(|w| w * n as f64 / tot).collect();
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = (0..n).filter(|&i| prob[i] < 1.0).collect();
        let mut large: Vec<usize> = (0..n).filter(|&i| prob[i] >= 1.0).collect();
        while let (Some(s), Some(l)) = (small.pop(), large.pop()) {
            alias[s] = l;
            prob[l] = (prob[l] + prob[s]) - 1.0;
            if prob[l] < 1.0 {
                small.push(l);
            } else {
                large.push(l);
            }
        }
        for i in small.into_iter().chain(large) {
            prob[i] = 1.0;
        }
        Self { prob, alias }
    }

    #[inline]
    pub fn sample<R: Rng>(&self, rng: &mut R) -> usize {
        let n = self.prob.len();
        let i = rng.gen_usize(n);
        if rng.gen_f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }
}

/// Standard Gumbel(0, 1) draw.
#[inline]
pub fn gumbel<R: Rng>(rng: &mut R, u_clip: f64) -> f64 {
    let u = rng.gen_f64().clamp(u_clip, 1.0 - u_clip);
    -(-u.ln()).ln()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn rng() -> Xoshiro256 {
        Xoshiro256::seed_from_u64(12345)
    }

    #[test]
    fn exponential_mean() {
        let mut r = rng();
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| exponential(&mut r, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn poisson_small_mean_moments() {
        let mut r = rng();
        let lam = 3.7;
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| poisson(&mut r, lam) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - lam).abs() < 0.05, "mean={mean}");
        assert!((var - lam).abs() < 0.15, "var={var}");
    }

    #[test]
    fn poisson_large_mean_moments() {
        let mut r = rng();
        let lam = 250.0;
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| poisson(&mut r, lam) as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!((mean - lam).abs() < 0.5, "mean={mean}");
        assert!((var / lam - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn poisson_zero_mean() {
        let mut r = rng();
        assert_eq!(poisson(&mut r, 0.0), 0);
    }

    #[test]
    fn ln_factorial_matches_direct() {
        let mut acc = 0.0f64;
        for k in 1..=30u64 {
            acc += (k as f64).ln();
            assert!(
                (ln_factorial(k) - acc).abs() < 1e-8,
                "k={k} got={} want={acc}",
                ln_factorial(k)
            );
        }
    }

    #[test]
    fn binomial_moments_small_and_large() {
        let mut r = rng();
        for (n_tr, p) in [(40u64, 0.3), (5000u64, 0.002), (300u64, 0.9)] {
            let n = 30_000;
            let xs: Vec<f64> = (0..n).map(|_| binomial(&mut r, n_tr, p) as f64).collect();
            let mean = xs.iter().sum::<f64>() / n as f64;
            let want = n_tr as f64 * p;
            let sd = (n_tr as f64 * p * (1.0 - p)).sqrt();
            assert!(
                (mean - want).abs() < 4.0 * sd / (n as f64).sqrt() + 0.02,
                "n={n_tr} p={p} mean={mean} want={want}"
            );
        }
    }

    #[test]
    fn categorical_frequencies() {
        let mut r = rng();
        let w = [1.0, 2.0, 3.0, 4.0];
        let n = 100_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[categorical(&mut r, &w).unwrap()] += 1;
        }
        for i in 0..4 {
            let got = counts[i] as f64 / n as f64;
            let want = w[i] / 10.0;
            assert!((got - want).abs() < 0.01, "i={i} got={got} want={want}");
        }
    }

    #[test]
    fn categorical_zero_mass() {
        let mut r = rng();
        assert_eq!(categorical(&mut r, &[0.0, 0.0]), None);
    }

    #[test]
    fn alias_table_matches_linear() {
        let mut r = rng();
        let w = [0.5, 0.0, 2.5, 1.0, 6.0];
        let table = AliasTable::new(&w);
        let n = 200_000;
        let mut counts = [0usize; 5];
        for _ in 0..n {
            counts[table.sample(&mut r)] += 1;
        }
        let tot: f64 = w.iter().sum();
        for i in 0..5 {
            let got = counts[i] as f64 / n as f64;
            let want = w[i] / tot;
            assert!((got - want).abs() < 0.01, "i={i} got={got} want={want}");
        }
        assert_eq!(counts[1], 0);
    }

    #[test]
    fn gumbel_location() {
        let mut r = rng();
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| gumbel(&mut r, 1e-12)).sum::<f64>() / n as f64;
        // E[Gumbel] = Euler-Mascheroni.
        assert!((mean - 0.5772).abs() < 0.02, "mean={mean}");
    }
}
