//! Deterministic PRNGs: xoshiro256++ (main generator) and PCG32 (cheap
//! per-request streams), both seeded through SplitMix64.
//!
//! The coordinator owns *all* request-path randomness: uniforms are drawn
//! here and fed to the AOT step graphs as inputs, making generation
//! bit-reproducible from a request seed across the whole three-layer stack.

/// SplitMix64: seed expander (Steele, Lea, Flood 2014).
#[derive(Clone, Copy, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ 1.0 (Blackman & Vigna 2019). Period 2^256 - 1.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// The long-jump function: 2^192 steps, for independent parallel streams.
    pub fn long_jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x76e15d3efefdcbbf,
            0xc5004e441c522fb3,
            0x77710069854ee241,
            0x39109bb02acbe635,
        ];
        let mut s = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j & (1u64 << b)) != 0 {
                    s[0] ^= self.s[0];
                    s[1] ^= self.s[1];
                    s[2] ^= self.s[2];
                    s[3] ^= self.s[3];
                }
                self.next_u64();
            }
        }
        self.s = s;
    }

    /// Fork an independent stream (long-jumped copy; self also advances).
    pub fn fork(&mut self) -> Self {
        let mut child = self.clone();
        child.long_jump();
        // Decorrelate the parent from the child's pre-jump state.
        self.next_u64();
        child
    }
}

/// PCG32 (O'Neill 2014): XSH-RR 64/32. Small state for per-request streams.
#[derive(Clone, Copy, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut r = Self { state: 0, inc: (stream << 1) | 1 };
        r.next_u32();
        r.state = r.state.wrapping_add(seed);
        r.next_u32();
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }
}

/// Uniform-sampling trait shared by both generators.
pub trait Rng {
    fn gen_u64(&mut self) -> u64;

    /// U(0, 1) with 53 random bits; never returns exactly 0 or 1.
    #[inline]
    fn gen_f64(&mut self) -> f64 {
        let u = (self.gen_u64() >> 11) as f64 * (1.0 / 9007199254740992.0);
        if u == 0.0 {
            f64::MIN_POSITIVE
        } else {
            u
        }
    }

    #[inline]
    fn gen_f32(&mut self) -> f32 {
        self.gen_f64() as f32
    }

    /// Uniform integer in [0, n) via Lemire's rejection-free-ish method.
    #[inline]
    fn gen_range(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply; negligible bias rejection loop.
        loop {
            let x = self.gen_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    fn gen_usize(&mut self, n: usize) -> usize {
        self.gen_range(n as u64) as usize
    }

    fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Fill a buffer with U(0,1) f32s (step-graph uniforms).
    fn fill_f32(&mut self, buf: &mut [f32]) {
        for b in buf.iter_mut() {
            *b = self.gen_f32();
        }
    }
}

impl Rng for Xoshiro256 {
    #[inline]
    fn gen_u64(&mut self) -> u64 {
        self.next_u64()
    }
}

impl Rng for Pcg32 {
    #[inline]
    fn gen_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xoshiro_reference_vector() {
        // First outputs for the all-SplitMix64(0) seeding are stable.
        let mut a = Xoshiro256::seed_from_u64(0);
        let mut b = Xoshiro256::seed_from_u64(0);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_decorrelated() {
        let mut a = Xoshiro256::seed_from_u64(7);
        let mut c = a.fork();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.gen_f64();
            assert!(u > 0.0 && u < 1.0);
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
    }

    #[test]
    fn gen_range_bounds_and_coverage() {
        let mut r = Pcg32::new(42, 54);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let k = r.gen_range(7) as usize;
            assert!(k < 7);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn pcg_streams_differ() {
        let mut a = Pcg32::new(9, 1);
        let mut b = Pcg32::new(9, 2);
        assert_ne!(a.next_u32(), b.next_u32());
    }
}
