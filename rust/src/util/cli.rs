//! Tiny CLI argument parser (clap is not vendored in this image).
//!
//! Supports: positional subcommands, `--key value`, `--key=value`, and bare
//! `--flag` switches, with typed accessors and defaults.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare `--` is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.str_opt(name).unwrap_or(default).to_string()
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {s:?}")),
        }
    }

    /// Optional numeric flag: None when absent, parse error when malformed.
    pub fn f64_opt(&self, name: &str) -> Result<Option<f64>> {
        self.str_opt(name)
            .map(|s| {
                s.parse()
                    .map_err(|_| anyhow!("--{name} expects a number, got {s:?}"))
            })
            .transpose()
    }

    /// Optional integer flag: None when absent, parse error when malformed.
    pub fn usize_opt(&self, name: &str) -> Result<Option<usize>> {
        self.str_opt(name)
            .map(|s| {
                s.parse()
                    .map_err(|_| anyhow!("--{name} expects an integer, got {s:?}"))
            })
            .transpose()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.str_opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {s:?}")),
        }
    }

    /// Comma-separated list of numbers, e.g. `--nfe 16,32,64`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.str_opt(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{name}: bad integer {p:?}"))
                })
                .collect(),
        }
    }

    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.str_opt(name) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| anyhow!("--{name}: bad number {p:?}"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["exp", "fig2", "--steps", "64", "--theta=0.5", "--verbose"]);
        assert_eq!(a.subcommand(), Some("exp"));
        assert_eq!(a.positional[1], "fig2");
        assert_eq!(a.get_usize("steps", 0).unwrap(), 64);
        assert_eq!(a.get_f64("theta", 0.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults_and_lists() {
        let a = parse(&["x", "--nfe", "16,32,64"]);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert_eq!(a.get_usize_list("nfe", &[]).unwrap(), vec![16, 32, 64]);
        assert_eq!(
            a.get_f64_list("thetas", &[0.5]).unwrap(),
            vec![0.5]
        );
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--fast", "--seed", "9"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 9);
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.get_usize("steps", 0).is_err());
    }
}
