//! SHA-256 (FIPS 180-4) plus hex codecs — the content-address substrate
//! for the artifact registry ([`crate::registry`]).
//!
//! Implemented from the spec because the image vendors no crypto crate:
//! the standard Merkle–Damgård construction over 64-byte blocks with the
//! usual eight-word state and 64-round compression.  Both a streaming
//! hasher ([`Sha256`]) and a one-shot helper ([`sha256_hex`]) are
//! provided; the unit tests pin the NIST FIPS 180-4 vectors (empty,
//! "abc", the two-block message) and a streaming-vs-oneshot equality
//! property over random chunkings, so an incorrect carry in the length
//! counter or the block buffer cannot survive CI.
//!
//! This is an integrity hash for artifact addressing, not a password /
//! key-derivation primitive — no constant-time claims are made.

/// Round constants: first 32 bits of the fractional parts of the cube
/// roots of the first 64 primes (FIPS 180-4 §4.2.2).
const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1,
    0x923f82a4, 0xab1c5ed5, 0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3,
    0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174, 0xe49b69c1, 0xefbe4786,
    0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147,
    0x06ca6351, 0x14292967, 0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13,
    0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85, 0xa2bfe8a1, 0xa81a664b,
    0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a,
    0x5b9cca4f, 0x682e6ff3, 0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208,
    0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Initial hash state: first 32 bits of the fractional parts of the
/// square roots of the first 8 primes (FIPS 180-4 §5.3.3).
const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c,
    0x1f83d9ab, 0x5be0cd19,
];

/// Streaming SHA-256 hasher: `update` any number of times, `finalize`
/// once.  Equivalent to hashing the concatenation in one shot.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    /// Partial block awaiting compression.
    buf: [u8; 64],
    buf_len: usize,
    /// Total message length in bytes (the padding encodes it in bits).
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { state: H0, buf: [0u8; 64], buf_len: 0, total_len: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        // Top up a partial block first.
        if self.buf_len > 0 {
            let take = (64 - self.buf_len).min(data.len());
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        // Whole blocks straight from the input.
        while data.len() >= 64 {
            let mut block = [0u8; 64];
            block.copy_from_slice(&data[..64]);
            self.compress(&block);
            data = &data[64..];
        }
        // Stash the tail.
        if !data.is_empty() {
            self.buf[..data.len()].copy_from_slice(data);
            self.buf_len = data.len();
        }
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // Padding: 0x80, zeros to 56 mod 64, then the 64-bit bit length.
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0x00]);
        }
        // Write the length directly into the buffer (update would also
        // advance total_len, which no longer matters, but keep it exact).
        self.buf[56..64].copy_from_slice(&bit_len.to_be_bytes());
        let block = self.buf;
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let mut w = [0u32; 64];
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        self.state[0] = self.state[0].wrapping_add(a);
        self.state[1] = self.state[1].wrapping_add(b);
        self.state[2] = self.state[2].wrapping_add(c);
        self.state[3] = self.state[3].wrapping_add(d);
        self.state[4] = self.state[4].wrapping_add(e);
        self.state[5] = self.state[5].wrapping_add(f);
        self.state[6] = self.state[6].wrapping_add(g);
        self.state[7] = self.state[7].wrapping_add(h);
    }
}

/// One-shot digest.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// One-shot digest as lowercase hex — the registry's canonical address
/// form (64 chars, `[0-9a-f]`).
pub fn sha256_hex(data: &[u8]) -> String {
    hex_encode(&sha256(data))
}

/// Lowercase hex encoding.
pub fn hex_encode(bytes: &[u8]) -> String {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut out = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        out.push(HEX[(b >> 4) as usize] as char);
        out.push(HEX[(b & 0x0f) as usize] as char);
    }
    out
}

/// Hex decoding (either case accepted).  Fails on odd length or a
/// non-hex character — wire blobs travel hex-encoded, so a malformed
/// payload must die typed at the boundary, not corrupt a blob.
pub fn hex_decode(s: &str) -> anyhow::Result<Vec<u8>> {
    fn nibble(c: u8) -> Option<u8> {
        match c {
            b'0'..=b'9' => Some(c - b'0'),
            b'a'..=b'f' => Some(c - b'a' + 10),
            b'A'..=b'F' => Some(c - b'A' + 10),
            _ => None,
        }
    }
    let bytes = s.as_bytes();
    if bytes.len() % 2 != 0 {
        anyhow::bail!("hex string has odd length {}", bytes.len());
    }
    let mut out = Vec::with_capacity(bytes.len() / 2);
    for pair in bytes.chunks_exact(2) {
        let (hi, lo) = match (nibble(pair[0]), nibble(pair[1])) {
            (Some(h), Some(l)) => (h, l),
            _ => anyhow::bail!(
                "invalid hex byte {:?}",
                String::from_utf8_lossy(pair)
            ),
        };
        out.push((hi << 4) | lo);
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    // NIST FIPS 180-4 test vectors.
    #[test]
    fn fips_vector_empty() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn fips_vector_abc() {
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
    }

    #[test]
    fn fips_vector_two_block() {
        // 56 bytes: the padding spills into a second block.
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
    }

    #[test]
    fn million_a() {
        // The classic long-message vector: 1,000,000 x 'a', streamed in
        // deliberately awkward chunk sizes.
        let mut h = Sha256::new();
        let chunk = [b'a'; 997];
        let mut left = 1_000_000usize;
        while left > 0 {
            let n = left.min(chunk.len());
            h.update(&chunk[..n]);
            left -= n;
        }
        assert_eq!(
            hex_encode(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn streaming_equals_oneshot_over_random_chunkings() {
        let mut rng = Xoshiro256::seed_from_u64(0xD16E57);
        for case in 0..32 {
            let len = (rng.next_u64() % 700) as usize + case;
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let oneshot = sha256_hex(&data);
            let mut h = Sha256::new();
            let mut off = 0usize;
            while off < data.len() {
                let take = ((rng.next_u64() % 130) as usize + 1).min(data.len() - off);
                h.update(&data[off..off + take]);
                off += take;
            }
            assert_eq!(hex_encode(&h.finalize()), oneshot, "len={len}");
        }
        // Empty-update streams are the oneshot of "".
        let mut h = Sha256::new();
        h.update(b"");
        h.update(b"");
        assert_eq!(hex_encode(&h.finalize()), sha256_hex(b""));
    }

    #[test]
    fn hex_roundtrip_and_rejects() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        for len in [0usize, 1, 2, 31, 32, 65] {
            let data: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let enc = hex_encode(&data);
            assert_eq!(enc.len(), 2 * len);
            assert_eq!(hex_decode(&enc).unwrap(), data);
            // Uppercase decodes to the same bytes.
            assert_eq!(hex_decode(&enc.to_uppercase()).unwrap(), data);
        }
        assert!(hex_decode("abc").is_err(), "odd length must fail");
        assert!(hex_decode("zz").is_err(), "non-hex must fail");
        assert!(hex_decode("0g").is_err());
    }
}
