//! From-scratch substrates.
//!
//! This image vendors only the `xla` crate's dependency closure, so every
//! support library a serving system normally pulls from crates.io is
//! implemented here: PRNG ([`rng`]), sampling distributions ([`dist`]), JSON
//! ([`json`]), CLI parsing ([`cli`]), a thread pool ([`threadpool`]) and
//! statistics (mean/CI/bootstrap/regression, [`stats`]).

pub mod rng;
pub mod dist;
pub mod json;
pub mod sha256;
pub mod cli;
pub mod cancel;
pub mod threadpool;
pub mod stats;
