//! Fixed-size thread pool with a `par_map` helper (tokio is not vendored in
//! this image; the coordinator uses std threads + channels throughout).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex, OnceLock};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A simple work-stealing-free pool: one shared queue, N workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    pub size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        assert!(size > 0);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = {
                        // A job that panics while a sibling waits on the
                        // receiver poisons this mutex; the receiver itself
                        // stays valid, so recover and keep the pool alive
                        // instead of cascading the panic to every worker.
                        let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break,
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers, size }
    }

    /// Pool sized to the machine (capped — PJRT also spawns threads).
    /// Memoised: `available_parallelism` is a syscall on most platforms and
    /// this is queried on every batched score evaluation, so the probe runs
    /// once per process.
    pub fn default_size() -> usize {
        static SIZE: OnceLock<usize> = OnceLock::new();
        *SIZE.get_or_init(|| {
            std::thread::available_parallelism()
                .map(|n| n.get().min(16))
                .unwrap_or(4)
        })
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker channel closed");
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split a mutable slice into contiguous chunks of (at most) `chunk`
/// elements.  Uses `mem::take` so each chunk carries the full original
/// lifetime (required to move chunks into scoped threads).
fn chunks_mut<T>(mut rest: &mut [T], chunk: usize) -> Vec<&mut [T]> {
    let mut v = Vec::new();
    while !rest.is_empty() {
        let take = chunk.min(rest.len());
        let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
        v.push(head);
        rest = tail;
    }
    v
}

/// Run `f(i)` for i in 0..n across up to `threads` scoped threads and return
/// results in order.  Each thread handles a contiguous chunk (deterministic
/// work assignment keeps seeded RNG streams reproducible).
pub fn par_map_indexed<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let slots = chunks_mut(&mut out, chunk);
    std::thread::scope(|scope| {
        for (c, slot) in slots.into_iter().enumerate() {
            let f = &f;
            scope.spawn(move || {
                for (j, cell) in slot.iter_mut().enumerate() {
                    *cell = Some(f(c * chunk + j));
                }
            });
        }
    });
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// Run `f(i, &mut xs[i], &ys[i])` for all i, chunked contiguously across up
/// to `threads` scoped threads.  Deterministic work assignment: the result
/// is identical to the sequential loop whatever the thread count.  Used by
/// the batched score-evaluation default to fan per-lane sparse evaluations
/// out without giving up bit-reproducibility.
pub fn par_zip_mut<A, B, F>(xs: &mut [A], ys: &[B], threads: usize, f: F)
where
    A: Send,
    B: Sync,
    F: Fn(usize, &mut A, &B) + Sync,
{
    let n = xs.len();
    assert_eq!(n, ys.len(), "par_zip_mut slice length mismatch");
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for (i, (x, y)) in xs.iter_mut().zip(ys).enumerate() {
            f(i, x, y);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let x_chunks = chunks_mut(xs, chunk);
    std::thread::scope(|scope| {
        for (c, xc) in x_chunks.into_iter().enumerate() {
            let f = &f;
            let base = c * chunk;
            let yc = &ys[base..base + xc.len()];
            scope.spawn(move || {
                for (j, (x, y)) in xc.iter_mut().zip(yc).enumerate() {
                    f(base + j, x, y);
                }
            });
        }
    });
}

/// As [`par_zip_mut`] but with both slices mutable: `f(i, &mut xs[i],
/// &mut ys[i])`.  Used to step solver lane state and its scratch buffers
/// together from worker threads.
pub fn par_zip_mut2<A, B, F>(xs: &mut [A], ys: &mut [B], threads: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut A, &mut B) + Sync,
{
    let n = xs.len();
    assert_eq!(n, ys.len(), "par_zip_mut2 slice length mismatch");
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for (i, (x, y)) in xs.iter_mut().zip(ys.iter_mut()).enumerate() {
            f(i, x, y);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let x_chunks = chunks_mut(xs, chunk);
    let y_chunks = chunks_mut(ys, chunk);
    std::thread::scope(|scope| {
        for (c, (xc, yc)) in x_chunks.into_iter().zip(y_chunks).enumerate() {
            let f = &f;
            let base = c * chunk;
            scope.spawn(move || {
                for (j, (x, y)) in xc.iter_mut().zip(yc.iter_mut()).enumerate() {
                    f(base + j, x, y);
                }
            });
        }
    });
}

/// Global atomic counter used by tests and metrics.
#[derive(Default)]
pub struct Counter(AtomicUsize);

impl Counter {
    pub fn new() -> Self {
        Self(AtomicUsize::new(0))
    }
    pub fn inc(&self) -> usize {
        self.0.fetch_add(1, Ordering::Relaxed)
    }
    pub fn add(&self, n: usize) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }
    pub fn get(&self) -> usize {
        self.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                tx.send(()).unwrap();
            });
        }
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_drop_joins_workers() {
        let pool = ThreadPool::new(2);
        let c = Arc::new(AtomicUsize::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&c);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // must not hang, must have run everything
        assert_eq!(c.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn par_map_order_preserved() {
        let out = par_map_indexed(1000, 8, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_edge_cases() {
        assert_eq!(par_map_indexed(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(par_map_indexed(1, 4, |i| i + 1), vec![1]);
        assert_eq!(par_map_indexed(3, 100, |i| i), vec![0, 1, 2]);
    }

    #[test]
    fn par_zip_mut_matches_sequential() {
        let ys: Vec<usize> = (0..257).collect();
        for threads in [1, 3, 8] {
            let mut xs = vec![0usize; 257];
            par_zip_mut(&mut xs, &ys, threads, |i, x, y| *x = i * 10 + *y);
            for (i, (&x, &y)) in xs.iter().zip(&ys).enumerate() {
                assert_eq!(x, i * 10 + y, "threads={threads} i={i}");
            }
        }
    }

    #[test]
    fn par_zip_mut2_updates_both_sides() {
        for threads in [1, 4, 100] {
            let mut xs: Vec<usize> = (0..37).collect();
            let mut ys = vec![0usize; 37];
            par_zip_mut2(&mut xs, &mut ys, threads, |i, x, y| {
                *x += 1;
                *y = i + *x;
            });
            for i in 0..37 {
                assert_eq!(xs[i], i + 1);
                assert_eq!(ys[i], 2 * i + 1);
            }
        }
    }

    #[test]
    fn par_zip_empty_and_single() {
        let mut xs: Vec<usize> = Vec::new();
        par_zip_mut(&mut xs, &[], 4, |_, _, _: &usize| unreachable!());
        let mut one = vec![5usize];
        let ys = vec![7usize];
        par_zip_mut(&mut one, &ys, 4, |i, x, y| *x = i + *x + *y);
        assert_eq!(one, vec![12]);
    }

    #[test]
    fn counter_concurrent() {
        let c = Arc::new(Counter::new());
        std::thread::scope(|s| {
            for _ in 0..8 {
                let c = Arc::clone(&c);
                s.spawn(move || {
                    for _ in 0..1000 {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), 8000);
    }
}
