//! Minimal JSON reader/writer (RFC 8259 subset sufficient for manifests and
//! the server protocol): no external deps are available in this image, so
//! this is a from-scratch recursive-descent parser plus a compact writer.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value. Objects preserve key lookup via a BTreeMap.
///
/// Integers are a first-class variant: JSON has one number type, but the
/// request protocol carries 64-bit ids and seeds whose values exceed 2^53 —
/// routing them through `f64` silently corrupts them.  The parser yields
/// [`Json::Int`] for any numeric token without a fraction or exponent, the
/// writer emits the digits verbatim, and [`Json::as_u64`] recovers the
/// exact value.  [`PartialEq`] compares `Int` and `Num` numerically so
/// hand-built documents (`Json::Num(42.0)`) still equal their re-parse.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Lossless integer (covers the full `u64` and `i64` ranges).
    Int(i128),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl PartialEq for Json {
    fn eq(&self, other: &Json) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::Int(a), Json::Int(b)) => a == b,
            (Json::Num(a), Json::Int(b)) | (Json::Int(b), Json::Num(a)) => {
                // Equal only when the float is exactly the integer (no
                // rounding): the cast round-trip must land back on b.
                *a == *b as f64 && !a.is_infinite() && *a as i128 == *b
            }
            _ => false,
        }
    }
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key {key:?}")),
            _ => bail!("not an object (looking up {key:?})"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            Json::Int(i) => Ok(*i as f64),
            _ => bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Json::Int(i) if *i >= 0 && *i <= usize::MAX as i128 => Ok(*i as usize),
            Json::Int(i) => bail!("not a non-negative integer: {i}"),
            _ => {
                let x = self.as_f64()?;
                if x < 0.0 || x.fract() != 0.0 {
                    bail!("not a non-negative integer: {x}");
                }
                Ok(x as usize)
            }
        }
    }

    /// Exact u64 accessor: integers round-trip losslessly through
    /// [`Json::Int`]; floats are accepted only below 2^53, where every
    /// integer is still exactly representable.
    pub fn as_u64(&self) -> Result<u64> {
        match self {
            Json::Int(i) if *i >= 0 && *i <= u64::MAX as i128 => Ok(*i as u64),
            Json::Num(x)
                if *x >= 0.0 && x.fract() == 0.0 && *x <= 9_007_199_254_740_992.0 =>
            {
                Ok(*x as u64)
            }
            _ => bail!("not a u64: {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("not a bool: {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_f64_mat(&self) -> Result<Vec<Vec<f64>>> {
        self.as_arr()?.iter().map(|v| v.as_f64_vec()).collect()
    }

    // ---- writer ----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Int(x as i128)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Int(x as i128)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Int(x as i128)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}", c as char, self.i);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        // Integer tokens (no fraction, no exponent) parse losslessly:
        // 64-bit ids and seeds must not be laundered through f64.
        if !s.contains(['.', 'e', 'E']) {
            if let Ok(i) = s.parse::<i128>() {
                return Ok(Json::Int(i));
            }
        }
        let x: f64 = s.parse().with_context(|| format!("bad number {s:?}"))?;
        Ok(Json::Num(x))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = (start + len).min(self.b.len());
                        out.push_str(std::str::from_utf8(&self.b[start..end])?);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' got {:?} at byte {}", c as char, self.i),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' got {:?} at byte {}", c as char, self.i),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": "hi\nthere"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64().unwrap(), 1.0);
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "hi\nthere");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn nested_and_unicode() {
        let src = r#"{"x": {"y": [[1,2],[3,4]]}, "s": "été"}"#;
        let v = Json::parse(src).unwrap();
        let mat = v.get("x").unwrap().get("y").unwrap().as_f64_mat().unwrap();
        assert_eq!(mat, vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "été");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("{\"k\": \"héllo → 世界\"}").unwrap();
        assert_eq!(v.get("k").unwrap().as_str().unwrap(), "héllo → 世界");
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123 456").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn integer_formatting() {
        let v = Json::obj(vec![("n", Json::Num(42.0)), ("x", Json::Num(0.5))]);
        let s = v.to_string();
        assert!(s.contains("\"n\":42"), "{s}");
        assert!(s.contains("\"x\":0.5"), "{s}");
    }

    #[test]
    fn u64_round_trip_is_lossless() {
        // Values above 2^53 corrupt through f64; they must survive the
        // parser + writer bit for bit.
        for v in [
            0u64,
            1,
            (1u64 << 53) - 1,
            (1u64 << 53) + 1,
            u64::MAX - 1,
            u64::MAX,
        ] {
            let doc = Json::obj(vec![("seed", Json::from(v))]);
            let text = doc.to_string();
            assert!(text.contains(&format!("{v}")), "{text}");
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.get("seed").unwrap().as_u64().unwrap(), v, "{text}");
        }
        // i64 negatives survive too.
        let doc = Json::obj(vec![("x", Json::from(-1234567890123456789i64))]);
        let back = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(back, doc);
        // A float token is not a lossless u64 once it leaves the safe range.
        assert!(Json::Num(9.3e18).as_u64().is_err());
        assert_eq!(Json::Num(42.0).as_u64().unwrap(), 42);
    }

    #[test]
    fn int_and_num_compare_numerically() {
        assert_eq!(Json::Int(42), Json::Num(42.0));
        assert_eq!(Json::Num(42.0), Json::Int(42));
        assert_ne!(Json::Int(42), Json::Num(42.5));
        // A u64 beyond 2^53 is NOT equal to its rounded f64 image.
        let big = (1i128 << 53) + 1;
        assert_ne!(Json::Int(big), Json::Num(big as f64));
        // Usize/f64 From impls agree under eq.
        assert_eq!(Json::from(7usize), Json::from(7.0f64));
    }

    #[test]
    fn accessor_errors() {
        let v = Json::parse("{\"a\": 1.5}").unwrap();
        assert!(v.get("a").unwrap().as_usize().is_err());
        assert!(v.get("missing").is_err());
        assert!(v.get("a").unwrap().as_str().is_err());
    }

    #[test]
    fn escape_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}
