//! Statistics: summary moments, quantiles, histograms, bootstrap confidence
//! intervals (used for Fig. 2's 95% CI exactly as App. D.2 prescribes), and
//! least-squares regression (the log-log convergence-slope fits).

use crate::util::rng::{Rng, Xoshiro256};

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Linear-interpolated quantile, q in [0, 1]; input need not be sorted.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Counts of `xs` into `n_bins` equal bins over [lo, hi].
pub fn histogram(xs: &[f64], lo: f64, hi: f64, n_bins: usize) -> Vec<usize> {
    assert!(hi > lo && n_bins > 0);
    let mut bins = vec![0usize; n_bins];
    let w = (hi - lo) / n_bins as f64;
    for &x in xs {
        if x < lo || x >= hi {
            continue;
        }
        let i = (((x - lo) / w) as usize).min(n_bins - 1);
        bins[i] += 1;
    }
    bins
}

/// Empirical distribution of categorical samples (np.bincount equivalent).
pub fn bincount(xs: &[usize], n: usize) -> Vec<f64> {
    let mut counts = vec![0usize; n];
    for &x in xs {
        assert!(x < n, "category {x} out of range {n}");
        counts[x] += 1;
    }
    let tot = xs.len().max(1) as f64;
    counts.into_iter().map(|c| c as f64 / tot).collect()
}

/// Bootstrap confidence interval for a statistic of iid samples.
///
/// `stat` maps a resample to a scalar; returns (lo, hi) at the given level
/// (e.g. 0.95) from `n_boot` resamples.  Matches the paper's App. D.2
/// procedure (1000 resamples, 95%).
pub fn bootstrap_ci<F>(xs: &[f64], n_boot: usize, level: f64, seed: u64, stat: F) -> (f64, f64)
where
    F: Fn(&[f64]) -> f64,
{
    assert!(!xs.is_empty());
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut vals = Vec::with_capacity(n_boot);
    let mut resample = vec![0.0; xs.len()];
    for _ in 0..n_boot {
        for r in resample.iter_mut() {
            *r = xs[rng.gen_usize(xs.len())];
        }
        vals.push(stat(&resample));
    }
    vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let alpha = (1.0 - level) / 2.0;
    (
        quantile_sorted(&vals, alpha),
        quantile_sorted(&vals, 1.0 - alpha),
    )
}

/// Ordinary least squares y = a + b x. Returns (intercept, slope, r^2).
pub fn linreg(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let syy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    let slope = sxy / sxx;
    let intercept = my - slope * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    let _ = n;
    (intercept, slope, r2)
}

/// Log-log regression: fits y ~ c * x^slope; returns (slope, r^2).
/// The Fig. 2 convergence-order estimator.
pub fn loglog_slope(x: &[f64], y: &[f64]) -> (f64, f64) {
    let lx: Vec<f64> = x.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = y.iter().map(|v| v.max(1e-300).ln()).collect();
    let (_, slope, r2) = linreg(&lx, &ly);
    (slope, r2)
}

/// Welford online accumulator for streaming metrics.
#[derive(Clone, Debug, Default)]
pub struct Online {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Online {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mean(&xs), 3.0);
        assert!((variance(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantiles() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert!((quantile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts() {
        let xs = [0.1, 0.2, 0.5, 0.9, 1.5, -0.3];
        let h = histogram(&xs, 0.0, 1.0, 2);
        // [0, .5): {0.1, 0.2}; [.5, 1): {0.5, 0.9}; 1.5 and -0.3 fall out.
        assert_eq!(h, vec![2, 2]);
    }

    #[test]
    fn bincount_normalises() {
        let b = bincount(&[0, 0, 1, 2], 4);
        assert_eq!(b, vec![0.5, 0.25, 0.25, 0.0]);
    }

    #[test]
    fn bootstrap_contains_truth() {
        // Mean of U(0,1) samples: CI should bracket 0.5 nearly always.
        let mut rng = Xoshiro256::seed_from_u64(5);
        let xs: Vec<f64> = (0..2000).map(|_| rng.gen_f64()).collect();
        let (lo, hi) = bootstrap_ci(&xs, 500, 0.95, 1, mean);
        assert!(lo < 0.5 && 0.5 < hi, "({lo}, {hi})");
        assert!(hi - lo < 0.06);
    }

    #[test]
    fn linreg_exact_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [3.0, 5.0, 7.0, 9.0];
        let (a, b, r2) = linreg(&x, &y);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn loglog_recovers_power() {
        let x: Vec<f64> = (1..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v.powf(-2.0)).collect();
        let (slope, r2) = loglog_slope(&x, &y);
        assert!((slope + 2.0).abs() < 1e-9, "slope={slope}");
        assert!(r2 > 0.999999);
    }

    #[test]
    fn online_matches_batch() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut o = Online::new();
        for &x in &xs {
            o.push(x);
        }
        assert!((o.mean() - mean(&xs)).abs() < 1e-12);
        assert!((o.variance() - variance(&xs)).abs() < 1e-12);
        assert_eq!(o.min, 2.0);
        assert_eq!(o.max, 9.0);
    }
}
